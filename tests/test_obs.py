"""repro.obs: metrics math, span tracing, serve integration, QDQ taps.

Acceptance properties: (1) histogram percentile estimates stay within one
bucket width of the exact quantile and clamp to observed min/max; (2) the
JSONL span log round-trips through ``read_trace``/``validate_trace`` and the
request lifecycle holds a stable ``rid`` across preemption-and-requeue;
(3) the registry is the single source of truth — ``TokenScheduler.counters()``
and the pool's property views are bit-identical to the registry deltas on a
shared-prefix workload; (4) the disabled path is a no-op — tokens served
with tracing on are bit-identical to tokens served with observability off;
(5) the quant-health taps publish when armed at trace time and insert
nothing when disarmed.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.obs import (DEFAULT_LATENCY_BUCKETS, JsonlSink, ListSink,
                       MetricsRegistry, Obs, Tracer, read_trace,
                       record_calibration, validate_trace)
from repro.obs import quant_health
from repro.obs.metrics import Histogram
from repro.obs.validate import (REQUIRED_SERVE_EVENTS, check_trace,
                                parse_prom)
from repro.serve import PagedServeEngine, Request


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _shared_requests(cfg, n, sp_len, suf_len, max_new, seed=7):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, sp_len)
    return [Request(prompt=np.concatenate(
                        [sys_prompt, rng.integers(0, cfg.vocab_size, suf_len)]),
                    max_new=max_new) for _ in range(n)]


# --------------------------------------------------------------------------- #
# Histogram bucket + percentile math
# --------------------------------------------------------------------------- #
def test_histogram_buckets_and_exact_stats():
    h = Histogram("t", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 10.0):
        h.observe(v)
    # (..,1], (1,2], (2,4], (4,..) — boundary values land in the lower bucket
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5 and h.sum == pytest.approx(16.0)
    assert h._min == 0.5 and h._max == 10.0
    assert h.mean == pytest.approx(3.2)


def test_histogram_percentile_within_bucket_width():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-3.0, sigma=1.2, size=2000)
    h = Histogram("lat", buckets=DEFAULT_LATENCY_BUCKETS)
    for v in samples:
        h.observe(v)
    bounds = (0.0,) + DEFAULT_LATENCY_BUCKETS
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = float(np.quantile(samples, q))
        est = h.percentile(q)
        # the estimate must land in the same or an adjacent bucket: its error
        # is bounded by the width of the bucket holding the exact quantile
        width = next(hi - lo for lo, hi in zip(bounds, bounds[1:])
                     if exact <= hi)
        assert abs(est - exact) <= width, (q, exact, est)
    # edge clamping: p0/p100 return the exact observed extremes
    assert h.percentile(0.0) == pytest.approx(h._min)
    assert h.percentile(1.0) == pytest.approx(h._max)


def test_histogram_percentile_degenerate():
    h = Histogram("t", buckets=(1.0, 2.0))
    assert math.isnan(h.percentile(0.5))        # empty
    h.observe(1.5)
    # single observation: every quantile is that observation
    assert h.percentile(0.5) == pytest.approx(1.5)
    assert h.percentile(0.99) == pytest.approx(1.5)
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(2.0, 1.0))    # non-increasing bounds


def test_registry_types_and_prom_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c_total", help="a counter").inc(3)
    reg.counter("c_total").inc(0.5)             # same object, float ok
    reg.gauge("g", {"site": "r1"}).set(2.5)
    reg.gauge("g_live").set_fn(lambda: 7)
    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    h.observe(0.05), h.observe(0.5), h.observe(5.0)
    with pytest.raises(TypeError):
        reg.gauge("c_total")                    # name is already a counter
    with pytest.raises(ValueError):
        reg.counter("c_total").inc(-1)          # counters are monotone

    snap = reg.snapshot()
    assert snap["c_total"] == pytest.approx(3.5)
    assert snap['g{site="r1"}'] == 2.5
    assert snap["g_live"] == 7
    assert snap["h_seconds_count"] == 3

    path = tmp_path / "m.prom"
    reg.write_prom(str(path))
    text = path.read_text()
    # cumulative le-buckets + the +Inf catch-all
    assert 'h_seconds_bucket{le="0.1"} 1' in text
    assert 'h_seconds_bucket{le="1"} 2' in text
    assert 'h_seconds_bucket{le="+Inf"} 3' in text
    assert parse_prom(str(path)) == {"c_total", "g", "g_live", "h_seconds"}


# --------------------------------------------------------------------------- #
# Span tracing: schema, JSONL round-trip
# --------------------------------------------------------------------------- #
def test_tracer_schema_and_jsonl_roundtrip(tmp_path):
    path = tmp_path / "trace.jsonl"
    tr = Tracer(JsonlSink(str(path)))
    tr.emit("enqueue", rid=0, prompt_len=4, max_new=2)
    tr.emit("admit", rid=0, seq_id=0, slot=0, cached_len=0, queue_s=0.0)
    tr.emit("first_token", rid=0, seq_id=0, ttft_s=0.01)
    tr.emit("finish", rid=0, seq_id=0, n_tokens=2, pages_held=1,
            ttft_s=0.01, queue_s=0.0, itl_mean_s=0.002)
    with pytest.raises(ValueError, match="unknown trace event"):
        tr.emit("made_up_event", rid=0)
    tr.close()

    events = read_trace(str(path))
    assert len(events) == 4
    validate_trace(events, require={"enqueue", "finish"})
    for ev in events:
        assert {"event", "t_wall", "t_mono"} <= ev.keys()
    # every line is standalone JSON (crash-parseable contract)
    for line in path.read_text().splitlines():
        json.loads(line)
    # schema violations are loud
    with pytest.raises(ValueError, match="missing fields"):
        validate_trace([{"event": "finish", "t_wall": 0.0, "t_mono": 0.0}])
    with pytest.raises(ValueError, match="no .*decode_step"):
        validate_trace(events, require={"decode_step"})


def test_obs_disabled_emit_is_noop():
    obs = Obs()
    assert not obs.tracing
    obs.emit("enqueue", rid=0, prompt_len=1, max_new=1)   # swallowed
    sink = ListSink()
    obs2 = Obs(tracer=Tracer(sink))
    obs2.emit("enqueue", rid=0, prompt_len=1, max_new=1)
    assert len(sink.events) == 1


# --------------------------------------------------------------------------- #
# Serve integration: lifecycle under preemption, registry == counters(),
# disabled-path bit-identity
# --------------------------------------------------------------------------- #
def test_span_lifecycle_stable_rid_across_preemption(cfg, params):
    """Overcommitted pool (the test_serve_prefix workload): a request is
    preempted and re-admitted, and its spans keep one rid across
    admit -> preempt -> admit -> finish while seq_id changes."""
    sp_len, suf_len, max_new, page = 20, 4, 8, 8
    num_pages = -(-(sp_len + suf_len) // page) + 3
    sink = ListSink()
    obs = Obs(tracer=Tracer(sink))
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=page, kv_bits=4, prefix_cache=True,
                           num_pages=num_pages, obs=obs)
    reqs, stats = eng.generate(
        _shared_requests(cfg, 4, sp_len, suf_len, max_new, seed=11))
    assert all(r.done for r in reqs)
    assert stats["preemptions"] >= 1

    events = sink.events
    validate_trace(events, require=REQUIRED_SERVE_EVENTS | {"preempt",
                                                            "prefill_chunk"})
    preempted_rids = {e["rid"] for e in events if e["event"] == "preempt"}
    assert preempted_rids
    for rid in preempted_rids:
        chain = [e for e in events
                 if e.get("rid") == rid and e["event"] != "prefill_chunk"]
        kinds = [e["event"] for e in chain]
        # enqueue once, admitted at least twice around the preemption, and
        # exactly one terminal finish
        assert kinds[0] == "enqueue" and kinds[-1] == "finish"
        assert kinds.count("admit") >= 2
        assert kinds.count("finish") == 1
        assert kinds.index("preempt") > kinds.index("admit")
        # re-admission changed the sequence identity but not the rid
        seq_ids = [e["seq_id"] for e in chain if "seq_id" in e]
        assert len(set(seq_ids)) >= 2
        fin = chain[-1]
        assert fin["n_tokens"] == max_new
        assert fin["ttft_s"] >= 0 and fin["queue_s"] >= 0
    # decode_step events carry who was running
    steps = [e for e in events if e["event"] == "decode_step"]
    assert steps and all(len(e["rids"]) == e["n_running"] for e in steps)
    # requests that finish report the pages they held before the free
    assert all(e["pages_held"] > 0 for e in events if e["event"] == "finish")


def test_registry_matches_legacy_counters(cfg, params):
    """counters() is a compat view over the registry: on a shared-prefix
    workload the dict values equal the registry counters bit-for-bit
    (fresh engine, so lifetime == per-call deltas)."""
    sp_len, suf_len, max_new, page = 18, 3, 4, 8
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=page, kv_bits=4, prefix_cache=True)
    reqs, stats = eng.generate(
        _shared_requests(cfg, 5, sp_len, suf_len, max_new))
    assert all(r.done for r in reqs)
    m = eng.obs.metrics
    assert stats["prompt_tokens"] == m.value("serve_prompt_tokens_total")
    assert stats["prefix_hit_tokens"] == m.value(
        "serve_prefix_hit_tokens_total")
    assert stats["cow_copies"] == m.value("serve_cow_copies_total")
    assert stats["prefix_evictions"] == m.value(
        "serve_prefix_evictions_total")
    assert stats["preemptions"] == m.value("serve_preemptions_total")
    assert stats["prefix_hit_rate"] == pytest.approx(
        stats["prefix_hit_tokens"] / stats["prompt_tokens"])
    # pool property views ride the same counters
    assert eng.pool.cow_copies == stats["cow_copies"]
    # engine token counters agree with the stats the loop accumulated
    assert stats["prefill_tokens"] == m.value("serve_prefill_tokens_total")
    assert m.value("serve_decode_tokens_total") == sum(
        len(r.out) - 1 for r in reqs)
    # latency histograms saw every request / step
    assert m.histogram("serve_ttft_seconds").count == len(reqs)
    assert m.histogram("serve_itl_seconds").count == m.value(
        "serve_decode_tokens_total")
    # occupancy gauges are live views over a consistent pool
    snap = m.snapshot()
    assert snap["serve_pages_free"] + snap["serve_pages_owned"] \
        + snap["serve_pages_shared"] == snap["serve_pages_total"]


def test_tracing_does_not_change_tokens(cfg, params):
    """The hard requirement: observability on vs off serves bit-identical
    tokens (tracing adds fences and event assembly, never math)."""
    sp_len, suf_len, max_new, page = 20, 4, 6, 8

    def run(obs):
        eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                               page_size=page, kv_bits=4, prefix_cache=True,
                               obs=obs)
        reqs, _ = eng.generate(
            _shared_requests(cfg, 4, sp_len, suf_len, max_new, seed=3))
        return [r.out for r in reqs]

    plain = run(None)                           # default Obs: no tracer
    sink = ListSink()
    traced = run(Obs(tracer=Tracer(sink)))
    assert traced == plain
    assert sink.events                          # tracing actually happened


def test_scheduler_error_paths_count_before_raising(cfg, params):
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=8, num_pages=3, kv_bits=4)
    m = eng.obs.metrics
    with pytest.raises(MemoryError, match="growth stall"):
        eng.generate([Request(prompt=np.arange(8) % cfg.vocab_size,
                              max_new=24)])
    assert m.value("serve_growth_stalls_total") == 1

    with pytest.raises(ValueError, match="max_new"):
        eng.generate([Request(prompt=np.arange(4), max_new=0)])
    assert m.value("serve_admission_rejects_total") == 1


# --------------------------------------------------------------------------- #
# Calibration-side: loss streaming + QDQ health taps
# --------------------------------------------------------------------------- #
def test_record_calibration_single_and_batched():
    sink = ListSink()
    obs = Obs(tracer=Tracer(sink))
    record_calibration(obs, "r1", np.array([4.0, 3.0, 2.0]),
                       aux={"kurtosis": np.array([9.0, 5.0, 3.0])})
    record_calibration(obs, "r2", np.array([[2.0, 1.0], [6.0, 5.0]]))
    m = obs.metrics
    assert m.value("calib_loss_initial", {"site": "r1"}) == 4.0
    assert m.value("calib_loss_final", {"site": "r1"}) == 2.0
    assert m.value("calib_steps_total", {"site": "r1"}) == 3
    assert m.value("calib_metric_final",
                   {"site": "r1", "metric": "kurtosis"}) == 3.0
    # batched history publishes one site per layer
    assert m.value("calib_loss_final", {"site": "r2[0]"}) == 1.0
    assert m.value("calib_loss_final", {"site": "r2[1]"}) == 5.0
    spans = [e for e in sink.events if e["event"] == "calib_site"]
    assert [e["site"] for e in spans] == ["r1", "r2[0]", "r2[1]"]
    assert spans[0]["loss_history"] == [4.0, 3.0, 2.0]
    validate_trace(spans)


def test_calibrate_scan_streams_into_registry():
    from repro.core.qr_orth import calibrate_scan
    from repro.core.whip import whip
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 8))
    z0 = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    obs = Obs()
    res = calibrate_scan(x, z0, whip, steps=5, lr=1e-2, obs=obs,
                         site="r1")
    lh = np.asarray(res.loss_history)
    m = obs.metrics
    assert m.value("calib_loss_initial", {"site": "r1"}) == pytest.approx(
        float(lh[0]))
    assert m.value("calib_loss_final", {"site": "r1"}) == pytest.approx(
        float(lh[-1]))
    assert m.value("calib_steps_total", {"site": "r1"}) == 5


def test_quant_health_tap_armed_vs_disarmed():
    from repro.quant.quantizers import fake_quant_act, quant_weight
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))

    # disarmed (default): jit the QDQ path, nothing is published
    baseline = np.asarray(jax.jit(lambda v: fake_quant_act(v, 4))(x))

    reg = MetricsRegistry()
    with quant_health.sampling(reg):
        # armed at trace time: the callback is baked into this program
        armed = np.asarray(jax.jit(lambda v: fake_quant_act(v, 4))(x))
        quant_weight(x, bits=4, group=16)
        jax.effects_barrier()
    assert np.array_equal(baseline, armed)      # taps never change values
    assert reg.value("quant_act_samples_total") >= 1
    assert reg.value("quant_weight_samples_total") >= 1
    clip = reg.histogram("quant_act_clip_rate")
    assert clip.count >= 1 and 0.0 <= clip._max <= 1.0
    # min-max asymmetric act quant always pins both extremes somewhere
    assert reg.value("quant_act_clip_rate_last") > 0.0
    dyn = reg.histogram("quant_weight_scale_dynamic_range_log2")
    assert dyn.count >= 1 and dyn._min >= 0.0

    before = reg.value("quant_act_samples_total")
    jax.jit(lambda v: fake_quant_act(v, 4))(x + 1.0)     # traced disarmed
    jax.effects_barrier()
    assert reg.value("quant_act_samples_total") == before


# --------------------------------------------------------------------------- #
# validate CLI plumbing
# --------------------------------------------------------------------------- #
def test_check_trace_catches_unfinished(tmp_path):
    path = tmp_path / "t.jsonl"
    tr = Tracer(JsonlSink(str(path)))
    tr.emit("enqueue", rid=0, prompt_len=4, max_new=2)
    tr.emit("admit", rid=0, seq_id=0, slot=0, cached_len=0, queue_s=0.0)
    tr.emit("first_token", rid=0, seq_id=0, ttft_s=0.01)
    tr.emit("decode_step", n_running=1, duration_s=0.001, rids=[0])
    # a second request completes normally; rid 0 never reaches finish
    tr.emit("enqueue", rid=1, prompt_len=4, max_new=1)
    tr.emit("admit", rid=1, seq_id=1, slot=1, cached_len=0, queue_s=0.0)
    tr.emit("first_token", rid=1, seq_id=1, ttft_s=0.01)
    tr.emit("finish", rid=1, seq_id=1, n_tokens=1, pages_held=1,
            ttft_s=0.01, queue_s=0.0, itl_mean_s=0.0)
    tr.close()
    with pytest.raises(ValueError, match="never finished"):
        check_trace(str(path))
