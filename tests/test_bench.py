"""repro.obs.bench: record discipline, artifact round-trip, regression gate."""
import json

import pytest

from repro.obs.bench import (BenchRecord, BenchReport, bench_path,
                             compare_reports, env_fingerprint, main,
                             measure, read_bench_json, record_from_samples,
                             write_bench_json)
from repro.obs.validate import check_bench
from repro.obs.validate import main as validate_main

FP = {"jax": "0.0.test", "jaxlib": "0.0.test", "backend": "cpu",
      "device_kind": "cpu", "device_count": 1, "cpu_count": 1,
      "python": "3.x", "platform": "test", "git_sha": "deadbeef",
      "smoke": True}


def _report(records, module="benchmarks.demo", fp=None):
    return BenchReport(module=module, fingerprint=dict(fp or FP),
                       records=records)


def _write(tmp_path, name, report):
    d = tmp_path / name
    d.mkdir(exist_ok=True)
    return str(write_bench_json(report, str(d)))


# --------------------------------------------------------------------------- #
# records + measurement discipline
# --------------------------------------------------------------------------- #
def test_record_validation():
    with pytest.raises(ValueError):
        BenchRecord(name="", value=1.0, unit="s")
    with pytest.raises(ValueError):
        BenchRecord(name="x", value=1.0, unit="")
    with pytest.raises(ValueError):
        BenchRecord(name="x", value=1.0, unit="s", repeats=0)


def test_record_from_samples_median_and_iqr():
    rec = record_from_samples("t", [3.0, 1.0, 2.0, 4.0, 100.0], "s",
                              warmup=1)
    assert rec.repeats == 5 and rec.warmup == 1
    assert rec.value == rec.median == 3.0
    assert rec.q25 <= rec.median <= rec.q75
    assert rec.iqr is not None and rec.iqr > 0


def test_record_from_samples_single_sample_degrades():
    rec = record_from_samples("t", [2.5], "s")
    assert rec.repeats == 1
    assert rec.q25 == rec.median == rec.q75 == 2.5


def test_measure_runs_warmup_plus_repeats():
    calls = []
    rec = measure("t", lambda: calls.append(1), unit="s", repeats=4,
                  warmup=2)
    assert len(calls) == 6          # 2 warmup + 4 timed
    assert rec.repeats == 4 and rec.warmup == 2
    assert rec.value >= 0


def test_env_fingerprint_complete():
    fp = env_fingerprint(smoke=True)
    for key in ("jax", "backend", "device_kind", "device_count",
                "cpu_count", "git_sha", "smoke"):
        assert key in fp
    assert fp["smoke"] is True
    assert env_fingerprint()["smoke"] is False


def test_report_round_trip(tmp_path):
    rep = _report([BenchRecord("a,b", 1.5, "s"),
                   record_from_samples("c", [1.0, 2.0, 3.0], "tok_per_s")])
    path = write_bench_json(rep, str(tmp_path))
    assert path.name == "BENCH_demo.json"
    back = read_bench_json(str(path))
    assert back.module == rep.module
    assert back.fingerprint == rep.fingerprint
    assert [r.to_dict() for r in back.records] == \
        [r.to_dict() for r in rep.records]


def test_read_rejects_wrong_schema(tmp_path):
    p = tmp_path / "BENCH_bad.json"
    p.write_text(json.dumps({"schema": 999, "module": "m",
                             "fingerprint": {}, "records": []}))
    with pytest.raises(ValueError, match="schema"):
        read_bench_json(str(p))


def test_bench_path_uses_short_module_name(tmp_path):
    assert bench_path(str(tmp_path),
                      "benchmarks.serve_bench").name == "BENCH_serve_bench.json"


# --------------------------------------------------------------------------- #
# compare_reports semantics
# --------------------------------------------------------------------------- #
def _statuses(verdicts):
    return {v.name: v.status for v in verdicts}


def test_compare_detects_timing_regression():
    base = _report([record_from_samples("t", [1.0, 1.01, 1.02], "s")])
    cur = _report([record_from_samples("t", [3.0, 3.01, 3.02], "s")])
    verdicts, errors = compare_reports(base, cur, timing_tol=0.5)
    assert not errors
    assert _statuses(verdicts) == {"t": "regressed"}


def test_compare_improvement_passes():
    base = _report([record_from_samples("t", [2.0, 2.1, 2.2], "s"),
                    record_from_samples("thru", [10.0, 10.5, 11.0],
                                        "tok_per_s")])
    cur = _report([record_from_samples("t", [1.0, 1.05, 1.1], "s"),
                   record_from_samples("thru", [20.0, 21.0, 22.0],
                                       "tok_per_s")])
    verdicts, errors = compare_reports(base, cur)
    assert not errors
    assert set(_statuses(verdicts).values()) == {"ok"}


def test_compare_throughput_drop_regresses():
    base = _report([record_from_samples("thru", [20.0, 20.1, 20.2],
                                        "tok_per_s")])
    cur = _report([record_from_samples("thru", [5.0, 5.1, 5.2],
                                       "tok_per_s")])
    verdicts, _ = compare_reports(base, cur, timing_tol=0.5)
    assert _statuses(verdicts) == {"thru": "regressed"}


def test_compare_loose_tol_still_gates_throughput():
    # tol is a multiplicative slowdown bound: even the loose CI tolerance
    # (tol=20 -> 21x) must catch a 50x throughput collapse; an additive
    # margin would make any tol >= 1 vacuous for higher-better units
    base = _report([BenchRecord("thru", 1000.0, "tok_per_s")])
    verdicts, _ = compare_reports(
        base, _report([BenchRecord("thru", 20.0, "tok_per_s")]),
        timing_tol=20.0)
    assert _statuses(verdicts) == {"thru": "regressed"}
    # a drop within the bound (1000 -> 100 > 1000/21) passes
    verdicts, _ = compare_reports(
        base, _report([BenchRecord("thru", 100.0, "tok_per_s")]),
        timing_tol=20.0)
    assert _statuses(verdicts) == {"thru": "ok"}


def test_compare_missing_vs_new_metric():
    base = _report([BenchRecord("kept", 1, "tok"), BenchRecord("gone", 2,
                                                               "tok")])
    cur = _report([BenchRecord("kept", 1, "tok"),
                   BenchRecord("added", 3, "tok")])
    verdicts, errors = compare_reports(base, cur)
    assert not errors
    st = _statuses(verdicts)
    assert st["gone"] == "missing"          # tracked metric vanished: fails
    assert st["added"] == "new"             # new metric: never gates
    assert st["kept"] == "ok"


def test_compare_zero_baseline_is_informational():
    base = _report([BenchRecord("t", 0.0, "s")])
    cur = _report([BenchRecord("t", 5.0, "s")])
    verdicts, _ = compare_reports(base, cur)
    assert _statuses(verdicts) == {"t": "info"}


def test_compare_iqr_overlap_rescues_noise():
    # median drifted +60% (beyond tol) but the repeat distributions overlap:
    # noise, not regression
    base = _report([BenchRecord("t", 1.0, "s", repeats=3, warmup=1,
                                q25=0.9, median=1.0, q75=1.8)])
    cur = _report([BenchRecord("t", 1.6, "s", repeats=3, warmup=1,
                               q25=1.5, median=1.6, q75=1.7)])
    verdicts, _ = compare_reports(base, cur, timing_tol=0.5)
    assert _statuses(verdicts) == {"t": "ok"}
    # single-shot records get no IQR rescue
    base1 = _report([BenchRecord("t", 1.0, "s")])
    cur1 = _report([BenchRecord("t", 1.6, "s")])
    verdicts, _ = compare_reports(base1, cur1, timing_tol=0.5)
    assert _statuses(verdicts) == {"t": "regressed"}


def test_compare_strict_units_exact():
    base = _report([BenchRecord("bytes", 4096, "B")])
    ok, _ = compare_reports(base, _report([BenchRecord("bytes", 4096, "B")]))
    assert _statuses(ok) == {"bytes": "ok"}
    # even an *improvement* in a deterministic metric is drift: strict units
    # gate on equality, the baseline must be refreshed deliberately
    bad, _ = compare_reports(base, _report([BenchRecord("bytes", 4095, "B")]))
    assert _statuses(bad) == {"bytes": "regressed"}


def test_compare_unit_change_and_unknown_unit():
    base = _report([BenchRecord("a", 1.0, "s"), BenchRecord("b", 2.0,
                                                            "blorps")])
    cur = _report([BenchRecord("a", 1.0, "ms"), BenchRecord("b", 9.0,
                                                            "blorps")])
    st = _statuses(compare_reports(base, cur)[0])
    assert st["a"] == "regressed"           # unit changed
    assert st["b"] == "info"                # unknown unit: never gates


def test_compare_fingerprint_gate():
    base = _report([BenchRecord("t", 1.0, "s")])
    cur_fp = dict(FP, smoke=False)
    cur = _report([BenchRecord("t", 1.0, "s")], fp=cur_fp)
    verdicts, errors = compare_reports(base, cur)
    assert errors and not verdicts          # smoke-vs-full never compares
    verdicts, errors = compare_reports(base, cur, allow_env_mismatch=True)
    assert not errors and _statuses(verdicts) == {"t": "ok"}


def test_compare_tol_override():
    base = _report([record_from_samples("t", [1.0, 1.0, 1.0], "s")])
    cur = _report([record_from_samples("t", [1.4, 1.4, 1.4], "s")])
    verdicts, _ = compare_reports(base, cur, timing_tol=0.1)
    assert _statuses(verdicts) == {"t": "regressed"}
    verdicts, _ = compare_reports(base, cur, timing_tol=0.1,
                                  tol_overrides={"t": 1.0})
    assert _statuses(verdicts) == {"t": "ok"}


# --------------------------------------------------------------------------- #
# the CLI: exit codes are the CI contract
# --------------------------------------------------------------------------- #
def test_cli_self_compare_passes(tmp_path):
    rep = _report([record_from_samples("t", [1.0, 1.1], "s"),
                   BenchRecord("bytes", 64, "B")])
    p = _write(tmp_path, "a", rep)
    assert main(["compare", p, p]) == 0


def test_cli_injected_regression_fails(tmp_path, capsys):
    base = _report([record_from_samples("t", [1.0, 1.01, 1.02], "s")])
    cur = _report([record_from_samples("t", [9.0, 9.01, 9.02], "s")])
    bp = _write(tmp_path, "base", base)
    cp = _write(tmp_path, "cur", cur)
    assert main(["compare", bp, cp]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "FAIL" in out


def test_cli_dir_mode_missing_current_report_fails(tmp_path):
    rep = _report([BenchRecord("t", 1.0, "s")])
    bd, cd = tmp_path / "base", tmp_path / "cur"
    bd.mkdir(), cd.mkdir()
    write_bench_json(rep, str(bd))
    assert main(["compare", str(bd), str(cd)]) == 1     # module didn't run
    write_bench_json(rep, str(cd))
    assert main(["compare", str(bd), str(cd)]) == 0


def test_cli_usage_errors_exit_2(tmp_path):
    rep = _report([BenchRecord("t", 1.0, "s")])
    d = tmp_path / "a"
    d.mkdir()
    p = write_bench_json(rep, str(d))
    assert main(["compare", str(d), str(p)]) == 2       # dir vs file
    assert main(["compare", str(p), str(p), "--tol", "nonsense"]) == 2


# --------------------------------------------------------------------------- #
# obs.validate --bench
# --------------------------------------------------------------------------- #
def test_validate_bench_ok(tmp_path):
    rep = _report([record_from_samples("t", [1.0, 2.0, 3.0], "s")])
    p = str(write_bench_json(rep, str(tmp_path)))
    assert check_bench(p).module == rep.module
    assert validate_main(["--bench", p]) == 0


def test_validate_bench_rejects_bad_artifacts(tmp_path):
    # missing fingerprint key
    fp = {k: v for k, v in FP.items() if k != "git_sha"}
    p1 = str(write_bench_json(_report([BenchRecord("t", 1.0, "s")], fp=fp),
                              str(tmp_path / "a")))
    with pytest.raises(ValueError, match="git_sha"):
        check_bench(p1)
    # empty record list
    p2 = str(write_bench_json(_report([]), str(tmp_path / "b")))
    with pytest.raises(ValueError, match="no records"):
        check_bench(p2)
    # disordered quartiles (hand-corrupted artifact)
    rep = _report([BenchRecord("t", 1.0, "s", repeats=3, q25=5.0,
                               median=1.0, q75=0.5)])
    p3 = str(write_bench_json(rep, str(tmp_path / "c")))
    with pytest.raises(ValueError, match="quartiles"):
        check_bench(p3)
    assert validate_main(["--bench", p3]) == 1
