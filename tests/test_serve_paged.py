"""Paged serving runtime: allocator, int4 KV accounting, scheduler correctness.

The headline test serves more requests than slots through the paged engine and
checks every completed request's tokens against a single-sequence dense-cache
reference run — exactly the property the legacy lockstep engine violates (its
slot refill decodes a queued prompt against the previous occupant's KV).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.quant import (dequantize_kv, kv_bytes, make_kv_quant, quantize_kv,
                         quantkv_bytes)
from repro.quant.context import get_act_quant
from repro.serve import PagedServeEngine, PagePool, Request
from repro.train import steps as S


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# Page-pool allocator
# --------------------------------------------------------------------------- #
def test_page_pool_allocator(cfg):
    pool = PagePool(cfg, num_pages=8, page_size=4, max_seq=32, kv_bits=4)
    assert pool.free_pages == 7                 # page 0 reserved (null page)
    p0 = pool.alloc_seq(0, 9)                   # 3 pages
    assert len(p0) == 3 and 0 not in p0
    row = pool.block_table_row(0)
    assert row.shape == (8,) and list(row[:3]) == p0 and not row[3:].any()
    with pytest.raises(ValueError):
        pool.alloc_seq(0, 4)                    # double alloc
    pool.alloc_seq(1, 16)                       # 4 pages -> 0 free
    assert pool.free_pages == 0
    with pytest.raises(MemoryError):
        pool.alloc_seq(2, 1)
    pool.free_seq(0)
    assert pool.free_pages == 3
    assert not pool.block_table_row(0).any()    # freed seq -> null entries
    p2 = pool.alloc_seq(2, 12)
    assert sorted(p2) == sorted(p0)             # pages recycled


def test_page_pool_rejects_unsupported():
    """Only encoder-decoder models fall outside the paged runtime now —
    MLA latent caches and SSM state pools are first-class adapters."""
    encdec = get_config("whisper-medium").reduced()
    with pytest.raises(NotImplementedError, match="ServeEngine"):
        PagePool(encdec, num_pages=4, page_size=4, max_seq=16)
    # previously-rejected families construct adapter-backed pools
    for arch in ("deepseek-v3-671b", "mamba2-370m", "zamba2-7b"):
        pool = PagePool(get_config(arch).reduced(), num_pages=4, page_size=4,
                        max_seq=16, n_slots=2)
        assert pool.nbytes == pool.predicted_nbytes


# --------------------------------------------------------------------------- #
# int4 integer KV path
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("hd", [16, 13])        # even + odd head dims
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_kv_roundtrip(hd, bits, key):
    kv = jax.random.normal(key, (2, 6, 3, hd), jnp.float32) * 2.5
    qkv = quantize_kv(kv, bits)
    back = dequantize_kv(qkv, bits, jnp.float32, head_dim=hd)
    assert back.shape == kv.shape
    # error bound: half an int step + fp16 rounding of scale/zero
    step = np.asarray(qkv.scale, np.float32).max()
    assert float(jnp.max(jnp.abs(back - kv))) <= 0.5 * step + 2e-2
    # codes are stable: re-quantizing the dequantized values is a fixed point
    again = dequantize_kv(quantize_kv(back, bits), bits, jnp.float32,
                          head_dim=hd)
    np.testing.assert_allclose(np.asarray(again), np.asarray(back), atol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_kv_quant_hook_matches_integer_path(bits, key):
    """The QDQ rot-context hook is bit-exact with QuantKV storage."""
    kv = jax.random.normal(key, (2, 5, 2, 16), jnp.float32)
    hook = make_kv_quant(bits)
    direct = dequantize_kv(quantize_kv(kv, bits), bits, kv.dtype, head_dim=16)
    assert (np.asarray(hook(kv)) == np.asarray(direct)).all()


@pytest.mark.parametrize("hd", [16, 13])
@pytest.mark.parametrize("bits", [4, 8])
def test_kv_bytes_matches_quantkv(hd, bits, key):
    """kv_bytes == bytes actually held by the K and V QuantKVs."""
    B, Sl, L, H = 2, 8, 3, 2
    held = 0
    for part in range(2 * L):                   # K and V, per layer
        kv = jax.random.normal(jax.random.fold_in(key, part), (B, Sl, H, hd))
        held += quantkv_bytes(quantize_kv(kv, bits))
    assert held == kv_bytes(B, Sl, L, H, hd, bits)


def test_pool_nbytes_matches_prediction(cfg):
    pool = PagePool(cfg, num_pages=9, page_size=4, max_seq=32, kv_bits=4)
    assert pool.nbytes == pool.predicted_nbytes
    # and the pool *is* QuantKV-formatted: per-page bytes match kv_bytes
    assert pool.nbytes == kv_bytes(1, 9 * 4, cfg.n_layers, cfg.n_kv_heads,
                                   cfg.resolved_head_dim, 4)


# --------------------------------------------------------------------------- #
# Scheduler correctness: the refill-bug acceptance test
# --------------------------------------------------------------------------- #
def _dense_reference(cfg, params, prompt, max_new, max_seq, rot):
    """Single-sequence greedy run on the dense-cache prefill/decode path."""
    pre = jax.jit(S.build_prefill(cfg, rot=rot))
    dec = jax.jit(S.build_decode_step(cfg, rot=rot))
    plen = len(prompt)
    logits, cache = pre(params, jnp.asarray(np.asarray(prompt)[None],
                                            jnp.int32))
    cache = jax.tree.map(
        lambda x: (jnp.pad(x, [(0, 0)] * 2 + [(0, max_seq - x.shape[2])]
                           + [(0, 0)] * (x.ndim - 3))
                   if x.ndim >= 3 and x.shape[2] == plen else x), cache)
    out = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    last, pos = out[0], plen
    for _ in range(max_new - 1):
        logits, cache = dec(params, jnp.asarray([[last]], jnp.int32), cache,
                            jnp.int32(pos))
        last = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
        out.append(last)
        pos += 1
    return out


def test_scheduler_more_requests_than_slots_matches_dense(cfg, params):
    """5 requests over 2 slots, ragged prompts crossing page boundaries:
    every request's greedy tokens equal its own single-sequence dense run.
    (The legacy ServeEngine fails this: a refilled slot decodes from the
    prompt-tail token over the previous occupant's KV cache.)"""
    max_seq = 48
    lens = [12, 7, 12, 9, 7]                    # few distinct prefill shapes
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, n), max_new=6)
            for n in lens]
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=max_seq,
                           page_size=8, a_bits=16, kv_bits=4)
    reqs, _ = eng.generate(reqs)
    assert all(r.done for r in reqs)
    rot = {"kv_quant": make_kv_quant(4)}
    for i, r in enumerate(reqs):
        ref = _dense_reference(cfg, params, r.prompt, r.max_new, max_seq, rot)
        assert r.out == ref, f"request {i} diverged: {r.out} vs {ref}"


def test_paged_engine_8bit_kv(cfg, params):
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 9), max_new=5)
            for _ in range(3)]
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=8, a_bits=16, kv_bits=8)
    reqs, stats = eng.generate(reqs)
    assert all(r.done for r in reqs)
    rot = {"kv_quant": make_kv_quant(8)}
    ref = _dense_reference(cfg, params, reqs[0].prompt, 5, 32, rot)
    assert reqs[0].out == ref
    assert stats["kv_cache_bytes"] == eng.pool.nbytes


def test_max_new_one_requests_cycle_through_slots(cfg, params):
    """Requests that finish at prefill free their slot for the next waiting
    request instead of tripping the deadlock guard."""
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6), max_new=1)
            for _ in range(3)]
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=16,
                           page_size=8, kv_bits=4)
    reqs, _ = eng.generate(reqs)
    assert all(r.done and len(r.out) == 1 for r in reqs)
    rot = {"kv_quant": make_kv_quant(4)}
    for r in reqs:
        assert r.out == _dense_reference(cfg, params, r.prompt, 1, 16, rot)


def test_oversized_request_raises(cfg, params):
    """A request longer than max_seq can never fit: loud MemoryError, not a
    mid-admit crash."""
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=16,
                           page_size=8, kv_bits=4)
    reqs = [Request(prompt=np.arange(20) % cfg.vocab_size, max_new=8)]
    with pytest.raises(MemoryError, match="max_seq"):
        eng.generate(reqs)


def test_prefill_chunk_overhang_lands_on_null_page(cfg, params):
    """A prefill chunk wider than the seq's reserved page coverage must spill
    to the null page — clamp-gather aliasing would overwrite real prompt KV."""
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 10)
    reqs = [Request(prompt=prompt, max_new=6)]
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=16,
                           page_size=8, prefill_chunk=32, kv_bits=4)
    reqs, _ = eng.generate(reqs)
    rot = {"kv_quant": make_kv_quant(4)}
    assert reqs[0].out == _dense_reference(cfg, params, prompt, 6, 16, rot)


def test_pool_exhaustion_raises(cfg, params):
    """A request that can never fit fails loudly instead of deadlocking."""
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=8, num_pages=2, kv_bits=4)
    reqs = [Request(prompt=np.arange(20) % cfg.vocab_size, max_new=8)]
    with pytest.raises(MemoryError):
        eng.generate(reqs)


# --------------------------------------------------------------------------- #
# Act-quant threading (no global trace-time context)
# --------------------------------------------------------------------------- #
def test_act_quant_threaded_through_builders(cfg, params):
    toks = jnp.asarray(np.arange(8)[None] % cfg.vocab_size, jnp.int32)
    plain = jax.jit(S.build_prefill(cfg))(params, toks)[0]
    from repro.quant import fake_quant_act
    quant = jax.jit(S.build_prefill(
        cfg, act_quant=lambda x: fake_quant_act(x, 4)))(params, toks)[0]
    # the hook must be live while jit traces: W-only vs W+A4 logits differ
    assert float(jnp.max(jnp.abs(plain - quant))) > 1e-3
    assert get_act_quant() is None              # nothing leaked globally


def test_engine_construction_leaves_no_global_hook(cfg, params):
    from repro.serve import ServeEngine
    eng = ServeEngine(cfg, params, batch_slots=1, max_seq=16, a_bits=8,
                      kv_bits=4, page_size=8)
    assert get_act_quant() is None
    # the wrapper forwards decoder-only families to the paged engine, and the
    # threaded hook is actually applied at trace time (the old global
    # set/clear around jit construction never fired — tracing is lazy)
    assert eng._paged is not None
    eng16 = ServeEngine(cfg, params, batch_slots=1, max_seq=16, a_bits=16,
                        kv_bits=4, page_size=8)

    def tail_logits(e):
        pool = e._paged.pool
        toks = jnp.asarray(np.arange(8)[None] % cfg.vocab_size, jnp.int32)
        table = jnp.asarray(pool.block_table_row(0)[None])  # null pages only
        from repro.models import model as M
        carry = M.init_prefill_carry(cfg, kv_bits=4)
        logits, _, _ = e._paged._prefill(params, toks, pool.state, table,
                                         jnp.int32(0), carry, jnp.int32(8), 1)
        return logits
    diff = jnp.max(jnp.abs(tail_logits(eng) - tail_logits(eng16)))
    assert float(diff) > 1e-4
    assert get_act_quant() is None              # nothing leaked globally
