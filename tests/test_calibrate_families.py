"""Calibration + fusion end-to-end for non-dense families: the whole
capture -> QR-Orth/Whip -> fuse pipeline must preserve model outputs for
SSM (R1 only), hybrid (R1 + shared R2) and enc-dec (dual R1 + R2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import calibrate_model, fuse_rotations
from repro.core.rotations import _centering, online_hadamard
from repro.data.pipeline import calibration_batch
from repro.models import model as M


@pytest.mark.parametrize("arch", [
    pytest.param("mamba2-370m", marks=pytest.mark.slow),
    pytest.param("zamba2-7b", marks=pytest.mark.slow),
    "whisper-medium", "deepseek-v3-671b"])
def test_calibrate_fuse_preserves_outputs(arch, key):
    cfg = get_config(arch).reduced().replace(n_layers=2)
    if cfg.shared_attn_every:
        cfg = cfg.replace(n_layers=4)
    params = M.init_params(cfg, key)
    calib = jnp.asarray(calibration_batch(cfg, 2, 32))
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jax.random.normal(key, (2, cfg.encoder_seq,
                                               cfg.d_model))
    pack = calibrate_model(cfg, params, calib, frames=kw.get("frames"),
                           key=key, steps=10, lr_r1=0.05, lr_r2=0.05)
    base, _ = M.forward(cfg, params, calib, **kw)
    fcfg, fused = fuse_rotations(cfg, params, pack)
    if cfg.is_encoder_decoder:
        kw["frames"] = kw["frames"] @ _centering(cfg.d_model)
        if "r1_enc" in pack:
            kw["frames"] = kw["frames"] @ pack["r1_enc"]
    out, _ = M.forward(fcfg, fused, calib, rot={"r4": online_hadamard}, **kw)
    rel = float(jnp.max(jnp.abs(out - base))) / (float(jnp.std(base)) + 1e-9)
    assert rel < 2e-2, f"{arch}: calibrated-fusion drift {rel}"
    # the calibrated rotations are genuinely orthogonal
    if "r1" in pack:
        r = pack["r1"]
        np.testing.assert_allclose(np.asarray(r @ r.T),
                                   np.eye(r.shape[0]), atol=1e-4)
