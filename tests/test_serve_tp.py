"""Tensor-parallel paged serving — 8-virtual-device subprocess tests.

The TP contract (ROADMAP "serve mesh"): decode/prefill run inside one
shard_map over the mesh ``model`` axis; sharded greedy decode is
token-for-token identical to the single-device engine; exactly one psum per
layer on the quantized-artifact path (online R4 replicates the FFN); the
scheduler / prefix index / CoW machinery stays host-side and mesh-oblivious.

Each body runs via ``_mesh_compat.run_in_mesh_subprocess`` so the main
pytest process keeps a single device.  The reduced configs ship 4 heads —
parity bodies bump to 8 uniform heads so the 8-way mesh divides them.
"""
import textwrap

import pytest

from _mesh_compat import run_in_mesh_subprocess as _run

PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request
from repro.launch.mesh import make_serve_mesh

def generate(cfg, params, mesh, n=3, max_new=8, **kw):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 12),
                    max_new=max_new) for _ in range(n)]
    eng = PagedServeEngine(cfg, params, mesh=mesh, batch_slots=2,
                           max_seq=64, **kw)
    eng.generate(reqs)
    return [list(r.out) for r in reqs], eng

key = jax.random.PRNGKey(0)
"""


def test_tp_gqa_parity_raw_and_packed():
    """GQA raw fp and packed-int4+online-R3/R4+A8: tp=8 token parity.

    The packed case is the artifact serving path: ffn must replicate under
    the online R4 (it mixes the full hidden dim), giving exactly
    n_layers psums per token.
    """
    code = PRELUDE + textwrap.dedent("""
        from repro.quant import pack_params
        from repro.kernels.hadamard.ops import online_hadamard
        cfg = get_config("llama2-7b").reduced().replace(
            n_heads=8, n_kv_heads=8, head_dim=8)
        params = M.init_params(cfg, key)
        one, _ = generate(cfg, params, None)
        tp, eng = generate(cfg, params, make_serve_mesh(8))
        assert one == tp, ("raw mismatch", one, tp)
        assert eng.tp == 8 and eng.tp_plan.tp == 8

        packed = pack_params(cfg, M.init_params(cfg, key))
        rot = {"r3": online_hadamard, "r4": online_hadamard}
        kw = dict(rot=rot, a_bits=8, kv_bits=4)
        one, _ = generate(cfg, packed, None, **kw)
        tp, eng = generate(cfg, packed, make_serve_mesh(8), **kw)
        assert one == tp, ("packed mismatch", one, tp)
        plan = eng.tp_plan
        assert not plan.ffn_sharded
        assert plan.psums_per_token() == cfg.n_layers
        print("OK gqa parity")
    """)
    r = _run(code)
    assert "OK gqa parity" in r.stdout, r.stdout + r.stderr


def test_tp_decode_collectives_contract():
    """The quantized-artifact decode program satisfies the engine's declared
    contracts — the one-psum-per-layer census (repro.models.common), the
    disarmed-obs zero-callback guarantee, the packed-dtype audit — via the
    same ``analysis.Contract`` objects the CI gate runs (no jaxpr string
    matching)."""
    code = PRELUDE + textwrap.dedent("""
        from repro.quant import pack_params
        from repro.kernels.hadamard.ops import online_hadamard
        from repro.analysis import run_contract
        cfg = get_config("llama2-7b").reduced().replace(
            n_heads=8, n_kv_heads=8, head_dim=8)
        params = pack_params(cfg, M.init_params(cfg, key))
        rot = {"r3": online_hadamard, "r4": online_hadamard}
        _, eng = generate(cfg, params, make_serve_mesh(8), n=1, max_new=2,
                          rot=rot, a_bits=8, kv_bits=4)
        contracts = {c.name: c for c in eng.analysis_contracts()}
        # the census is declared (single-stack GQA) and owned by the seam
        # that inserts the psums, not re-derived here
        for want in ("serve/tp-decode-collectives", "serve/disarmed-obs",
                     "serve/packed-dtype"):
            assert want in contracts, (want, sorted(contracts))
        assert contracts["serve/tp-decode-collectives"].owner \\
            == "repro.models.common"
        for c in contracts.values():
            findings = run_contract(c)
            assert not findings, (c.name, [str(f) for f in findings])
        # the declared census follows the plan: FFN replicates under online
        # R4, so the expected structural count is exactly 1
        from repro.models.common import expected_structural_tp_psums
        assert expected_structural_tp_psums(cfg, eng.tp_plan) \\
            == 1 + int(eng.tp_plan.ffn_sharded) == 1
        print("OK collectives contract")
    """)
    r = _run(code)
    assert "OK collectives contract" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_tp_mla_mixed_moe_parity():
    """MLA latent pages replicate; absorbed per-head projections and ragged
    expert stacks shard.  deepseek-v3 reduced = dense+MoE mixed stack."""
    code = PRELUDE + textwrap.dedent("""
        cfg = get_config("deepseek-v3-671b").reduced().replace(n_heads=8)
        params = M.init_params(cfg, key)
        one, _ = generate(cfg, params, None)
        tp, eng = generate(cfg, params, make_serve_mesh(8))
        assert one == tp, ("MLA mismatch", one, tp)
        plan = eng.tp_plan
        assert plan.ffn_sharded and plan.moe_sharded
        print("OK mla/moe parity; psums/token", plan.psums_per_token())
    """)
    r = _run(code)
    assert "OK mla/moe parity" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_tp_ssm_and_hybrid_parity():
    """SSM state replicates entirely (psums/token == 0); hybrid shards only
    the shared attention block."""
    code = PRELUDE + textwrap.dedent("""
        cfg = get_config("mamba2-370m").reduced()
        params = M.init_params(cfg, key)
        one, _ = generate(cfg, params, None, n=2, max_new=6)
        tp, eng = generate(cfg, params, make_serve_mesh(8), n=2, max_new=6)
        assert one == tp, ("ssm mismatch", one, tp)
        assert eng.tp_plan.psums_per_token() == 0

        cfg = get_config("zamba2-7b").reduced().replace(
            n_heads=8, n_kv_heads=8, head_dim=8)
        params = M.init_params(cfg, key)
        one, _ = generate(cfg, params, None, n=2, max_new=6)
        tp, eng = generate(cfg, params, make_serve_mesh(8), n=2, max_new=6)
        assert one == tp, ("hybrid mismatch", one, tp)
        print("OK ssm/hybrid parity")
    """)
    r = _run(code)
    assert "OK ssm/hybrid parity" in r.stdout, r.stdout + r.stderr


def test_tp_sharded_bytes_and_artifact_load():
    """Cache-byte conservation + shard-wise artifact load.

    Per-device page-pool bytes x tp must equal total pool bytes for sharded
    adapters; a packed artifact boots onto the mesh without any device
    holding a full projection weight, and every manifest tensor sits on a
    64-byte boundary (the contract that makes per-shard reads zero-waste).
    """
    code = PRELUDE + textwrap.dedent("""
        import tempfile
        from repro.artifacts import QuantArtifact, save_artifact, load_artifact
        from repro.artifacts.io import leaf_alignment
        from repro.quant import pack_params
        from repro.quant.quantizers import QTensor
        cfg = get_config("llama2-7b").reduced().replace(
            n_heads=8, n_kv_heads=8, head_dim=8)
        packed = pack_params(cfg, M.init_params(cfg, key))
        d = tempfile.mkdtemp()
        save_artifact(d, QuantArtifact(cfg=cfg, params=packed,
                                       rotations={}, meta={}))
        art = load_artifact(d)
        align = leaf_alignment(art.manifest)
        assert align and all(rem == 0 for _, _, rem in align.values()), align
        # host views (not jax.Arrays) must reach the engine for shard loads
        wo_art = art.params["layers"]["attn"]["wo"]
        assert isinstance(wo_art, QTensor)
        assert isinstance(wo_art.q, np.ndarray)

        _, eng = generate(cfg, art.params, make_serve_mesh(8), n=1, max_new=2,
                          kv_bits=4)
        # KV codes shard over heads: per-device bytes x tp == pool total
        total = eng.pool.nbytes
        per_dev = eng.pool.nbytes_per_device(eng.tp)
        assert per_dev < total and per_dev * eng.tp >= total, (per_dev, total)
        # no device holds a full row-sharded projection: every shard of the
        # packed wo payload carries 1/tp of the global bytes (the [out, 1]
        # per-channel scale replicates by design)
        wo = eng.params["layers"]["attn"]["wo"]
        for sh in wo.q.addressable_shards:
            assert sh.data.nbytes * eng.tp <= wo.q.nbytes, (sh.data.shape,
                                                            wo.q.shape)
        assert wo.scale.addressable_shards[0].data.shape == wo.scale.shape
        print("OK bytes+artifact")
    """)
    r = _run(code)
    assert "OK bytes+artifact" in r.stdout, r.stdout + r.stderr


def test_tp_plan_guards():
    """Indivisible head counts raise with an actionable message; tp=1 and
    meshless engines build no plan (host scheduler stays mesh-oblivious)."""
    code = PRELUDE + textwrap.dedent("""
        from repro.dist.sharding import serve_tp_plan, tp_degree
        cfg = get_config("llama2-7b").reduced()     # 4 heads: 8 won't divide
        params = M.init_params(cfg, key)
        mesh = make_serve_mesh(8)
        try:
            serve_tp_plan(cfg, params, mesh)
            raise SystemExit("expected ValueError for 4 heads on tp=8")
        except ValueError as e:
            assert "n_heads" in str(e), e
        assert tp_degree(None) == 1
        assert serve_tp_plan(cfg, params, None) is None
        _, eng = generate(cfg, params, None, n=1, max_new=2)
        assert eng.tp == 1 and eng.tp_plan is None
        print("OK guards")
    """)
    r = _run(code)
    assert "OK guards" in r.stdout, r.stdout + r.stderr
