"""Quantizer properties (hypothesis) + GPTQ behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # no network deps: seeded deterministic fallback
    from _hyp_compat import given, settings, st

from repro.quant import (dequant_act, fake_quant_act, fake_quant_kv,
                         fake_quant_weight, gptq_quantize, hessian, pack_int4,
                         quant_act, recon_error, rtn_quantize, unpack_int4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.integers(2, 16), st.integers(4, 64))
def test_act_quant_roundtrip_bound(seed, bits, rows, cols):
    """|x - QDQ(x)| <= scale/2 per element (asymmetric per-token affine)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * 5
    qt = quant_act(x, bits)
    deq = dequant_act(qt)
    err = jnp.abs(deq - x)
    assert bool(jnp.all(err <= qt.scale * 0.5 + 1e-5))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pack_unpack_int4_roundtrip(seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (6, 32), -8, 8,
                           dtype=jnp.int8)
    assert bool((unpack_int4(pack_int4(q)) == q).all())


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([3, 4, 8]))
def test_weight_quant_symmetric_bound(seed, bits):
    w = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
    dq = fake_quant_weight(w, bits=bits)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(w), axis=-1, keepdims=True) / qmax
    assert bool(jnp.all(jnp.abs(dq - w) <= scale * 0.5 + 1e-6))


def test_quant_monotone_in_bits(key):
    x = jax.random.laplace(key, (64, 128))
    errs = [float(jnp.mean((fake_quant_act(x, b) - x) ** 2))
            for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_gptq_beats_rtn(key):
    w = jax.random.normal(key, (32, 64))
    # anisotropic inputs: GPTQ's advantage comes from the Hessian
    scale = 1 + 9 * jax.random.uniform(jax.random.fold_in(key, 1), (1, 64))
    x = jax.random.normal(jax.random.fold_in(key, 2), (512, 64)) * scale
    h = hessian(x)
    wq, codes = gptq_quantize(w, h, bits=4)
    e_gptq = float(recon_error(w, wq, x))
    e_rtn = float(recon_error(w, rtn_quantize(w, 4), x))
    assert e_gptq < e_rtn
    assert codes.dtype == jnp.int8


def test_kv_quant_error_small(key):
    kv = jax.random.normal(key, (2, 8, 4, 32))
    for bits, tol in [(4, 0.2), (8, 0.02)]:
        d = fake_quant_kv(kv, bits)
        assert float(jnp.max(jnp.abs(d - kv))) < tol * float(jnp.max(jnp.abs(kv)))
