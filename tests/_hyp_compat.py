"""Deterministic fallback for ``hypothesis`` when it isn't installed.

Provides just the surface the suite uses — ``@settings``, ``@given``,
``st.integers``, ``st.sampled_from`` — running each property test over a
fixed number of seeded draws instead of hypothesis' adaptive search.  Install
the real thing with ``pip install -e '.[dev]'`` for shrinking and coverage.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def sample(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng):
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng):
        return rng.choice(self.options)


class _St:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        return _SampledFrom(options)


st = _St()


def settings(*_args, **kwargs):
    """Records max_examples on the wrapped function; other knobs ignored."""
    max_examples = kwargs.get("max_examples", _DEFAULT_EXAMPLES)

    def deco(f):
        f._max_examples = max_examples
        return f

    return deco


def given(*strategies):
    """Run the test over seeded draws. The wrapper takes no parameters so
    pytest does not mistake the drawn arguments for fixtures."""

    def deco(f):
        def wrapper():
            rng = random.Random(0xDA27)
            # cap draws: distinct shapes recompile jits; degraded mode favors
            # wall-clock over search depth
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES), 8)
            for _ in range(n):
                f(*(s.sample(rng) for s in strategies))

        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper._max_examples = getattr(f, "_max_examples",
                                        _DEFAULT_EXAMPLES)
        return wrapper

    return deco
