"""Per-kernel allclose vs pure-jnp oracles: shape x dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.act_quant.ops import act_quant
from repro.kernels.act_quant.ref import act_quant_ref
from repro.kernels.hadamard.ops import online_hadamard as wht_op
from repro.kernels.hadamard.ref import wht_ref
from repro.kernels.quant_matmul.ops import w4_matmul
from repro.kernels.quant_matmul.ref import w4_matmul_ref
from repro.kernels.whip_rotate.ops import whip_rotate
from repro.kernels.whip_rotate.ref import whip_rotate_grad_ref, whip_rotate_ref
from repro.quant.quantizers import QTensor, pack_int4, quant_weight


@pytest.mark.parametrize("n", [64, 128, 256, 112, 448, 2304])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wht_kernel_matches_ref(n, dtype, key):
    x = jax.random.normal(key, (32, n), dtype)
    out = wht_op(x)
    ref = wht_ref(x)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", [(16, 64), (128, 96), (64, 512), (3, 33)])
@pytest.mark.parametrize("bits", [4, 8])
def test_act_quant_kernel_matches_ref(shape, bits, key):
    x = jax.random.normal(key, shape) * 3
    q, s, z = act_quant(x, bits=bits)
    qr, sr, zr = act_quant_ref(x, bits)
    assert (np.asarray(q) == np.asarray(qr)).mean() > 0.999  # rounding ties
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (64, 128, 96), (128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w4_matmul_kernel_matches_ref(mkn, dtype, key):
    m, k, n = mkn
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    qt = quant_weight(w, bits=4)
    packed = QTensor(pack_int4(qt.q), qt.scale, None)
    out = w4_matmul(x, packed)
    ref = w4_matmul_ref(x, packed.q, packed.scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2)


@pytest.mark.parametrize("mn", [(256, 32), (1024, 64), (512, 96)])
def test_whip_rotate_value_and_grad(mn, key):
    m, n = mn
    x = jax.random.laplace(key, (m, n))
    r = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (n, n)))[0]
    np.testing.assert_allclose(float(whip_rotate(x, r)),
                               float(whip_rotate_ref(x, r)), rtol=1e-5)
    g = jax.grad(lambda rr: whip_rotate(x, rr))(r)
    g_ref = whip_rotate_grad_ref(x, r)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)


def test_whip_rotate_kernel_drives_calibration(key):
    """The fused Pallas whip_rotate is a drop-in objective for QR-Orth."""
    from repro.core.qr_orth import qr_rotation, sgd_update
    from repro.core.rotations import random_hadamard
    x = jax.random.laplace(key, (512, 64))
    z = random_hadamard(64, key)
    m = jnp.zeros_like(z)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda zz: whip_rotate(x, qr_rotation(zz))))
    losses = []
    for _ in range(6):
        l, g = loss_fn(z)
        losses.append(float(l))
        z, m = sgd_update(z, m, g, 0.1)
    assert losses[-1] < losses[0]
