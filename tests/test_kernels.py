"""Per-kernel allclose vs pure-jnp oracles: shape x dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.act_quant.ops import act_quant
from repro.kernels.act_quant.ref import act_quant_ref
from repro.kernels.hadamard.ops import online_hadamard as wht_op
from repro.kernels.hadamard.ref import wht_ref
from repro.kernels.paged_attn.ops import paged_attention
from repro.kernels.paged_attn.ref import paged_attention_ref
from repro.kernels.quant_matmul.ops import quant_matmul, w4_matmul
from repro.kernels.quant_matmul.ref import quant_matmul_ref, w4_matmul_ref
from repro.kernels.whip_rotate.ops import whip_rotate
from repro.kernels.whip_rotate.ref import whip_rotate_grad_ref, whip_rotate_ref
from repro.quant.kv_cache import quantize_kv
from repro.quant.quantizers import QTensor, pack_int4, quant_weight


@pytest.mark.parametrize("n", [64, 128, 256, 112, 448, 2304])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wht_kernel_matches_ref(n, dtype, key):
    x = jax.random.normal(key, (32, n), dtype)
    out = wht_op(x)
    ref = wht_ref(x)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n", [64, 256])
def test_wht_kernel_matches_online_hadamard(n, key):
    """Serve-path parity: the Pallas WHT op == core.rotations.online_hadamard
    (the dense-matmul R3/R4 reference the serve driver used to import)."""
    from repro.core.rotations import online_hadamard as dense_op
    x = jax.random.normal(key, (4, 8, 2, n), jnp.float32)
    np.testing.assert_allclose(np.asarray(wht_op(x)),
                               np.asarray(dense_op(x)), atol=5e-5, rtol=5e-5)


def _quant_pool(key, P, T, H, hd, bits):
    k = jax.random.normal(key, (P, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 7), (P, T, H, hd))
    qk, qv = quantize_kv(k, bits), quantize_kv(v, bits)
    return {"kq": qk.q, "ks": qk.scale[..., 0], "kz": qk.zero[..., 0],
            "vq": qv.q, "vs": qv.scale[..., 0], "vz": qv.zero[..., 0]}


@pytest.mark.parametrize("bits,hd", [(4, 16), (4, 13), (8, 16)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (5, 0.0), (0, 30.0)])
def test_paged_attn_kernel_matches_ref(bits, hd, window, cap, key):
    """Pallas paged attention (scalar-prefetch block-table gather + fused
    int4 dequant + online softmax) vs the dense-gather oracle; lengths
    include partial pages, full capacity, and an empty (idle) slot."""
    P, T, H, G = 9, 4, 2, 3
    B, Pmax = 4, 5
    pool = _quant_pool(key, P, T, H, hd, bits)
    rng = np.random.default_rng(3)
    bt = jnp.asarray(rng.integers(1, P, (B, Pmax)), jnp.int32)
    lengths = jnp.asarray([7, 20, 1, 0], jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H * G, hd))
    out = paged_attention(q, pool, bt, lengths, bits=bits, window=window,
                          logit_cap=cap)
    ref = paged_attention_ref(q, pool, bt, lengths, bits=bits, window=window,
                              logit_cap=cap)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-5)


def test_paged_attn_matches_dense_decode(key):
    """Paged attention over int4 pages == the dense decode path
    (decode_attn_scores) over the same dequantized cache, within f32 noise."""
    from repro.kernels.paged_attn.ref import gather_pages
    from repro.models.attention import decode_attn_scores
    P, T, H, hd, G, B, Pmax = 9, 4, 2, 16, 2, 2, 4
    pool = _quant_pool(key, P, T, H, hd, 4)
    rng = np.random.default_rng(0)
    bt = jnp.asarray(rng.integers(1, P, (B, Pmax)), jnp.int32)
    lengths = jnp.asarray([9, 14], jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H * G, hd))
    out = paged_attention(q, pool, bt, lengths, bits=4)
    k, v = gather_pages(pool, bt, bits=4, head_dim=hd)
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    dense = decode_attn_scores(q, k, v, k_pos, (lengths - 1)[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-6, rtol=2e-5)


def _latent_pool(key, P, T, kvlr, rope, bits):
    ckv = jax.random.normal(key, (P, T, kvlr))
    kr = jax.random.normal(jax.random.fold_in(key, 9), (P, T, rope))
    qc, qr = quantize_kv(ckv, bits), quantize_kv(kr, bits)
    return {"cq": qc.q, "cs": qc.scale[..., 0], "cz": qc.zero[..., 0],
            "rq": qr.q, "rs": qr.scale[..., 0], "rz": qr.zero[..., 0]}


@pytest.mark.parametrize("bits,kvlr,rope", [(4, 32, 8), (4, 33, 7), (8, 32, 8)])
def test_paged_mla_kernel_matches_ref(bits, kvlr, rope, key):
    """Pallas paged MLA attention (latent pages dequantized in VMEM, values =
    the latent rows) vs the dense-gather oracle; lengths include partial
    pages, full capacity and an empty (idle) slot."""
    from repro.kernels.paged_attn.ops import paged_mla_attention
    from repro.kernels.paged_attn.ref import paged_mla_attention_ref
    P, T, h, B, Pmax = 9, 4, 5, 4, 5
    pool = _latent_pool(key, P, T, kvlr, rope, bits)
    rng = np.random.default_rng(3)
    bt = jnp.asarray(rng.integers(1, P, (B, Pmax)), jnp.int32)
    lengths = jnp.asarray([7, 20, 1, 0], jnp.int32)
    ql = jax.random.normal(jax.random.fold_in(key, 1), (B, h, kvlr))
    qr = jax.random.normal(jax.random.fold_in(key, 2), (B, h, rope))
    scale = 1.0 / np.sqrt(24)       # the model's 1/sqrt(nope+rope) scale is
    out = paged_mla_attention(ql, qr, pool, bt, lengths, bits=bits,
                              scale=scale)                # not shape-derivable
    ref = paged_mla_attention_ref(ql, qr, pool, bt, lengths, bits=bits,
                                  scale=scale)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=2e-5)


def test_paged_fp16_pages_match_quantfree_decode(key):
    """bits=16 pages (the compat layout) attend through the dense-gather
    path and agree with decode_attn_scores on the raw fp16 values."""
    from repro.kernels.paged_attn.ref import gather_pages
    from repro.models.attention import decode_attn_scores
    P, T, H, hd, G, B, Pmax = 7, 4, 2, 16, 2, 2, 3
    k = jax.random.normal(key, (P, T, H, hd)).astype(jnp.float16)
    v = jax.random.normal(jax.random.fold_in(key, 7),
                          (P, T, H, hd)).astype(jnp.float16)
    pool = {"k": k, "v": v}
    rng = np.random.default_rng(1)
    bt = jnp.asarray(rng.integers(1, P, (B, Pmax)), jnp.int32)
    lengths = jnp.asarray([5, 11], jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, H * G, hd))
    out = paged_attention(q, pool, bt, lengths, bits=16)
    kd, vd = gather_pages(pool, bt, bits=16, head_dim=hd)
    k_pos = jnp.arange(kd.shape[1], dtype=jnp.int32)
    dense = decode_attn_scores(q, kd, vd, k_pos, (lengths - 1)[:, None])
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               atol=2e-6, rtol=2e-5)


@pytest.mark.parametrize("shape", [(16, 64), (128, 96), (64, 512), (3, 33)])
@pytest.mark.parametrize("bits", [4, 8])
def test_act_quant_kernel_matches_ref(shape, bits, key):
    x = jax.random.normal(key, shape) * 3
    q, s, z = act_quant(x, bits=bits)
    qr, sr, zr = act_quant_ref(x, bits)
    assert (np.asarray(q) == np.asarray(qr)).mean() > 0.999  # rounding ties
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6)


@pytest.mark.parametrize("mkn", [(16, 64, 32), (64, 128, 96), (128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_w4_matmul_kernel_matches_ref(mkn, dtype, key):
    m, k, n = mkn
    x = jax.random.normal(key, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    qt = quant_weight(w, bits=4)
    packed = QTensor(pack_int4(qt.q), qt.scale, None, bits=4, packed=True)
    out = w4_matmul(x, packed)
    ref = w4_matmul_ref(x, packed.q, packed.scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("group", [-1, 16])
@pytest.mark.parametrize("k", [64, 33])
def test_quant_matmul_kernel_qlinear_dense_parity(bits, group, k, key):
    """The Pallas quant_matmul, the jnp qlinear_matmul fallback, and the
    pure-jnp oracle agree exactly on the same packed QTensor (group and
    per-channel scales, int4 and int8, odd in-features via code padding) —
    and all track the dense fp matmul within quantization noise."""
    from repro.quant.qlinear import pack_weight, qlinear_matmul
    x = jax.random.normal(key, (8, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, k))
    qt = pack_weight(w, bits=bits, group=group)
    assert qt.in_features == k
    out = quant_matmul(x, qt)
    ref = quant_matmul_ref(x, qt)
    fb = qlinear_matmul(x, qt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fb),
                               atol=1e-5, rtol=1e-5)
    dense = np.asarray(x @ w.T.astype(jnp.float32))
    err = np.abs(np.asarray(out) - dense).max() / np.abs(dense).max()
    assert err < (0.2 if bits == 4 else 0.02)


@pytest.mark.parametrize("mn", [(256, 32), (1024, 64), (512, 96)])
def test_whip_rotate_value_and_grad(mn, key):
    m, n = mn
    x = jax.random.laplace(key, (m, n))
    r = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (n, n)))[0]
    np.testing.assert_allclose(float(whip_rotate(x, r)),
                               float(whip_rotate_ref(x, r)), rtol=1e-5)
    g = jax.grad(lambda rr: whip_rotate(x, rr))(r)
    g_ref = whip_rotate_grad_ref(x, r)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)


def test_whip_rotate_kernel_drives_calibration(key):
    """The fused Pallas whip_rotate is a drop-in objective for QR-Orth."""
    from repro.core.qr_orth import qr_rotation, sgd_update
    from repro.core.rotations import random_hadamard
    x = jax.random.laplace(key, (512, 64))
    z = random_hadamard(64, key)
    m = jnp.zeros_like(z)
    loss_fn = jax.jit(jax.value_and_grad(
        lambda zz: whip_rotate(x, qr_rotation(zz))))
    losses = []
    for _ in range(6):
        l, g = loss_fn(z)
        losses.append(float(l))
        z, m = sgd_update(z, m, g, 0.1)
    assert losses[-1] < losses[0]
