"""Whip loss + QR-Orth properties (hypothesis where it matters)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:         # no network deps: seeded deterministic fallback
    from _hyp_compat import given, settings, st

from repro.core import (calibrate_rotation, outlier_count, quant_error,
                        random_hadamard, whip)
from repro.core.qr_orth import (calibrate_cayley, cayley_sgd_step,
                                orthogonality_error, qr_rotation)
from repro.core.whip import OBJECTIVES, kurtosis, variance


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 24), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_qr_rotation_orthogonal(n, _m, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (n, n))
    r = qr_rotation(z)
    assert float(orthogonality_error(r)) < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_whip_invariance_properties(seed):
    """Whip is permutation-invariant and decreases as values move from 0."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (16, 32))
    perm = jax.random.permutation(k, 32)
    assert np.isclose(float(whip(x)), float(whip(x[:, perm])), rtol=1e-5)
    assert float(whip(x * 2.0)) < float(whip(x))       # pushing away from zero
    assert float(whip(jnp.zeros_like(x))) == pytest.approx(32.0)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_variance_rotation_invariant_for_centered(seed):
    """Paper §4.1: per-token variance ~ invariant under rotation (norm
    preservation) for zero-mean tokens — the reason variance is a bad
    objective."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (32, 64))
    x = x - x.mean(axis=-1, keepdims=True)
    r = qr_rotation(jax.random.normal(jax.random.fold_in(k, 1), (64, 64)))
    xr = x @ r
    xr = xr - xr.mean(axis=-1, keepdims=True)
    v0, v1 = float(variance(x)), float(variance(xr))
    assert np.isclose(v0, v1, rtol=0.05)


def test_cayley_step_stays_orthogonal(key):
    r = qr_rotation(jax.random.normal(key, (32, 32)))
    m = jnp.zeros_like(r)
    g = jax.random.normal(jax.random.fold_in(key, 1), (32, 32)) * 0.01
    for _ in range(5):
        r, m = cayley_sgd_step(r, m, g, lr=0.01)
    assert float(orthogonality_error(r)) < 1e-2


def _toy_data(key, n=64, N=1024):
    x = jax.random.laplace(key, (N, n)) * 0.5
    oc = jax.random.choice(jax.random.fold_in(key, 1), n, (4,), replace=False)
    x = x.at[:, oc].multiply(10.0)
    return x / jnp.std(x)


def test_whip_calibration_improves_quant_error(key):
    x = _toy_data(key)
    base = float(quant_error(x))
    had = float(quant_error(x @ random_hadamard(64, key)))
    r = calibrate_rotation(x, 64, key, objective="whip", steps=60, lr=0.2)
    calib = float(quant_error(x @ r))
    assert had < base          # rotation beats identity (Fig. 3)
    assert calib <= had * 1.02  # calibration >= Hadamard (Fig. 6)
    assert float(orthogonality_error(r)) < 1e-4


def test_qr_orth_matches_cayley_objective(key):
    """Same Whip objective: QR-Orth reaches a loss <= Cayley's (Fig. 7b)."""
    x = _toy_data(key)
    r_qr = calibrate_rotation(x, 64, key, objective="whip", method="qr",
                              steps=40, lr=0.2)
    r_cy = calibrate_rotation(x, 64, key, objective="whip", method="cayley",
                              steps=40, lr=0.2)
    assert float(whip(x @ r_qr)) <= float(whip(x @ r_cy)) * 1.05
