"""Training infrastructure: loss decreases, checkpoint exact-resume,
fault-tolerant restart, straggler detection, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.collectives import compress_grad
from repro.train.checkpoint import latest_step, restore, save
from repro.train.fault import FaultTolerantLoop, StragglerMonitor
from repro.train.trainer import Trainer


TINY = get_config("llama2-7b").reduced().replace(n_layers=2, d_model=32,
                                                 d_ff=64, n_heads=2,
                                                 n_kv_heads=2, head_dim=16,
                                                 vocab_size=128)


def test_training_reduces_loss(tmp_path):
    tr = Trainer(TINY, batch_size=8, seq_len=32, lr=1e-2)
    hist = tr.train(60, verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_checkpoint_save_restore_exact(tmp_path, key):
    from repro.models import model as M
    params = M.init_params(TINY, key)
    save(tmp_path, 7, params)
    assert latest_step(tmp_path) == 7
    restored = restore(tmp_path, 7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resharding_roundtrip(tmp_path, key):
    """Restore onto explicit shardings (elastic-rescale path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import model as M
    params = M.init_params(TINY, key)
    save(tmp_path, 1, params)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored = restore(tmp_path, 1, params, shardings=sh)
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(params)[0]),
                                  np.asarray(jax.tree.leaves(restored)[0]))


def test_fault_tolerant_restart(tmp_path):
    """Inject a fault mid-training; the loop restores and converges anyway."""
    tr = Trainer(TINY, batch_size=4, seq_len=32, lr=5e-3,
                 ckpt_dir=str(tmp_path), ckpt_every=10)
    boom = {"armed": True}

    def faulty_step(state, batch):
        if tr.step >= 15 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device failure")
        return tr._one_step(state, batch)

    hist = tr.train(30, fault_hook=faulty_step, verbose=False)
    assert hist[-1]["step"] >= 30


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 0.5)          # 5x EMA -> straggler
    assert len(mon.events) == 1
    assert not mon.observe(11, 0.11)


def test_grad_compression_error_feedback(key):
    g = jax.random.normal(key, (64, 64))
    err = jnp.zeros_like(g)
    # accumulated dequantized payload + error feedback reconstructs g
    q, scale, new_err = compress_grad(g, err)
    deq = q.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(deq + new_err), np.asarray(g),
                               atol=1e-5)
    # compression is ~4x (int8 payload vs f32)
    assert q.dtype == jnp.int8


def test_trainer_grad_accum_matches_single_batch():
    """grad_accum=2 over the same data gives a loss in the same ballpark and
    runs; exact equality isn't expected (loss averaging order)."""
    tr1 = Trainer(TINY, batch_size=8, seq_len=32, lr=5e-3, grad_accum=2)
    hist = tr1.train(10, verbose=False)
    assert np.isfinite(hist[-1]["loss"])
