"""Per-request sampling: top-p + repetition-penalty semantics and
deterministic replay.

The serve sampler chain is rep-penalty -> top-k -> top-p -> temperature
softmax, keyed by the request seed folded with the absolute position.  The
replay contract: a preempted request re-admitted later rebuilds the same
history and keys, hence the same tokens — so a contended run (preemptions)
must produce bit-identical outputs to an uncontended one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request
from repro.serve.engine import MAX_REP_HISTORY, _build_sampler

VOCAB = 64


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _sample(lg, *, t=1.0, k=0, p=1.0, rp=1.0, hist=None, pos=3, seed=7):
    """Drive _build_sampler with a single slot."""
    fn = _build_sampler(VOCAB)
    h = np.full((1, MAX_REP_HISTORY), VOCAB, np.int32)
    if hist is not None:
        h[0, :len(hist)] = hist
    out = fn(jnp.asarray(lg, jnp.float32).reshape(1, 1, VOCAB),
             jnp.asarray([t], jnp.float32), jnp.asarray([k], jnp.int32),
             jnp.asarray([p], jnp.float32), jnp.asarray([rp], jnp.float32),
             jnp.asarray(h), jax.random.PRNGKey(seed)[None],
             jnp.asarray([pos], jnp.int32))
    return int(out[0])


def test_defaults_are_bit_identical_to_plain_temperature_sampling():
    """top_p=1.0 / rep_penalty=1.0 are exact no-ops: the sampled token
    equals a direct categorical over logits/t with the same folded key."""
    lg = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (VOCAB,))) * 3
    for seed in range(8):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), np.uint32(5))
        ref = int(jax.random.categorical(key, jnp.asarray(lg) / 0.8))
        got = _sample(lg, t=0.8, p=1.0, rp=1.0,
                      hist=[1, 2, 3], pos=5, seed=seed)
        assert got == ref


def test_top_p_tiny_is_greedy():
    """p -> 0 keeps only the top token (its exclusive prefix mass is 0)."""
    lg = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (VOCAB,)))
    top = int(np.argmax(lg))
    for seed in range(8):
        assert _sample(lg, t=1.5, p=1e-6, seed=seed) == top


def test_rep_penalty_suppresses_seen_tokens():
    """A huge CTRL penalty pushes history tokens out of a peaked
    distribution; rp=1.0 leaves them untouched."""
    lg = np.full((VOCAB,), -4.0, np.float32)
    lg[5] = 10.0
    lg[9] = 8.0
    assert _sample(lg, t=0.1, rp=1.0, hist=[5]) == 5
    assert _sample(lg, t=0.1, rp=1e4, hist=[5]) == 9   # 5 damped to ~0
    assert _sample(lg, t=0.1, rp=1e4, hist=[9]) == 5   # only seen ids damped


def test_greedy_rows_ignore_sampling_params():
    """temperature=0 rows stay the argmax oracle regardless of top-p/rep
    settings — the parity tests' contract."""
    lg = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (VOCAB,)))
    top = int(np.argmax(lg))
    assert _sample(lg, t=0.0, p=0.1, rp=100.0, hist=[top]) == top


def test_sampled_generation_deterministic_across_runs(cfg, params):
    """Same seeds -> same tokens across two engine instances."""
    def run():
        rng = np.random.default_rng(4)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 10),
                        max_new=6, temperature=0.9, top_k=8, top_p=0.85,
                        rep_penalty=1.4, seed=100 + i) for i in range(3)]
        eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                               page_size=8, kv_bits=8)
        eng.generate(reqs)
        return [list(r.out) for r in reqs]
    a, b = run(), run()
    assert a == b
    assert any(out for out in a)


def test_sampled_replay_across_preemption(cfg, params):
    """Contended pool (preemptions) vs uncontended: bit-identical outputs.

    Preemption requeues the request with its pinned seed and cleared
    output; replay rebuilds the same rep-penalty history and per-position
    keys, so the final tokens cannot depend on scheduling."""
    def reqs():
        rng = np.random.default_rng(5)
        return [Request(prompt=rng.integers(0, cfg.vocab_size, 20),
                        max_new=8, temperature=0.8, top_p=0.9,
                        rep_penalty=1.3, seed=50 + i) for i in range(4)]

    calm = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                            page_size=8, kv_bits=4)
    calm_reqs, calm_stats = calm.generate(reqs())
    tight = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                             page_size=8, kv_bits=4, num_pages=7)
    tight_reqs, tight_stats = tight.generate(reqs())
    assert tight_stats["preemptions"] >= 1, tight_stats
    assert calm_stats["preemptions"] == 0
    assert [r.out for r in tight_reqs] == [r.out for r in calm_reqs]
