"""Prefix cache, copy-on-write pages, on-demand growth and preemption.

The two acceptance properties: (1) sharing is invisible — N requests with a
common prompt prefix produce token-for-token the outputs of the same
requests served with the index disabled, while hitting the cache and CoW-ing
the boundary page; (2) refcount conservation — free + owned + shared pages
always partition the pool under random admit/grow/share/free interleavings.
Plus the scheduler correctness fixes that ride along: max_new validation,
stale-Request rejection, and the growth-stall deadlock guard.
"""
import sys

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                     # degraded-env fallback
    sys.path.insert(0, "tests")
    from _hyp_compat import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serve import (PagedServeEngine, PagePool, PrefixIndex, Request,
                         TokenScheduler)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


def _shared_requests(cfg, n, sp_len, suf_len, max_new, seed=7):
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, sp_len)
    return [Request(prompt=np.concatenate(
                        [sys_prompt, rng.integers(0, cfg.vocab_size, suf_len)]),
                    max_new=max_new) for _ in range(n)]


# --------------------------------------------------------------------------- #
# PrefixIndex: trie matching, registration, subtree eviction
# --------------------------------------------------------------------------- #
def test_prefix_index_match_register_evict():
    idx = PrefixIndex(4)
    # page 7 holds the full chunk (1,2,3,4); page 8 the partial tail (5,6)
    assert idx.register([1, 2, 3, 4, 5, 6], [7, 8], 6) == 2
    assert idx.match([1, 2, 3, 4, 5, 6, 9]) == ([7, 8], 6)
    assert idx.match([1, 2, 3, 4, 9]) == ([7], 4)
    # partial common prefix against a full node: usable up to the divergence
    assert idx.match([1, 2, 9, 9, 9]) == ([7], 2)
    assert idx.match([9, 9]) == ([], 0)
    # re-registering the same content dedupes (first registrant stays)
    assert idx.register([1, 2, 3, 4], [11], 4) == 0
    assert idx.match([1, 2, 3, 4]) == ([7], 4)
    # a longer partial tail coexists with the shorter one (full page dedupes)
    assert idx.register([1, 2, 3, 4, 5, 6, 7], [7, 9], 7) == 1
    assert idx.match([1, 2, 3, 4, 5, 6, 7, 8])[1] == 7
    # evicting the root page drops its whole subtree
    dropped = idx.remove(7)
    assert sorted(dropped) == [7, 8, 9]
    assert idx.match([1, 2, 3, 4]) == ([], 0)
    assert len(idx) == 0


def test_pool_admission_shares_and_cows(cfg):
    pool = PagePool(cfg, num_pages=10, page_size=4, max_seq=32, kv_bits=4,
                    prefix_cache=True)
    a = np.arange(10)                           # 3 pages: 4 + 4 + 2
    cached, cow = pool.admit_seq(0, a)
    assert (cached, cow) == (0, [])             # cold: nothing to share
    pool.register_prefix(0, a)
    pages_a = list(pool._owned[0])
    # same 10 tokens + divergent suffix: 2 full pages shared, tail CoW'd
    b = np.concatenate([a, [99, 98]])
    cached, cow = pool.admit_seq(1, b)
    assert cached == 10
    assert cow == [(pages_a[2], pool._owned[1][2])]
    assert pool._owned[1][:2] == pages_a[:2]    # read-only mapping
    assert pool.shared_pages == 2 and pool.cow_copies == 1
    # identical prompt: usable capped at len-1 (tail logits must be computed)
    cached, _ = pool.admit_seq(2, np.array(a))
    assert cached == 9
    pool.free_seq(0), pool.free_seq(1), pool.free_seq(2)
    # unreferenced-but-indexed pages park as cached-free, still allocatable
    assert pool.free_pages == 9 and len(pool._cached_free) > 0
    with pytest.raises(KeyError):
        pool.free_seq(0)                        # double free still raises


# --------------------------------------------------------------------------- #
# Property: refcount conservation under random interleavings
# --------------------------------------------------------------------------- #
def _check_conservation(pool):
    from collections import Counter
    assert (len(pool._free) + len(pool._cached_free) + len(pool._ref)
            == pool.num_pages - 1)
    assert (pool.free_pages + pool.owned_pages + pool.shared_pages
            == pool.num_pages - 1)
    # refcounts mirror the owner map exactly, and no page sits in two states
    counts = Counter(p for pages in pool._owned.values() for p in pages)
    assert dict(counts) == pool._ref
    free, cached = set(pool._free), set(pool._cached_free)
    assert not (free & cached) and not ((free | cached) & set(pool._ref))
    assert 0 not in free | cached | set(pool._ref)      # null page untouched


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_pool_refcount_conservation(seed):
    """free + owned + shared == num_pages - 1 after every admit / grow /
    CoW / preempt / finish, with prompts drawn from a tiny vocab so shared
    prefixes (and thus refcount bumps + CoW) occur constantly."""
    import random
    rng = random.Random(seed)
    cfg = get_config("llama2-7b").reduced()
    pool = PagePool(cfg, num_pages=8, page_size=4, max_seq=16, kv_bits=4,
                    prefix_cache=True)
    active = {}
    next_id = 0
    for _ in range(60):
        op = rng.choice(["admit", "admit", "grow", "free"])
        if op == "admit":
            prompt = [rng.randrange(3) for _ in range(rng.randint(1, 12))]
            res = pool.admit_seq(next_id, prompt)
            if res is not None:
                active[next_id] = prompt
                if rng.random() < 0.7:
                    pool.register_prefix(next_id, prompt)
                next_id += 1
        elif op == "grow" and active:
            sid = rng.choice(list(active))
            if pool.seq_page_count(sid) < pool.max_pages_per_seq:
                pool.grow_seq(sid)              # False (exhausted) is fine
        elif op == "free" and active:
            sid = rng.choice(list(active))
            pool.free_seq(sid)
            del active[sid]
        _check_conservation(pool)
    for sid in list(active):
        pool.free_seq(sid)
    _check_conservation(pool)
    assert pool.free_pages == pool.num_pages - 1
    with pytest.raises((KeyError, ValueError)):
        pool.free_seq(-1)


# --------------------------------------------------------------------------- #
# Scheduler satellite fixes: max_new validation + stale-Request rejection
# --------------------------------------------------------------------------- #
def test_add_rejects_max_new_zero_and_stale_requests(cfg):
    pool = PagePool(cfg, num_pages=4, page_size=4, max_seq=16, kv_bits=4)
    sched = TokenScheduler(pool, slots=1)
    with pytest.raises(ValueError, match="max_new"):
        sched.add([Request(prompt=np.arange(4), max_new=0)])
    with pytest.raises(ValueError, match="max_new"):
        sched.add([Request(prompt=np.arange(4), max_new=-3)])
    done_req = Request(prompt=np.arange(4), max_new=2, done=True)
    with pytest.raises(ValueError, match="already served"):
        sched.add([done_req])
    stale = Request(prompt=np.arange(4), max_new=2, out=[5, 6])
    with pytest.raises(ValueError, match="already served"):
        sched.add([stale])
    assert not sched.waiting                    # nothing half-enqueued
    sched.add([Request(prompt=np.arange(4), max_new=1)])   # boundary: valid
    assert len(sched.waiting) == 1


# --------------------------------------------------------------------------- #
# Shared-prefix parity: sharing is an optimization, never a behaviour change
# --------------------------------------------------------------------------- #
def test_shared_prefix_parity_token_for_token(cfg, params):
    """5 requests sharing an 18-token system prompt over 2 slots: outputs
    must equal the prefix-cache-off run exactly, with a nonzero hit rate, at
    least one CoW copy (the prefix ends mid-page) and fewer prefilled
    tokens."""
    kw = dict(batch_slots=2, max_seq=32, page_size=8, kv_bits=4)
    mk = lambda: _shared_requests(cfg, 5, sp_len=18, suf_len=3, max_new=6)
    off = PagedServeEngine(cfg, params, prefix_cache=False, **kw)
    off_reqs, off_stats = off.generate(mk())
    on = PagedServeEngine(cfg, params, prefix_cache=True, **kw)
    on_reqs, on_stats = on.generate(mk())
    assert all(r.done for r in on_reqs)
    for i, (r_on, r_off) in enumerate(zip(on_reqs, off_reqs)):
        assert r_on.out == r_off.out, f"request {i} diverged under sharing"
    assert on_stats["prefix_hit_rate"] > 0
    assert on_stats["cow_copies"] >= 1
    assert on_stats["prefill_tokens"] < off_stats["prefill_tokens"]
    assert off_stats["prefix_hit_tokens"] == 0  # the baseline really is off


def test_prefix_cache_disabled_for_recurrent_state():
    """SSM/hybrid families must not skip prefill (slot state is recomputed
    from the full prompt): the index stays off even when requested."""
    for arch in ("mamba2-370m", "zamba2-7b"):
        pool = PagePool(get_config(arch).reduced(), num_pages=4, page_size=4,
                        max_seq=16, n_slots=2, prefix_cache=True)
        assert pool.prefix is None


# --------------------------------------------------------------------------- #
# On-demand growth: preemption-with-requeue + the growth-stall guard
# --------------------------------------------------------------------------- #
def test_preemption_requeue_completes_overcommitted_workload(cfg, params):
    """Pool sized to one full prompt + a CoW page + one growth page: two
    slots cannot both grow, so the younger sequence is preempted, requeued
    and replayed — and every output still matches a roomy no-sharing run.
    Reserve-at-admission could never run these two concurrently at all."""
    sp_len, suf_len, max_new, page = 20, 4, 8, 8
    mk = lambda: _shared_requests(cfg, 4, sp_len, suf_len, max_new, seed=11)
    roomy = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                             page_size=page, kv_bits=4, prefix_cache=False)
    ref_reqs, _ = roomy.generate(mk())
    num_pages = -(-(sp_len + suf_len) // page) + 3          # 5 usable
    tight = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                             page_size=page, kv_bits=4, prefix_cache=True,
                             num_pages=num_pages)
    # over-committed by reserve-at-admission standards: two concurrent
    # full reservations can never fit this pool
    full = tight.pool.pages_for(sp_len + suf_len + max_new)
    assert 2 * full > num_pages - 1
    reqs, stats = tight.generate(mk())
    assert all(r.done for r in reqs)
    assert stats["preemptions"] >= 1
    for i, (r, ref) in enumerate(zip(reqs, ref_reqs)):
        assert r.out == ref.out, f"request {i} diverged after preemption"


def test_growth_stall_raises_not_deadlocks(cfg, params):
    """A lone mid-decode sequence that crosses a page boundary with zero
    free pages has no preemptible victim: loud MemoryError (the extended
    check_progress guard), not an infinite decode loop."""
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=8, num_pages=3, kv_bits=4)
    reqs = [Request(prompt=np.arange(8) % cfg.vocab_size, max_new=24)]
    with pytest.raises(MemoryError, match="growth stall"):
        eng.generate(reqs)
