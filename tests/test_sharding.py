"""Distribution tests — run in a subprocess with 8 placeholder devices so the
main test process keeps a single CPU device."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str):
    # JAX_PLATFORMS must survive into the subprocess: images that ship libtpu
    # hang for minutes probing for TPU hardware otherwise.
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/root")},
        timeout=560)


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


def test_sharded_train_step_matches_single_device():
    code = PRELUDE + textwrap.dedent("""
        from repro.configs import get_config
        from repro.dist.sharding import Sharding
        from repro.models import model as M
        from repro.train import steps as S
        from repro.train.optimizer import init_opt_state, OptState

        cfg = get_config("llama2-7b").reduced().replace(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
            d_ff=128, vocab_size=512)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        opt = init_opt_state(cfg, params)
        toks = jax.random.randint(key, (8, 33), 0, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

        # single device
        s0 = jax.jit(S.build_train_step(cfg))
        p1, o1, m1 = s0(params, opt, batch)

        # sharded
        shd = Sharding(cfg, mesh)
        psh = shd.named(shd.param_specs(params))
        osh = OptState(NamedSharding(mesh, P()), psh, psh)
        bsh = shd.named(shd.batch_specs(batch))
        with mesh:
            sf = jax.jit(S.build_train_step(cfg, mesh=mesh, shd=shd),
                         in_shardings=(psh, osh, bsh))
            p2, o2, m2 = sf(params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \\
            (float(m1["loss"]), float(m2["loss"]))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-3, d
        print("OK sharded==single", d)
    """)
    r = _run(code)
    assert "OK sharded==single" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_moe_ep_shard_map_matches_local():
    code = PRELUDE + textwrap.dedent("""
        from repro.configs import get_config
        from repro.models import model as M, ffn as F
        cfg = get_config("deepseek-v3-671b").reduced().replace(
            moe_impl="ragged", n_experts=8, moe_top_k=2)
        p = M.init_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda a: a[0], p["moe_layers"])["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
        with mesh:
            y, _ = jax.jit(lambda xx: F.moe_ragged_ep(cfg, lp, xx, mesh,
                                                      dp_axes=("data",)))(x)
        y2, _ = F.moe_ragged_local(cfg, lp, x)
        d = float(jnp.max(jnp.abs(y - y2)))
        assert d < 1e-4, d
        print("OK ep==local", d)
    """)
    r = _run(code)
    assert "OK ep==local" in r.stdout, r.stdout + r.stderr


def test_compressed_grad_allreduce():
    code = PRELUDE + textwrap.dedent("""
        from repro.dist.collectives import all_reduce_compressed_tree, \\
            init_error_feedback
        g = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4) / 7.0}
        errs = init_error_feedback(g)
        out, errs = all_reduce_compressed_tree(g, errs, mesh, axis="data")
        # all shards had identical grads -> average == original (to int8 tol)
        d = float(jnp.max(jnp.abs(out["w"] - g["w"])))
        assert d < 0.05, d
        print("OK compressed allreduce", d)
    """)
    r = _run(code)
    assert "OK compressed allreduce" in r.stdout, r.stdout + r.stderr


def test_compressed_grad_allreduce_sharded():
    code = PRELUDE + textwrap.dedent("""
        from repro.dist.collectives import all_reduce_compressed_tree
        # per-shard DISTINCT gradients: leading axis = shard index
        k = 2   # mesh data axis size
        g = {"w": jnp.stack([jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
                             * (i + 1) / 7.0 for i in range(k)])}
        e = {"w": jnp.zeros_like(g["w"])}
        out, errs = all_reduce_compressed_tree(g, e, mesh, axis="data",
                                               sharded=True)
        want = jnp.mean(g["w"], axis=0)      # true mean of per-shard grads
        d = float(jnp.max(jnp.abs(out["w"] - want)))
        assert out["w"].shape == (8, 4), out["w"].shape
        assert d < 0.05, d
        # error feedback keeps the per-shard leading axis (stays local)
        assert errs["w"].shape == g["w"].shape
        print("OK sharded compressed allreduce", d)
    """)
    r = _run(code)
    assert "OK sharded compressed allreduce" in r.stdout, r.stdout + r.stderr


def test_production_mesh_shapes():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
print("OK meshes")
"""
    r = _run(code)
    assert "OK meshes" in r.stdout, r.stdout + r.stderr
