"""Open-loop load generator: deterministic workloads, goodput math, serving
parity between open-loop admission and batch generate."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.obs import MetricsRegistry
from repro.serve import LoadSpec, PagedServeEngine, Request, SLO
from repro.serve.loadgen import (build_workload, goodput_report,
                                 publish_goodput, run_workload)


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama2-7b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


# --------------------------------------------------------------------------- #
# workload construction
# --------------------------------------------------------------------------- #
def test_workload_deterministic_under_seed():
    spec = LoadSpec(n_requests=12, rate_rps=20.0, prompt_len=(4, 10),
                    max_new=(2, 6), shared_prefix_len=5, shared_frac=0.5,
                    seed=3)
    a = build_workload(spec, vocab_size=101)
    b = build_workload(spec, vocab_size=101)
    assert [t for t, _ in a] == [t for t, _ in b]
    for (_, ra), (_, rb) in zip(a, b):
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert ra.max_new == rb.max_new
    c = build_workload(spec.replace(seed=4), vocab_size=101)
    assert [t for t, _ in a] != [t for t, _ in c]


def test_workload_shape_and_mix():
    spec = LoadSpec(n_requests=64, rate_rps=10.0, prompt_len=(4, 8),
                    max_new=(2, 5), shared_prefix_len=6, shared_frac=0.5,
                    seed=0)
    wl = build_workload(spec, vocab_size=101)
    offsets = [t for t, _ in wl]
    assert offsets == sorted(offsets) and offsets[0] > 0
    # mean inter-arrival gap ~ 1/rate (CLT bound, seeded so never flaky)
    gaps = np.diff([0.0] + offsets)
    assert 0.05 < gaps.mean() < 0.2
    shared = [r for _, r in wl if len(r.prompt) > 8]        # prefix + suffix
    assert 0 < len(shared) < 64                             # mixed traffic
    head = shared[0].prompt[:6]
    for r in shared:
        np.testing.assert_array_equal(r.prompt[:6], head)   # same sys prompt
        assert 4 + 6 <= len(r.prompt) <= 8 + 6
    for _, r in wl:
        assert 2 <= r.max_new <= 5
        assert r.prompt.dtype == np.int64
        assert (0 <= r.prompt).all() and (r.prompt < 101).all()


def test_workload_validation():
    with pytest.raises(ValueError):
        build_workload(LoadSpec(n_requests=0), 100)
    with pytest.raises(ValueError):
        build_workload(LoadSpec(rate_rps=0.0), 100)
    with pytest.raises(ValueError):
        build_workload(LoadSpec(shared_frac=1.5), 100)
    with pytest.raises(ValueError):
        build_workload(LoadSpec(prompt_len=(0, 4)), 100)


# --------------------------------------------------------------------------- #
# goodput math vs hand-computed SLO counts
# --------------------------------------------------------------------------- #
def _req(rid, done=True):
    r = Request(prompt=np.array([1, 2], dtype=np.int64), max_new=2)
    r.rid, r.done = rid, done
    return r


def test_goodput_hand_computed():
    reqs = [_req(0), _req(1), _req(2), _req(3, done=False)]
    lat = {0: {"ttft_s": 0.1, "queue_s": 0.0},     # good
           1: {"ttft_s": 9.0, "queue_s": 0.0},     # TTFT miss
           2: {"ttft_s": 0.2, "queue_s": 0.0}}     # ITL miss below
    itl = {0: [0.01, 0.02], 1: [0.01], 2: [5.0, 0.01]}
    rep = goodput_report(reqs, lat, itl, SLO(ttft_s=1.0, itl_p99_s=1.0))
    assert rep["n_requests"] == 4
    assert rep["n_finished"] == 3                  # rid 3 never finished
    assert rep["ttft_misses"] == 1 and rep["itl_misses"] == 1
    assert rep["n_good"] == 1
    assert rep["goodput"] == pytest.approx(1 / 4)  # unfinished counts against
    assert rep["ttft_mean_s"] == pytest.approx((0.1 + 9.0 + 0.2) / 3)
    assert rep["itl_p99_worst_s"] == pytest.approx(
        float(np.percentile([5.0, 0.01], 99)))


def test_goodput_no_decode_steps_meets_itl():
    # a request that emitted only its prefill token has no ITL samples and
    # trivially meets the ITL SLO
    reqs = [_req(0)]
    rep = goodput_report(reqs, {0: {"ttft_s": 0.1, "queue_s": 0.0}}, {},
                         SLO(ttft_s=1.0, itl_p99_s=0.001))
    assert rep["n_good"] == 1 and rep["itl_p99_worst_s"] == 0.0


def test_publish_goodput_metric_families():
    reg = MetricsRegistry()
    spec, slo = LoadSpec(n_requests=2, rate_rps=5.0), SLO()
    rep = {"goodput": 0.5, "ttft_misses": 1, "itl_misses": 0,
           "n_requests": 2, "n_finished": 2}
    publish_goodput(reg, spec, slo, rep, duration_s=4.0)
    snap = reg.snapshot()
    assert snap["serve_goodput_ratio"] == 0.5
    assert snap["serve_slo_ttft_misses_total"] == 1
    assert snap["loadgen_requests_total"] == 2
    assert snap["loadgen_offered_rps"] == 5.0
    assert snap["loadgen_achieved_rps"] == pytest.approx(0.5)


# --------------------------------------------------------------------------- #
# end-to-end: open-loop admission == batch generate, token for token
# --------------------------------------------------------------------------- #
def test_run_workload_end_to_end_parity(cfg, params):
    spec = LoadSpec(n_requests=5, rate_rps=100.0, prompt_len=(3, 7),
                    max_new=(2, 4), shared_prefix_len=4, shared_frac=0.5,
                    seed=2)
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=16,
                           page_size=4, kv_bits=8)
    reqs, stats = run_workload(eng, spec, slo=SLO(ttft_s=300.0,
                                                  itl_p99_s=300.0))
    assert all(r.done for r in reqs)
    assert stats["n_finished"] == 5
    assert stats["goodput"] == 1.0                 # lenient SLOs: all good
    assert stats["serve_duration_s"] > 0
    assert set(stats["request_latencies"]) == {r.rid for r in reqs}
    # open-loop admission must not change decoded tokens (greedy decoding)
    ref = PagedServeEngine(cfg, params, batch_slots=2, max_seq=16,
                           page_size=4, kv_bits=8)
    ref_reqs, _ = ref.generate(
        [r for _, r in build_workload(spec, cfg.vocab_size)])
    assert [r.out for r in reqs] == [r.out for r in ref_reqs]
    # goodput metrics landed in the engine registry
    snap = eng.obs.metrics.snapshot()
    assert snap["serve_goodput_ratio"] == 1.0
    assert snap["loadgen_requests_total"] == 5


def test_serve_open_loop_rejects_unsorted(cfg, params):
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=16,
                           page_size=4)
    r = Request(prompt=np.array([1, 2, 3], dtype=np.int64), max_new=2)
    r2 = Request(prompt=np.array([1, 2, 3], dtype=np.int64), max_new=2)
    with pytest.raises(ValueError):
        eng.serve_open_loop([(1.0, r), (0.5, r2)])
