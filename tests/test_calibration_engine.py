"""Scan-based calibration engine: legacy equivalence, loss-history contract,
orthogonalization properties, batched-vs-serial agreement.

Trajectory-equality tests run in float64 (``jax.experimental.enable_x64``):
in float32 the scan/vmap lowering differs from the host loop by ~1e-7 per
step and the non-convex whip landscape amplifies that chaotically, so f32
comparisons say nothing about algorithmic equality.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.configs import get_config
from repro.core import OBJECTIVES, calibrate_model, quant_error, whip
from repro.core.qr_orth import (calibrate_cayley_legacy, calibrate_qr_legacy,
                                calibrate_rotations_batched, calibrate_scan,
                                cholqr_rotation, orthogonality_error,
                                qr_rotation)
from repro.core.rotations import random_hadamard


def _toy(key, n=32, N=256, dtype=jnp.float32):
    x = jax.random.laplace(key, (N, n)).astype(dtype) * 0.5
    oc = jax.random.choice(jax.random.fold_in(key, 1), n, (3,), replace=False)
    x = x.at[:, oc].multiply(8.0)
    return x / jnp.std(x)


# --------------------------------------------------------------------------- #
# scan vs legacy host loop
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("optimizer", ["sgd", "adam"])
def test_scan_matches_legacy_qr(key, optimizer):
    """Same seed -> same rotation and loss trace as the legacy host loop."""
    with enable_x64():
        x = _toy(key, dtype=jnp.float64)
        z0 = random_hadamard(32, key).astype(jnp.float64)
        trace = []
        r_legacy = calibrate_qr_legacy(
            x, z0, whip, steps=15, lr=0.05, optimizer=optimizer,
            callback=lambda k, l, z: trace.append(l))
        res = calibrate_scan(x, z0, whip, method="qr", optimizer=optimizer,
                             steps=15, lr=0.05, orth="qr")
        np.testing.assert_allclose(np.asarray(res.rotation),
                                   np.asarray(r_legacy), atol=1e-8)
        np.testing.assert_allclose(np.asarray(res.loss_history),
                                   np.asarray(trace), rtol=1e-10)


def test_scan_matches_legacy_cayley(key):
    with enable_x64():
        x = _toy(key, dtype=jnp.float64)
        r0 = random_hadamard(32, key).astype(jnp.float64)
        trace = []
        r_legacy = calibrate_cayley_legacy(
            x, r0, whip, steps=15, lr=0.05,
            callback=lambda k, l, r: trace.append(l))
        res = calibrate_scan(x, r0, whip, method="cayley", steps=15, lr=0.05)
        np.testing.assert_allclose(np.asarray(res.rotation),
                                   np.asarray(r_legacy), atol=1e-8)
        np.testing.assert_allclose(np.asarray(res.loss_history),
                                   np.asarray(trace), rtol=1e-10)


# --------------------------------------------------------------------------- #
# loss-history / metrics contract
# --------------------------------------------------------------------------- #
def test_loss_history_contract(key):
    """history[0] is the loss at the init; histories have length == steps."""
    x = _toy(key)
    z0 = random_hadamard(32, key)
    res = calibrate_scan(x, z0, whip, steps=12, lr=0.05,
                         metrics=(("quant_err", quant_error),))
    assert res.loss_history.shape == (12,)
    assert res.aux["quant_err"].shape == (12,)
    init_loss = float(whip(x @ cholqr_rotation(z0)))
    assert float(res.loss_history[0]) == pytest.approx(init_loss, rel=1e-5)
    assert float(res.aux["quant_err"][0]) == pytest.approx(
        float(quant_error(x @ cholqr_rotation(z0))), rel=1e-4)
    assert bool(jnp.all(jnp.isfinite(res.loss_history)))
    # whip should make progress on outlier-heavy toy data
    assert float(res.loss_history[-1]) < float(res.loss_history[0])


def test_scan_objectives_all_run(key):
    x = _toy(key)
    z0 = random_hadamard(32, key)
    for name, obj in OBJECTIVES.items():
        res = calibrate_scan(x, z0, obj, steps=3, lr=0.01)
        assert bool(jnp.all(jnp.isfinite(res.loss_history))), name
        assert float(orthogonality_error(res.rotation)) < 1e-4, name


# --------------------------------------------------------------------------- #
# orthogonalization properties across sizes and dtypes
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [2, 5, 16, 57, 128])
def test_qr_rotation_properties_sizes(n):
    z = jax.random.normal(jax.random.PRNGKey(n), (n, n))
    r = qr_rotation(z)
    assert float(orthogonality_error(r)) < 1e-4
    assert abs(abs(float(jnp.linalg.det(r))) - 1.0) < 1e-3


@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_qr_rotation_properties_dtypes(dtype):
    with enable_x64():
        z = jax.random.normal(jax.random.PRNGKey(3), (24, 24)).astype(dtype)
        r = qr_rotation(z)
        assert r.dtype == jnp.dtype(dtype)
        tol = 1e-4 if dtype == "float32" else 1e-12
        assert float(orthogonality_error(r)) < tol


def test_cholqr_matches_qr_near_orthogonal(key):
    """cholqr == sign-fixed QR for the well-conditioned latents the engine
    maintains; the custom VJP matches autodiff through jnp.linalg.qr."""
    n = 48
    z = random_hadamard(n, key) + 0.05 * jax.random.normal(key, (n, n))
    np.testing.assert_allclose(np.asarray(cholqr_rotation(z)),
                               np.asarray(qr_rotation(z)), atol=1e-5)
    x = _toy(jax.random.fold_in(key, 1), n=n)
    g_qr = jax.grad(lambda z: whip(x @ qr_rotation(z)))(z)
    g_ch = jax.grad(lambda z: whip(x @ cholqr_rotation(z)))(z)
    np.testing.assert_allclose(np.asarray(g_ch), np.asarray(g_qr), atol=1e-4)


# --------------------------------------------------------------------------- #
# batched engine
# --------------------------------------------------------------------------- #
def test_batched_matches_serial_engine(key):
    """vmapped scan == per-site scan, checked in f64 (see module doc)."""
    with enable_x64():
        L, n = 3, 24
        xs = jnp.stack([_toy(jax.random.fold_in(key, i), n=n, N=128,
                             dtype=jnp.float64) for i in range(L)])
        z0s = jnp.stack([random_hadamard(n, k).astype(jnp.float64)
                         for k in jax.random.split(key, L)])
        batched = calibrate_rotations_batched(xs, z0s, whip, steps=20,
                                              lr=0.02)
        for i in range(L):
            one = calibrate_scan(xs[i], z0s[i], whip, steps=20, lr=0.02)
            np.testing.assert_allclose(np.asarray(batched.rotation[i]),
                                       np.asarray(one.rotation), atol=1e-8)
            np.testing.assert_allclose(np.asarray(batched.loss_history[i]),
                                       np.asarray(one.loss_history),
                                       rtol=1e-10)


def test_calibrate_model_batched_matches_serial(key):
    """calibrate_model's one-call R2 path == the serial per-layer loop."""
    cfg = get_config("llama2-7b").reduced().replace(
        n_layers=2, d_model=32, d_ff=64, n_heads=2, n_kv_heads=2,
        head_dim=16, vocab_size=128)
    from repro.models import model as M
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    hist_b, hist_s = {}, {}
    pack_b = calibrate_model(cfg, params, toks, key=key, steps=6, lr_r2=1e-3,
                             r2_batched=True, history_out=hist_b)
    pack_s = calibrate_model(cfg, params, toks, key=key, steps=6, lr_r2=1e-3,
                             r2_batched=False, history_out=hist_s)
    assert pack_b["r2"].shape == pack_s["r2"].shape == (2, 16, 16)
    np.testing.assert_allclose(np.asarray(pack_b["r2"]),
                               np.asarray(pack_s["r2"]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hist_b["r2"]),
                               np.asarray(hist_s["r2"]), rtol=1e-3)
    for r in np.asarray(pack_b["r2"]):
        np.testing.assert_allclose(r @ r.T, np.eye(16), atol=1e-4)


def test_bf16_activations_match_f32(key):
    """Low-precision activations must not degrade the optimizer math: latent,
    optimizer state, and lr stay f32 (cast to x.dtype only at x @ R), so a
    bf16-activation run tracks the f32 run to bf16 matmul noise."""
    x = _toy(key)
    z0 = random_hadamard(32, key)
    # SGD: divergence scales with lr * per-step bf16 matmul noise.  (Adam is
    # excluded by design: its g/sqrt(v) normalization turns sign flips of
    # near-zero gradient entries into O(lr) jumps under ANY noise source.)
    res32 = calibrate_scan(x, z0, whip, steps=10, lr=0.01)
    res16 = calibrate_scan(x.astype(jnp.bfloat16), z0, whip, steps=10,
                           lr=0.01)
    assert res16.rotation.dtype == jnp.float32    # latent stays f32
    np.testing.assert_allclose(np.asarray(res16.rotation),
                               np.asarray(res32.rotation), atol=0.01)
    assert float(orthogonality_error(res16.rotation)) < 1e-4
    np.testing.assert_allclose(
        np.asarray(res16.loss_history).astype(np.float32),
        np.asarray(res32.loss_history), rtol=0.02)


def test_single_device_mesh_matches_unsharded(key):
    """mesh= with one device exercises the sharded path (pad/mask, shard_map,
    per-step psum) in-process; it must agree with the plain engine."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = _toy(key, N=250)    # uneven N: exercises pad+mask with k=1
    z0 = random_hadamard(32, key)
    one = calibrate_scan(x, z0, whip, steps=10, lr=0.05)
    shd = calibrate_scan(x, z0, whip, steps=10, lr=0.05, mesh=mesh)
    np.testing.assert_allclose(np.asarray(shd.rotation),
                               np.asarray(one.rotation), atol=1e-4)
    np.testing.assert_allclose(np.asarray(shd.loss_history),
                               np.asarray(one.loss_history), rtol=1e-5)


def test_batched_histories_decrease(key):
    L, n = 4, 32
    xs = jnp.stack([_toy(jax.random.fold_in(key, i), n=n) for i in range(L)])
    z0s = jnp.stack([random_hadamard(n, k)
                     for k in jax.random.split(key, L)])
    res = calibrate_rotations_batched(xs, z0s, whip, steps=25, lr=0.05)
    assert res.loss_history.shape == (L, 25)
    first, last = res.loss_history[:, 0], res.loss_history[:, -1]
    assert bool(jnp.all(last < first))
    for i in range(L):
        assert float(orthogonality_error(res.rotation[i])) < 1e-4
