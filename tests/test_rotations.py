"""Computational-invariance tests: fused rotations preserve outputs exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import arch_params
from repro.configs import ALL_ARCH_IDS, get_config
from repro.core import fuse_rotations, hadamard_matrix, random_hadamard
from repro.core.qr_orth import qr_rotation
from repro.core.rotations import _centering, online_hadamard
from repro.models import model as M


def _build_pack(cfg, key):
    D = cfg.d_model
    hd = cfg.v_head_dim if cfg.attn_type == "mla" else cfg.resolved_head_dim
    k1, k2, k3 = jax.random.split(key, 3)
    pack = {"r4": True}
    if not cfg.sandwich_norm:
        pack["r1"] = qr_rotation(jax.random.normal(k1, (D, D)))
        if cfg.is_encoder_decoder:
            pack["r1_enc"] = qr_rotation(jax.random.normal(k3, (D, D)))
    if cfg.attn_type != "none" and cfg.family != "hybrid":
        pack["r2"] = jax.vmap(qr_rotation)(
            jax.random.normal(k2, (cfg.n_layers, hd, hd)))
    if cfg.family == "hybrid":
        pack["r2_shared"] = qr_rotation(jax.random.normal(k2, (hd, hd)))
    return pack


@pytest.mark.parametrize("arch", arch_params(
    ALL_ARCH_IDS, fast=("llama2-7b", "whisper-medium",
                        "deepseek-v3-671b")))
def test_fusion_invariance(arch, key):
    cfg = get_config(arch).reduced()
    p = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jax.random.normal(key, (2, cfg.encoder_seq, cfg.d_model))
    base, _ = M.forward(cfg, p, toks, **kw)
    pack = _build_pack(cfg, key)
    fcfg, fused = fuse_rotations(cfg, p, pack)
    if cfg.is_encoder_decoder:
        kw["frames"] = kw["frames"] @ _centering(cfg.d_model)
        if "r1_enc" in pack:
            kw["frames"] = kw["frames"] @ pack["r1_enc"]
    out, _ = M.forward(fcfg, fused, toks, rot={"r4": online_hadamard}, **kw)
    rel = float(jnp.max(jnp.abs(out - base))) / (float(jnp.std(base)) + 1e-9)
    assert rel < 2e-2, f"{arch}: invariance broken rel={rel}"
    assert not bool(jnp.any(jnp.isnan(out)))


def test_hadamard_orthogonal():
    for n in (2, 4, 12, 16, 20, 28, 112, 448, 2304):
        h = hadamard_matrix(n)
        np.testing.assert_allclose(h @ h.T, n * np.eye(n), atol=1e-6)


def test_random_hadamard_is_rotation(key):
    for n in (64, 112, 96):
        r = random_hadamard(n, key)
        np.testing.assert_allclose(np.asarray(r @ r.T), np.eye(n), atol=1e-5)


def test_online_hadamard_preserves_norm(key):
    x = jax.random.normal(key, (8, 256))
    y = online_hadamard(x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
