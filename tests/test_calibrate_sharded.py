"""Token-sharded calibration parity — run in subprocesses with 8 placeholder
CPU devices so the main test process keeps a single device.

Strict (f64) parity pins algorithmic equality of the sharded and
single-device engines; f32 runs pin the acceptance-level "f32-noise
tolerance" contract on short trajectories (long f32 trajectories amplify
reduction-order noise chaotically — see test_calibration_engine's module
doc).
"""
import textwrap

from _mesh_compat import run_in_mesh_subprocess as _run


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.qr_orth import calibrate_scan, calibrate_rotations_batched, \\
    cholqr_rotation
from repro.core.whip import whip, quant_error
from repro.core.rotations import random_hadamard
mesh = jax.make_mesh((8, 1), ("data", "model"))
key = jax.random.PRNGKey(0)

def toy(key, n=32, N=256, dtype=jnp.float32):
    x = jax.random.laplace(key, (N, n)).astype(dtype) * 0.5
    oc = jax.random.choice(jax.random.fold_in(key, 1), n, (3,), replace=False)
    x = x.at[:, oc].multiply(8.0)
    return x / jnp.std(x)
"""


def test_sharded_scan_matches_single_device():
    """1-device vs 8-device calibrate_scan: strict in f64, f32-noise in f32."""
    code = PRELUDE + textwrap.dedent("""
        from jax.experimental import enable_x64
        with enable_x64():
            x = toy(key, dtype=jnp.float64)
            z0 = random_hadamard(32, key).astype(jnp.float64)
            one = calibrate_scan(x, z0, whip, steps=25, lr=0.05)
            shd = calibrate_scan(x, z0, whip, steps=25, lr=0.05, mesh=mesh)
            np.testing.assert_allclose(np.asarray(shd.rotation),
                                       np.asarray(one.rotation), atol=1e-10)
            np.testing.assert_allclose(np.asarray(shd.loss_history),
                                       np.asarray(one.loss_history),
                                       rtol=1e-12)
        x = toy(key)
        z0 = random_hadamard(32, key)
        one = calibrate_scan(x, z0, whip, steps=10, lr=0.05)
        shd = calibrate_scan(x, z0, whip, steps=10, lr=0.05, mesh=mesh)
        np.testing.assert_allclose(np.asarray(shd.rotation),
                                   np.asarray(one.rotation), atol=1e-4)
        np.testing.assert_allclose(np.asarray(shd.loss_history),
                                   np.asarray(one.loss_history), rtol=1e-5)
        print("OK scan parity")
    """)
    r = _run(code)
    assert "OK scan parity" in r.stdout, r.stdout + r.stderr


def test_sharded_batched_matches_single_device():
    """Acceptance workload [L=8, N=2048, n=256]: 8-device batched engine ==
    single-device rotations + full loss histories (f32-noise tolerance)."""
    code = PRELUDE + textwrap.dedent("""
        L, N, n = 8, 2048, 256
        xs = jnp.stack([toy(jax.random.fold_in(key, i), n=n, N=N)
                        for i in range(L)])
        z0s = jnp.stack([random_hadamard(n, k)
                         for k in jax.random.split(key, L)])
        one = calibrate_rotations_batched(xs, z0s, whip, steps=5, lr=0.01)
        shd = calibrate_rotations_batched(xs, z0s, whip, steps=5, lr=0.01,
                                          mesh=mesh)
        assert shd.rotation.shape == (L, n, n)
        assert shd.loss_history.shape == (L, 5)
        # 5e-4: on wide CPUs the [L=8, N=2048] reduction order drifts a
        # handful of elements past 1e-4 (observed max 2.4e-4)
        np.testing.assert_allclose(np.asarray(shd.rotation),
                                   np.asarray(one.rotation), atol=5e-4)
        np.testing.assert_allclose(np.asarray(shd.loss_history),
                                   np.asarray(one.loss_history), rtol=1e-5)
        for i in range(L):
            r = np.asarray(shd.rotation[i])
            np.testing.assert_allclose(r @ r.T, np.eye(n), atol=1e-3)
        print("OK batched parity")
    """)
    r = _run(code)
    assert "OK batched parity" in r.stdout, r.stdout + r.stderr


def test_sharded_loss_history_contract_and_metrics():
    """history[0] == loss at the init, metrics psum'd per step — the
    CalibResult contract is unchanged under sharding."""
    code = PRELUDE + textwrap.dedent("""
        x = toy(key)
        z0 = random_hadamard(32, key)
        res = calibrate_scan(x, z0, whip, steps=12, lr=0.05, mesh=mesh,
                             metrics=(("quant_err", quant_error),))
        assert res.loss_history.shape == (12,)
        assert res.aux["quant_err"].shape == (12,)
        init = float(whip(x @ cholqr_rotation(z0)))
        assert abs(float(res.loss_history[0]) - init) < 1e-4 * abs(init)
        qe = float(quant_error(x @ cholqr_rotation(z0)))
        assert abs(float(res.aux["quant_err"][0]) - qe) < 1e-3 * abs(qe)
        assert bool(jnp.all(jnp.isfinite(res.loss_history)))
        assert float(res.loss_history[-1]) < float(res.loss_history[0])
        print("OK contract")
    """)
    r = _run(code)
    assert "OK contract" in r.stdout, r.stdout + r.stderr


def test_sharded_uneven_tokens():
    """N=250 is not divisible by 8 shards: padding rows must be masked out of
    the loss, matching the unpadded single-device run exactly (f64)."""
    code = PRELUDE + textwrap.dedent("""
        from jax.experimental import enable_x64
        with enable_x64():
            x = toy(key, N=250, dtype=jnp.float64)
            z0 = random_hadamard(32, key).astype(jnp.float64)
            one = calibrate_scan(x, z0, whip, steps=20, lr=0.05)
            shd = calibrate_scan(x, z0, whip, steps=20, lr=0.05, mesh=mesh)
            np.testing.assert_allclose(np.asarray(shd.rotation),
                                       np.asarray(one.rotation), atol=1e-10)
            np.testing.assert_allclose(np.asarray(shd.loss_history),
                                       np.asarray(one.loss_history),
                                       rtol=1e-12)
        # batched uneven: token axis 1 padded+masked per site
        L = 3
        xs = jnp.stack([toy(jax.random.fold_in(key, i), N=250)
                        for i in range(L)])
        z0s = jnp.stack([random_hadamard(32, k)
                         for k in jax.random.split(key, L)])
        one = calibrate_rotations_batched(xs, z0s, whip, steps=10, lr=0.05)
        shd = calibrate_rotations_batched(xs, z0s, whip, steps=10, lr=0.05,
                                          mesh=mesh)
        np.testing.assert_allclose(np.asarray(shd.rotation),
                                   np.asarray(one.rotation), atol=1e-4)
        print("OK uneven")
    """)
    r = _run(code)
    assert "OK uneven" in r.stdout, r.stdout + r.stderr


def test_sharded_scan_collective_contract():
    """The sharded scan program satisfies the census qr_orth declares:
    exactly loss + gradient (+ one per metric) psums, all inside the scan,
    and no gathers — checked structurally via the shared ``analysis``
    contract (valid even on a 1-device mesh), not jaxpr string matching."""
    code = PRELUDE + textwrap.dedent("""
        from repro.analysis import run_contract
        from repro.core.qr_orth import sharded_scan_contract
        for metrics in ((), (("quant_err", quant_error),)):
            c = sharded_scan_contract(mesh, whip, metrics=metrics)
            assert c.owner == "repro.core.qr_orth"
            findings = run_contract(c)
            assert not findings, (metrics, [str(f) for f in findings])
        # the census is a real gate: demanding one extra psum must fail
        from repro.analysis import CollectiveCensus, Contract
        base = sharded_scan_contract(mesh, whip)
        wrong = Contract(name=base.name, owner=base.owner,
                         checks=(CollectiveCensus(expect={"psum": 3}),),
                         trace=base.trace)
        assert run_contract(wrong), "census failed to flag a wrong count"
        print("OK scan contract")
    """)
    r = _run(code)
    assert "OK scan contract" in r.stdout, r.stdout + r.stderr


def test_sharded_compressed_grads():
    """int8+error-feedback gradient psum: trajectory tracks the exact-psum
    run and still optimizes the objective."""
    code = PRELUDE + textwrap.dedent("""
        x = toy(key)
        z0 = random_hadamard(32, key)
        exact = calibrate_scan(x, z0, whip, steps=25, lr=0.05, mesh=mesh)
        comp = calibrate_scan(x, z0, whip, steps=25, lr=0.05, mesh=mesh,
                              compressed_grads=True)
        assert bool(jnp.all(jnp.isfinite(comp.loss_history)))
        assert float(comp.loss_history[-1]) < float(comp.loss_history[0])
        e = abs(float(comp.loss_history[-1]) - float(exact.loss_history[-1]))
        assert e < 0.02 * abs(float(exact.loss_history[-1])), e
        print("OK compressed")
    """)
    r = _run(code)
    assert "OK compressed" in r.stdout, r.stdout + r.stderr


def test_sharded_capture_and_calibrate_model():
    """capture_activations(mesh=...) keeps pools token-sharded over the data
    axes and calibrate_model runs every site on the sharded engine."""
    code = PRELUDE + textwrap.dedent("""
        from repro.configs import get_config
        from repro.core import calibrate_model
        from repro.core.capture import capture_activations
        from repro.models import model as M
        cfg = get_config("llama2-7b").reduced().replace(
            n_layers=2, d_model=32, d_ff=64, n_heads=2, n_kv_heads=2,
            head_dim=16, vocab_size=128)
        params = M.init_params(cfg, key)
        toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
        acts = capture_activations(cfg, params, toks, key=key, mesh=mesh)
        for name, v in acts.items():
            ax = 1 if v.ndim == 3 else 0
            assert v.shape[ax] % 8 == 0, (name, v.shape)
            spec = v.sharding.spec
            assert spec[ax] == "data", (name, spec)
        hist = {}
        pack = calibrate_model(cfg, params, toks, key=key, steps=5,
                               history_out=hist, mesh=mesh)
        assert pack["r2"].shape == (2, 16, 16)
        for r in np.asarray(pack["r2"]):
            np.testing.assert_allclose(r @ r.T, np.eye(16), atol=1e-4)
        assert hist["r1"].shape == (5,) and hist["r2"].shape == (2, 5)
        # a pool smaller than the shard count must fail loudly, not trim to
        # zero rows and silently 'calibrate' nothing
        from repro.dist.sharding import place_calib_acts
        try:
            place_calib_acts({"r1": jnp.ones((5, 8))}, mesh)
            raise SystemExit("expected ValueError for 5 tokens on 8 shards")
        except ValueError as e:
            assert "fewer than" in str(e), e
        print("OK capture+model")
    """)
    r = _run(code)
    assert "OK capture+model" in r.stdout, r.stdout + r.stderr
