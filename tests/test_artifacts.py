"""repro.artifacts: quantize-once artifact pipeline + packed-int4 serving.

Covers the format invariants (bit-exact round trip, hash-verified manifest),
the cold-boot contract (artifact serve == in-process calibrate-then-serve
token-for-token, with the calibration stack provably untouched), the memory
story (packed projection weights ≤ 0.3x the fp16 QDQ footprint), and kernel
vs QDQ decode parity.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.artifacts import (ArtifactError, QuantArtifact, load_artifact,
                             rotation_spec, save_artifact)
from repro.artifacts.io import WEIGHTS
from repro.configs import get_config
from repro.core import fuse_rotations, random_pack
from repro.models import model as M
from repro.quant import (memory_bytes, pack_params, pack_weight,
                         projection_weight_bytes, qlinear_matmul,
                         quantize_params)
from repro.quant.quantizers import QTensor
from repro.serve import PagedServeEngine, Request, ServeEngine

CFG = get_config("llama2-7b").reduced().replace(
    n_layers=2, vocab_size=256, max_seq_len=64)


def _fused_packed(key, pack=None):
    params = M.init_params(CFG, key)
    pack = pack if pack is not None else random_pack(CFG, key)
    cfg, params = fuse_rotations(CFG, params, pack)
    return cfg, pack_params(cfg, params), quantize_params(cfg, params), pack


def _artifact(cfg, packed, pack):
    return QuantArtifact(cfg=cfg, params=packed,
                         rotations=rotation_spec(pack),
                         meta={"arch": "llama2-7b"})


def _requests(n, plen=8, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, CFG.vocab_size, plen),
                    max_new=max_new) for _ in range(n)]


@pytest.fixture(scope="module")
def fused(key):
    return _fused_packed(key)


# --------------------------------------------------------------------------- #
# Round trip + manifest
# --------------------------------------------------------------------------- #
def test_roundtrip_bit_exact(tmp_path, fused):
    cfg, packed, _, pack = fused
    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))
    art = load_artifact(str(tmp_path))

    flat_a = jax.tree_util.tree_flatten_with_path(
        packed, is_leaf=lambda x: isinstance(x, QTensor))[0]
    flat_b = jax.tree_util.tree_flatten_with_path(
        art.params, is_leaf=lambda x: isinstance(x, QTensor))[0]
    assert [p for p, _ in flat_a] == [p for p, _ in flat_b]
    for (_, a), (_, b) in zip(flat_a, flat_b):
        if isinstance(a, QTensor):
            assert (a.bits, a.group, a.in_features, a.packed) == \
                (b.bits, b.group, b.in_features, b.packed)
            assert np.array_equal(np.asarray(a.q), np.asarray(b.q))
            assert np.array_equal(np.asarray(a.scale), np.asarray(b.scale))
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert art.cfg == cfg
    assert art.rotations["r3"] == "hadamard"
    assert art.rotations["r1"] == "fused"


def test_manifest_tamper_detected(tmp_path, fused):
    cfg, packed, _, pack = fused
    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))
    blob = tmp_path / WEIGHTS
    raw = bytearray(blob.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    blob.write_bytes(bytes(raw))
    with pytest.raises(ArtifactError, match="sha256"):
        load_artifact(str(tmp_path))
    # truncation is caught before hashing
    blob.write_bytes(bytes(raw[: len(raw) // 2]))
    with pytest.raises(ArtifactError):
        load_artifact(str(tmp_path))


def test_load_is_zero_copy_mmap(tmp_path, fused):
    cfg, packed, _, pack = fused
    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))
    art = load_artifact(str(tmp_path))
    leaves = jax.tree_util.tree_leaves(art.params)
    assert all(isinstance(l.base, np.memmap) or isinstance(l, np.memmap)
               for l in leaves)


# --------------------------------------------------------------------------- #
# Cold boot: serve from artifact == in-process path, no calibration calls
# --------------------------------------------------------------------------- #
def test_cold_boot_matches_inprocess_token_for_token(tmp_path, key,
                                                     monkeypatch):
    from repro.core import calibrate_model
    from repro.data.pipeline import calibration_batch
    calib = jnp.asarray(calibration_batch(CFG, 2, 32))
    params = M.init_params(CFG, key)
    pack = calibrate_model(CFG, params, calib, key=key, steps=5)
    cfg, fparams = fuse_rotations(CFG, params, pack)
    # snapshot the serving bits into the config (what launch/quantize.py does)
    cfg = cfg.replace(quant=cfg.quant.replace(a_bits=8, kv_bits=4))
    packed = pack_params(cfg, fparams)

    from repro.kernels.hadamard.ops import online_hadamard
    rot = {"r3": online_hadamard, "r4": online_hadamard}
    eng_kw = dict(batch_slots=2, max_seq=24, page_size=8, a_bits=8, kv_bits=4)
    eng = PagedServeEngine(cfg, packed, rot=rot, **eng_kw)
    ref_reqs, _ = eng.generate(_requests(3))

    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))

    # the cold boot must never touch the calibration stack
    def _forbidden(*a, **kw):
        raise AssertionError("calibration stack invoked during cold boot")
    import repro.core.calibrate as cal_mod
    import repro.core.qr_orth as qr_mod
    for mod, names in ((cal_mod, ("calibrate_model", "calibrate_rotation",
                                  "calibrate_rotations")),
                       (qr_mod, ("calibrate_scan", "calibrate_qr",
                                 "calibrate_cayley",
                                 "calibrate_rotations_batched"))):
        for name in names:
            monkeypatch.setattr(mod, name, _forbidden)

    art = load_artifact(str(tmp_path))
    cold = PagedServeEngine.from_artifact(
        art, batch_slots=2, max_seq=24, page_size=8)
    assert cold.kv_bits == 4
    cold_reqs, stats = cold.generate(_requests(3))
    for r_ref, r_cold in zip(ref_reqs, cold_reqs):
        assert r_cold.done and r_cold.out == r_ref.out
    assert stats["weight_bytes"] == memory_bytes(packed)


def test_paged_cold_boot_rejects_kv16_snapshot(tmp_path, fused):
    """A snapshot with KV quant off (kv_bits=16) must not be silently
    clamped to 4-bit pages — the artifact's config is a contract."""
    cfg, packed, _, pack = fused            # CFG keeps the default kv_bits=16
    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))
    art = load_artifact(str(tmp_path))
    with pytest.raises(ValueError, match="kv_bits"):
        PagedServeEngine.from_artifact(art, batch_slots=2, max_seq=16,
                                       page_size=8)
    # explicit override is the sanctioned escape hatch
    eng = PagedServeEngine.from_artifact(art, batch_slots=2, max_seq=16,
                                         page_size=8, kv_bits=4)
    assert eng.kv_bits == 4


def test_legacy_engine_cold_boot(tmp_path, fused):
    """The lockstep engine serves packed artifacts too (non-paged families)."""
    cfg, packed, _, pack = fused
    save_artifact(str(tmp_path), _artifact(cfg, packed, pack))
    art = load_artifact(str(tmp_path))
    eng = ServeEngine.from_artifact(art, batch_slots=2, max_seq=16)
    reqs, stats = eng.generate(_requests(2, plen=6, max_new=3))
    assert all(r.done for r in reqs)
    assert stats["weight_bytes"] == memory_bytes(packed)


# --------------------------------------------------------------------------- #
# Memory + numerics
# --------------------------------------------------------------------------- #
def test_packed_projection_bytes_under_budget(fused):
    """Acceptance: packed projection weights ≤ 0.3x the fp16 QDQ footprint."""
    cfg, packed, qdq, _ = fused
    proj, proj_fp16 = projection_weight_bytes(packed)
    assert proj <= 0.3 * proj_fp16
    # QDQ keeps dense fp tensors resident — the memory story it fakes
    dense_proj, dense_fp16 = projection_weight_bytes(qdq)
    assert dense_proj >= dense_fp16        # f32 here, ≥ the fp16 equivalent
    assert memory_bytes(packed) < memory_bytes(qdq)


def test_packed_forward_matches_qdq(fused):
    """Packed-kernel execution == the QDQ reference path within f32 noise
    (same codes + fp16 scales by construction, different matmul order)."""
    cfg, packed, qdq, _ = fused
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 12)), jnp.int32)
    logits_p, _ = M.prefill(cfg, packed, toks)
    logits_q, _ = M.prefill(cfg, qdq, toks)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_q),
                               atol=2e-4, rtol=2e-4)


def test_pack_weight_odd_in_features(key):
    """Odd last dims are padded (not skipped) and record the logical shape."""
    w = jax.random.normal(key, (6, 33))
    qt = pack_weight(w, bits=4)
    assert qt.packed and qt.in_features == 33
    assert qt.q.shape == (6, 17)            # padded to 34, two nibbles/byte
    assert qt.logical_shape == (6, 33)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 33))
    y = qlinear_matmul(x, qt)
    assert y.shape == (4, 6)
    # padding columns are exact zeros: identical to quantizing the unpadded
    # weight per channel
    from repro.quant.quantizers import quant_weight
    ref = x.astype(jnp.float32) @ (
        quant_weight(w, bits=4).q.astype(jnp.float32)
        * quant_weight(w, bits=4).scale.astype(jnp.float16).astype(jnp.float32)).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


def test_pack_params_covers_odd_dims():
    """pack_params no longer silently skips odd in-feature projections."""
    fake = {"attn": {"wq": jnp.ones((4, 7)), "wo": jnp.ones((4, 8))},
            "norm": {"scale": jnp.ones((7,))}}
    packed = pack_params(CFG, fake)
    assert isinstance(packed["attn"]["wq"], QTensor)
    assert packed["attn"]["wq"].in_features == 7
    assert isinstance(packed["attn"]["wo"], QTensor)
    assert not isinstance(packed["norm"]["scale"], QTensor)
