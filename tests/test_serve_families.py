"""One paged runtime for every decoder family: adapters, parity, sampling.

The headline tests reuse the 5-requests-over-2-slots pattern of
``test_serve_paged.py`` for the families the paged runtime gained in this
refactor — MLA latent pages (deepseek-v3), SSM state pools (mamba2), hybrid
interleavings (zamba2), and mixed dense+MoE stacks (grok1-style) — checking
every completed request token-for-token against its own single-sequence
dense-cache reference (legacy prefill/decode with the matching QDQ hooks:
``kv_quant`` at cache write, ``state_quant`` at the prefill handoff and each
decode step, exactly where the paged runtime quantizes for real).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCH_IDS, get_config
from repro.models import model as M
from repro.quant import make_kv_quant
from repro.serve import (MLALatentPages, PagedServeEngine, PagePool, Request,
                         ServeEngine, SSMStatePool, adapters_for)
from repro.train import steps as S

_PARAMS_CACHE = {}


def _model(arch, **repl):
    k = (arch, tuple(sorted(repl.items())))
    if k not in _PARAMS_CACHE:
        cfg = get_config(arch).reduced().replace(**repl)
        _PARAMS_CACHE[k] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
    return _PARAMS_CACHE[k]


def _family_rot(cfg, kv_bits=4, state_bits=8):
    """The QDQ hooks that make the dense reference bit-match paged storage."""
    rot = {}
    if cfg.attn_type != "none":
        rot["kv_quant"] = make_kv_quant(kv_bits)
    if cfg.family in ("ssm", "hybrid"):
        rot["state_quant"] = make_kv_quant(state_bits)
    return rot


def _dense_reference(cfg, params, prompt, max_new, max_seq, rot):
    """Single-sequence greedy run on the legacy dense-cache path."""
    pre = jax.jit(S.build_prefill(cfg, rot=rot))
    dec = jax.jit(S.build_decode_step(cfg, rot=rot))
    plen = len(prompt)
    logits, cache = pre(params, jnp.asarray(np.asarray(prompt)[None],
                                            jnp.int32))

    def grow(v):
        return jax.tree.map(
            lambda x: (jnp.pad(x, [(0, 0)] * 2 + [(0, max_seq - x.shape[2])]
                               + [(0, 0)] * (x.ndim - 3))
                       if x.ndim >= 3 and x.shape[2] == plen else x), v)

    cache = {k: (grow(v) if k.startswith("kv") else v)
             for k, v in cache.items()}
    # recurrent-state handoff: the paged engine quantizes the fp32 prefill
    # carry into its state slot exactly once — mirror it here
    sq = rot.get("state_quant")
    if sq is not None:
        cache = {k: (jax.tree.map(sq, v) if k.startswith("ssm") else v)
                 for k, v in cache.items()}
    out = [int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))]
    last, pos = out[0], plen
    for _ in range(max_new - 1):
        logits, cache = dec(params, jnp.asarray([[last]], jnp.int32), cache,
                            jnp.int32(pos))
        last = int(jnp.argmax(logits[0, 0, :cfg.vocab_size]))
        out.append(last)
        pos += 1
    return out


# --------------------------------------------------------------------------- #
# supports_paged: every decoder-only family, enc-dec excluded
# --------------------------------------------------------------------------- #
def test_supports_paged_covers_all_decoder_families():
    for arch in ALL_ARCH_IDS:
        cfg = get_config(arch)
        assert M.supports_paged(cfg) == (not cfg.is_encoder_decoder), arch
        # the fix for the mixed dense+MoE false-negative: a dense prefix must
        # not disqualify a MoE decoder
        if cfg.n_experts and not cfg.is_encoder_decoder:
            assert M.supports_paged(cfg.replace(n_dense_layers=1)), arch


# --------------------------------------------------------------------------- #
# Token-for-token parity: MLA / SSM / hybrid / mixed MoE over the scheduler
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch,repl", [
    ("deepseek-v3-671b", {}),               # MLA latent pages + mixed MoE
    ("mamba2-370m", {}),                    # SSM state pool
    ("zamba2-7b", {}),                      # hybrid: state pool + attn pages
    ("grok-1-314b", {"n_dense_layers": 1}),  # mixed dense+MoE GQA stack
])
def test_family_paged_matches_dense_reference(arch, repl):
    """5 requests over 2 slots, ragged prompts crossing page/chunk
    boundaries: every request's greedy tokens equal its own single-sequence
    dense-cache run (same QDQ points)."""
    cfg, params = _model(arch, **repl)
    rot = _family_rot(cfg)
    rng = np.random.default_rng(0)
    lens = [12, 7, 12, 9, 7]                # few distinct prefill shapes
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, n), max_new=6)
            for n in lens]
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=48,
                           page_size=8, kv_bits=4)
    reqs, stats = eng.generate(reqs)
    assert all(r.done for r in reqs)
    assert stats["kv_cache_bytes"] == eng.pool.nbytes
    for i, r in enumerate(reqs):
        ref = _dense_reference(cfg, params, r.prompt, r.max_new, 48, rot)
        assert r.out == ref, f"{arch} request {i}: {r.out} vs {ref}"


def test_ssm_prefill_chunk_wider_than_prompt(key):
    """A padded prefill chunk must not advance the recurrent state past the
    prompt tail (the state analogue of the null-page overhang property)."""
    cfg, params = _model("mamba2-370m")
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, 10)
    eng = PagedServeEngine(cfg, params, batch_slots=1, max_seq=32,
                           page_size=8, prefill_chunk=32, kv_bits=4)
    reqs, _ = eng.generate([Request(prompt=prompt, max_new=6)])
    ref = _dense_reference(cfg, params, prompt, 6, 32, _family_rot(cfg))
    assert reqs[0].out == ref


# --------------------------------------------------------------------------- #
# Latent-page and state-slot round-trip / byte-accounting properties
# --------------------------------------------------------------------------- #
def test_latent_pages_roundtrip_and_bytes(key):
    from repro.kernels.paged_attn.ref import gather_latent_pages
    cfg, _ = _model("deepseek-v3-671b")
    # deepseek is a mixed stack: dense prefix and MoE rest each get their own
    # latent-page sub-state (scans consume them without slice/concat copies)
    ads = adapters_for(cfg, kv_bits=4)
    assert set(ads) == {"attn_dense", "attn_moe"}
    ad = ads["attn_dense"]
    assert isinstance(ad, MLALatentPages)
    state = ad.init_state(num_pages=5, page_size=4)
    assert ad.nbytes(state) == ad.predicted_nbytes(5, 4)
    assert ad.nbytes(state) == sum(int(x.size) * x.dtype.itemsize
                                   for x in jax.tree.leaves(state))
    # write 4 latent rows into page 2, read them back through a block table
    kvlr, rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    c_kv = jax.random.normal(key, (4, kvlr))
    k_rope = jax.random.normal(jax.random.fold_in(key, 1), (4, rope))
    state_l = jax.tree.map(lambda a: a[0], state)
    new_l = ad.write_decode(state_l, c_kv, k_rope,
                            jnp.full((4,), 2, jnp.int32),
                            jnp.arange(4, dtype=jnp.int32))
    ckv_d, kr_d = gather_latent_pages(new_l, jnp.asarray([[2]], jnp.int32),
                                      bits=4, kv_lora_rank=kvlr,
                                      rope_dim=rope)
    hook = make_kv_quant(4)
    np.testing.assert_array_equal(np.asarray(ckv_d[0], np.float32),
                                  np.asarray(hook(c_kv), np.float32))
    np.testing.assert_array_equal(np.asarray(kr_d[0], np.float32),
                                  np.asarray(hook(k_rope), np.float32))


def test_state_slots_roundtrip_init_and_bytes(key):
    cfg, _ = _model("mamba2-370m")
    ad = adapters_for(cfg, state_bits=8)["ssm"]
    assert isinstance(ad, SSMStatePool)
    state = ad.init_state(n_slots=3)
    assert ad.nbytes(state) == ad.predicted_nbytes(3)
    K1, C, H, P, N = ad._dims()
    conv = jax.random.normal(key, (2, K1, C), jnp.float32)
    h = jax.random.normal(jax.random.fold_in(key, 1), (2, H, P, N),
                          jnp.float32)
    slots = jnp.asarray([1, 3], jnp.int32)
    state_l = jax.tree.map(lambda a: a[0], state)
    new_l = ad.write_slots(state_l, slots, {"conv": conv, "h": h})
    back = ad.read_slots(new_l, slots)
    hook = make_kv_quant(8)
    np.testing.assert_array_equal(np.asarray(back["conv"]),
                                  np.asarray(hook(conv)))
    np.testing.assert_array_equal(np.asarray(back["h"]), np.asarray(hook(h)))
    # init_slot zeroes exactly one physical slot
    full = jax.tree.map(lambda a: a[None].repeat(ad.layers, 0), new_l)
    wiped = ad.init_slot(full, 1)
    assert not any(np.asarray(v[:, 1]).any() for v in wiped.values())
    for v, w in zip(full.values(), wiped.values()):
        np.testing.assert_array_equal(np.asarray(v[:, 3]),
                                      np.asarray(w[:, 3]))
    # commit quantizes a fp32 carry into its slot (one event at the handoff)
    carry = ad.init_carry()
    carry = {"conv": carry["conv"].at[...].set(1.5),
             "h": carry["h"].at[...].set(-0.25)}
    committed = ad.commit(ad.init_state(3), carry, 2)
    got = ad.read_slots(jax.tree.map(lambda a: a[0], committed),
                        jnp.asarray([2], jnp.int32))
    np.testing.assert_allclose(np.asarray(got["conv"][0]), 1.5, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got["h"][0]), -0.25, atol=2e-2)


def test_pool_nbytes_extends_to_every_family():
    for arch in ("deepseek-v3-671b", "mamba2-370m", "zamba2-7b"):
        cfg = get_config(arch).reduced()
        pool = PagePool(cfg, num_pages=6, page_size=4, max_seq=16,
                        kv_bits=4, state_bits=8, n_slots=2)
        held = sum(int(x.size) * x.dtype.itemsize
                   for x in jax.tree.leaves(pool.state))
        assert pool.nbytes == held == pool.predicted_nbytes, arch
        assert set(pool.nbytes_by_kind) == set(pool.adapters)


# --------------------------------------------------------------------------- #
# Sampling: temperature/top-k with per-request PRNG keys
# --------------------------------------------------------------------------- #
def _serve_sampled(cfg, params, prompts, **req_kw):
    eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=32,
                           page_size=8, kv_bits=4)
    reqs = [Request(prompt=p.copy(), max_new=5, **req_kw) for p in prompts]
    return [r.out for r in eng.generate(reqs)[0]]


def test_sampling_deterministic_replay_and_greedy_oracle():
    cfg, params = _model("llama2-7b")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(3)]
    greedy = _serve_sampled(cfg, params, prompts)             # temp 0 default
    a = _serve_sampled(cfg, params, prompts, temperature=0.8, top_k=20,
                       seed=7)
    b = _serve_sampled(cfg, params, prompts, temperature=0.8, top_k=20,
                       seed=7)
    c = _serve_sampled(cfg, params, prompts, temperature=0.8, top_k=20,
                       seed=8)
    assert a == b                       # same per-request key -> same tokens
    assert a != c                       # key actually drives the draw
    assert a != greedy
    # top-k=1 collapses to the greedy oracle at any temperature
    assert _serve_sampled(cfg, params, prompts, temperature=0.7,
                          top_k=1) == greedy


def test_sampling_matches_dense_greedy_when_disabled():
    """Greedy remains the default and the parity oracle: no sampling args
    means argmax, token-for-token with the dense reference."""
    cfg, params = _model("llama2-7b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 9)
    out = _serve_sampled(cfg, params, [prompt])[0]
    assert out == _dense_reference(cfg, params, prompt, 5, 32,
                                   _family_rot(cfg))


# --------------------------------------------------------------------------- #
# Engine surface: wrapper forwarding + artifact rejection
# --------------------------------------------------------------------------- #
def test_serve_engine_is_paged_wrapper_for_decoders():
    """The lockstep loop is retired for decoder-only families: ServeEngine
    forwards to PagedServeEngine (refill bug gone), and kv_bits=16 serves
    through raw fp16 pages (lossless compat)."""
    cfg, params = _model("mamba2-370m")
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=32, page_size=8,
                      kv_bits=16)
    assert eng._paged is not None
    assert eng._paged.state_bits == 32          # f32 state: legacy numerics
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 7), max_new=4)
            for _ in range(3)]
    reqs, stats = eng.generate(reqs)
    assert all(r.done for r in reqs)
    # lossless compat path == plain dense reference (no QDQ hooks at all)
    ref = _dense_reference(cfg, params, reqs[0].prompt, 4, 32, {})
    assert reqs[0].out == ref
    assert stats["kv_cache_bytes"] == eng._paged.pool.nbytes


def test_wrapper_keeps_lockstep_for_enc_dec():
    cfg = get_config("whisper-medium").reduced()
    assert not M.supports_paged(cfg)
    with pytest.raises(NotImplementedError, match="ServeEngine"):
        PagedServeEngine(cfg, params=None)


def test_from_artifact_rejects_unpaged_family_with_clear_error():
    """An enc-dec artifact must fail fast with the family and the fallback
    named — not a deep shape error at jit time."""
    from repro.artifacts import QuantArtifact
    cfg = get_config("whisper-medium").reduced()
    art = QuantArtifact(cfg=cfg, params={}, rotations={})
    with pytest.raises(NotImplementedError) as ei:
        PagedServeEngine.from_artifact(art, batch_slots=1, max_seq=16)
    msg = str(ei.value)
    assert "whisper-medium" in msg and "encoder-decoder" in msg
    assert "ServeEngine" in msg                 # the fallback is named
