"""Per-arch smoke tests (reduced configs) + component oracles.

One forward + one train step per architecture on CPU: shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import arch_params
from repro.configs import ALL_ARCH_IDS, get_config
from repro.models import model as M
from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_chunked, ssd_reference
from repro.train import steps as S
from repro.train.optimizer import init_opt_state


def _batch(cfg, key, B=2, S_=32):
    toks = jax.random.randint(key, (B, S_ + 1), 0, cfg.vocab_size)
    b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", arch_params(ALL_ARCH_IDS))
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _ = M.forward(cfg, params, batch["tokens"],
                          frames=batch.get("frames"))
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one train step
    opt = init_opt_state(cfg, params)
    step = jax.jit(S.build_train_step(cfg))
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x, y: float(jnp.sum(jnp.abs(x.astype(jnp.float32)
                                                        - y.astype(jnp.float32)))),
                     params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", arch_params(ALL_ARCH_IDS))
def test_smoke_prefill_decode_consistency(arch, key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, key)
    B, S_ = 2, 17
    toks = jax.random.randint(key, (B, S_ + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
    full, _ = M.forward(cfg, params, toks, **kw)
    lg, cache = M.prefill(cfg, params, toks[:, :S_], **kw)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :S_]),
                               rtol=5e-3, atol=5e-3)

    def grow(c):
        out = {}
        for k, v in c.items():
            if k == "cross":
                out[k] = v
            elif isinstance(v, dict):
                out[k] = grow(v)
            elif k in ("k", "v", "ckv", "krope") and v.ndim >= 3:
                pad = [(0, 0)] * v.ndim
                pad[2] = (0, 4)
                out[k] = jnp.pad(v, pad)
            else:
                out[k] = v
        return out

    lg2, _ = M.decode_step(cfg, params, toks[:, S_:S_ + 1], grow(cache),
                           jnp.int32(S_))
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(full[:, S_]),
                               rtol=5e-3, atol=5e-3)


def test_ssd_chunked_matches_recurrence(key):
    B, S_, H, P, N = 2, 48, 3, 8, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S_, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S_, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (B, S_, H)) * 0.3) * dt
    Bm = jax.random.normal(ks[3], (B, S_, H, N))
    Cm = jax.random.normal(ks[4], (B, S_, H, N))
    for chunk in (8, 16, 48):
        y, h = ssd_chunked(x, a, Bm, Cm, dt, chunk)
        y_ref, h_ref = ssd_reference(x, a, Bm, Cm, dt)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   atol=1e-3, rtol=1e-3)


def test_chunked_attention_matches_dense(key):
    B, S_, H, hd = 2, 64, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S_, H, hd))
    k = jax.random.normal(ks[1], (B, S_, H, hd))
    v = jax.random.normal(ks[2], (B, S_, H, hd))
    pos = jnp.arange(S_, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=True, chunk=16)
    # dense reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S_, S_), bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_local_window_attention(key):
    B, S_, H, hd, W = 1, 32, 2, 8, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S_, H, hd))
    k = jax.random.normal(ks[1], (B, S_, H, hd))
    v = jax.random.normal(ks[2], (B, S_, H, hd))
    pos = jnp.arange(S_, dtype=jnp.int32)
    out = chunked_attention(q, k, v, pos, pos, causal=True, window=W, chunk=8)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S_, S_), bool)) & \
        ((pos[:, None] - pos[None, :]) < W)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_moe_paths_agree(key):
    from repro.models import ffn as F
    cfg = get_config("grok-1-314b").reduced()
    params = M.init_params(cfg, key)
    mp = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(key, (32, cfg.d_model))
    y1, _ = F.moe_einsum(cfg, mp, x)
    y2, _ = F.moe_ragged_local(cfg, mp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
