"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py forces 512 placeholder devices."""
import os

# the suite is a CPU suite (ROADMAP tier-1); without this, images that ship
# libtpu stall probing for TPU hardware.  setdefault keeps explicit overrides.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


# Architectures exercised in the fast (tier-1) selection; the rest run with
# `-m slow`.  One representative per family keeps the fast suite meaningful:
# dense GQA, SSM, sandwich-norm; MLA/MoE and enc-dec get their fast coverage
# through tests/test_calibrate_families.py and tests/test_rotations.py.
FAST_ARCHS = ("llama2-7b", "mamba2-370m", "gemma2-2b")


def arch_params(arch_ids, fast=FAST_ARCHS):
    """Wrap an arch-id list for parametrize, marking non-fast archs slow."""
    return [a if a in fast else pytest.param(a, marks=pytest.mark.slow)
            for a in arch_ids]
