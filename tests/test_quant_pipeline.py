"""End-to-end DartQuant pipeline: calibrate -> fuse -> quantize -> evaluate.

Reproduces the paper's qualitative orderings on a *trained* tiny model:
RTN-W4A4 >> rotated-W4A4; calibrated >= random-Hadamard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (calibrate_model, capture_activations, fuse_rotations,
                        identity_pack, outlier_count, quant_error, random_pack)
from repro.core.rotations import online_hadamard
from repro.data.pipeline import batches, calibration_batch
from repro.models import model as M
from repro.models.common import cross_entropy
from repro.quant import act_quant, fake_quant_act, quantize_params
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer

CFG = get_config("llama2-7b").reduced().replace(
    n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab_size=256)


@pytest.fixture(scope="module")
def trained():
    tr = Trainer(CFG, batch_size=8, seq_len=64, lr=5e-3)
    tr.train(80, verbose=False)
    return tr.params


def _ce(cfg, params, a_bits=16, rot=None, seed=9, n_batches=3):
    it = batches(cfg, 8, 64, seed=seed)
    evs = [next(it) for _ in range(n_batches)]

    def run(t, l):
        logits, _ = M.forward(cfg, params, t, rot=rot)
        return cross_entropy(logits, l)

    jrun = jax.jit(run)

    def all_batches():
        return float(np.mean([float(jrun(jnp.asarray(b["tokens"]),
                                         jnp.asarray(b["labels"])))
                              for b in evs]))
    if a_bits < 16:
        with act_quant(lambda x: fake_quant_act(x, a_bits)):
            return all_batches()
    return all_batches()


@pytest.mark.slow
def test_w4a4_quant_quality_ordering(trained, key):
    """fp <= dart(W4A4) <= hadamard(W4A4) (tol) << rtn(W4A4)  — Tab. 2 shape."""
    params = trained
    ce_fp = _ce(CFG, params)
    ce_rtn = _ce(CFG, quantize_params(CFG, params), a_bits=4)

    calib = jnp.asarray(calibration_batch(CFG, 8, 64))
    rot = {"r4": online_hadamard}

    hcfg, hp = fuse_rotations(CFG, params, random_pack(CFG, key))
    ce_had = _ce(hcfg, quantize_params(hcfg, hp), a_bits=4, rot=rot)

    pack = calibrate_model(CFG, params, calib, key=key, steps=60, lr_r1=0.05,
                           lr_r2=0.05)
    dcfg, dp = fuse_rotations(CFG, params, pack)
    ce_dart = _ce(dcfg, quantize_params(dcfg, dp), a_bits=4, rot=rot)

    # at d_model=64 the RTN-vs-rotated gap is noise-level (the catastrophic
    # RTN collapse needs 7B-scale activation outliers); assert the *robust*
    # orderings: quantization hurts, rotation never loses to RTN, and the
    # calibrated rotation tracks the Hadamard one.
    assert ce_rtn >= ce_fp - 0.02 and ce_had >= ce_fp - 0.02
    assert ce_had <= ce_rtn + 0.05, "rotation must not lose to RTN at W4A4"
    assert ce_dart <= ce_had * 1.10, "calibrated should not lose to Hadamard"
    assert ce_dart >= ce_fp - 0.05


def test_calibrated_rotation_reduces_outliers(trained, key):
    """Fig. 3: fewer outliers + lower quant error on captured activations."""
    acts = capture_activations(CFG, trained,
                               jnp.asarray(calibration_batch(CFG, 8, 64)),
                               sample_frac=0.5, key=key)
    x = acts["r1"]
    from repro.core import calibrate_rotation, random_hadamard
    had = random_hadamard(CFG.d_model, key)
    r = calibrate_rotation(x, CFG.d_model, key, steps=80, lr=0.1)
    q_id = float(quant_error(x))
    q_had = float(quant_error(x @ had))
    q_dart = float(quant_error(x @ r))
    # this tiny trained model has low-kurtosis activations, so a *random*
    # Hadamard has nothing to smooth — but the *calibrated* rotation still
    # finds a better-than-identity distribution (the paper's core claim)
    assert q_dart < q_id
    assert q_dart < q_had


def test_calibration_dataset_robustness(trained, key):
    """Tab. 5: calibrating on different corpora gives similar results."""
    results = []
    for seed in (0, 1):
        calib = jnp.asarray(calibration_batch(CFG, 8, 64, seed=seed))
        pack = calibrate_model(CFG, trained, calib, key=key, steps=40,
                               lr_r1=0.05, use_r2=False)
        dcfg, dp = fuse_rotations(CFG, trained, pack)
        results.append(_ce(dcfg, quantize_params(dcfg, dp), a_bits=4,
                           rot={"r4": online_hadamard}))
    assert abs(results[0] - results[1]) < 0.3 * max(results)


def test_serve_engine_generates(trained):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, 8), max_new=4)
            for _ in range(4)]
    eng = ServeEngine(CFG, trained, batch_slots=2, max_seq=48, a_bits=8,
                      kv_bits=4)
    reqs, stats = eng.generate(reqs)
    assert all(len(r.out) >= 4 for r in reqs if r.done)
    assert sum(r.done for r in reqs) == 4
    assert stats["decode_tok_per_s"] > 0
