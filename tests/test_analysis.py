"""repro.analysis — every rule must fire on its seeded-violation fixture
and stay silent on the idiomatic fix.

A lint that cannot flag its own fixture is dead weight; one that flags the
fix is noise.  Trace-time fixtures build tiny jaxprs in-process (the census
walk is structural, so a 1-device mesh suffices); AST fixtures go through
``lint_source``; the CLI tests exercise exit codes 0/1/2 end-to-end,
including the clean-tree run the CI gate relies on.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.analysis import (CollectiveCensus, Contract, DonationAliased,
                            Finding, HostCallbackCount, PackedDtypeAudit,
                            RecompileCount, collective_census, lint_source,
                            run_contract)
from repro.analysis.suppress import (SuppressionError, Suppression,
                                     filter_findings, load_suppressions)
from repro.quant.quantizers import QTensor

ROOT = Path(__file__).resolve().parents[1]


def _contract(name="fixture", *, checks, trace=None, lower=None, live=None):
    return Contract(name=name, owner="tests", checks=tuple(checks),
                    trace=trace, lower=lower, live=live)


def _rules(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------- #
# Trace-time rule 1: collective census
# --------------------------------------------------------------------------- #
def _census_jaxpr(*, loose_psum=False, gather=False):
    """3 psum equations inside a scanned body (the scan traces its body
    once, so the structural count is per-equation, not per-iteration);
    optional violations appended."""
    mesh = jax.make_mesh((1,), ("model",))

    def body(x):
        def step(c, _):
            a = jax.lax.psum(x, "model")
            b = jax.lax.psum(x * 2.0, "model")
            d = jax.lax.psum(x * 3.0, "model")
            return c + a + b + d, ()
        y, _ = jax.lax.scan(step, x, None, length=2)
        if loose_psum:
            y = jax.lax.psum(y, "model")
        if gather:
            y = jax.lax.all_gather(y, "model")
        return y

    f = shard_map(body, mesh=mesh, in_specs=P("model"),
                  out_specs=P("model"), check_rep=False)
    return jax.make_jaxpr(f)(jnp.ones((4,)))


def test_collective_census_clean_and_wrong_count():
    jaxpr = _census_jaxpr()
    census = collective_census(jaxpr)
    assert len(census.get("psum", [])) == 3
    assert all(s.in_scan for s in census["psum"])

    ok = _contract(checks=[CollectiveCensus(
        expect={"psum": 3}, forbid=("all_gather", "all_to_all"),
        require_in_scan=True)], trace=lambda: jaxpr)
    assert run_contract(ok) == []

    wrong = _contract(checks=[CollectiveCensus(expect={"psum": 2})],
                      trace=lambda: jaxpr)
    findings = run_contract(wrong)
    assert _rules(findings) == ["collective-census"], findings
    assert "expected 2 psum" in findings[0].message


def test_collective_census_flags_smuggled_gather():
    jaxpr = _census_jaxpr(gather=True)
    c = _contract(checks=[CollectiveCensus(
        expect={"psum": 3}, forbid=("all_gather", "all_to_all"))],
        trace=lambda: jaxpr)
    findings = run_contract(c)
    assert any("forbidden collective all_gather" in f.message
               for f in findings), findings


def test_collective_census_flags_psum_outside_scan():
    jaxpr = _census_jaxpr(loose_psum=True)
    # the structural total (4) is right — placement is not
    c = _contract(checks=[CollectiveCensus(expect={"psum": 4},
                                           require_in_scan=True)],
                  trace=lambda: jaxpr)
    findings = run_contract(c)
    assert len(findings) == 1 and "outside the layer scan" in \
        findings[0].message, findings


# --------------------------------------------------------------------------- #
# Trace-time rule 2: host-callback budget
# --------------------------------------------------------------------------- #
def test_host_callback_flags_armed_debug_callback():
    def armed(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0

    c = _contract(checks=[HostCallbackCount(expect=0)],
                  trace=lambda: jax.make_jaxpr(armed)(1.0))
    findings = run_contract(c)
    assert len(findings) == 1, findings
    assert "found 1" in findings[0].message

    clean = _contract(checks=[HostCallbackCount(expect=0)],
                      trace=lambda: jax.make_jaxpr(lambda x: x * 2.0)(1.0))
    assert run_contract(clean) == []


# --------------------------------------------------------------------------- #
# Trace-time rule 3: packed-dtype audit
# --------------------------------------------------------------------------- #
def _qt():
    return QTensor(jnp.zeros((8, 8), jnp.int8), jnp.ones((8, 1)), bits=4)


def _mk_quant_matmul(accum_dtype):
    # named exactly like the sanctioned seam: dequant inside is allowed,
    # but the accumulator contract still applies to its dot_general
    def quant_matmul(qt, x):
        w = qt.q.astype(accum_dtype) * qt.scale.astype(accum_dtype)
        return jax.lax.dot_general(x.astype(accum_dtype), w,
                                   (((1,), (0,)), ((), ())))
    return quant_matmul


def test_packed_dtype_flags_f32_dequant_outside_sanctioned_sites():
    def leaky(qt, x):
        w = qt.q.astype(jnp.float32) * qt.scale
        return x @ w

    args = (_qt(), jnp.ones((2, 8)))
    c = _contract(checks=[PackedDtypeAudit(payload_args=lambda: args)],
                  trace=lambda: jax.make_jaxpr(leaky)(*args))
    findings = run_contract(c)
    assert findings and "outside the sanctioned dequant sites" in \
        findings[0].message, findings


def test_packed_dtype_sanctioned_site_clean_but_accum_checked():
    args = (_qt(), jnp.ones((2, 8)))

    good = _mk_quant_matmul(jnp.float32)
    c = _contract(checks=[PackedDtypeAudit(payload_args=lambda: args)],
                  trace=lambda: jax.make_jaxpr(good)(*args))
    assert run_contract(c) == []

    bad = _mk_quant_matmul(jnp.bfloat16)
    c = _contract(checks=[PackedDtypeAudit(payload_args=lambda: args)],
                  trace=lambda: jax.make_jaxpr(bad)(*args))
    findings = run_contract(c)
    assert len(findings) == 1 and "accumulates in bfloat16" in \
        findings[0].message, findings


def test_packed_dtype_requires_payloads():
    args = (jnp.ones((2, 8)),)
    c = _contract(checks=[PackedDtypeAudit(payload_args=lambda: args)],
                  trace=lambda: jax.make_jaxpr(lambda x: x + 1)(*args))
    findings = run_contract(c)
    assert findings and "no quantized QTensor payloads" in \
        findings[0].message


# --------------------------------------------------------------------------- #
# Trace-time rule 4: donation aliasing
# --------------------------------------------------------------------------- #
def test_donation_flags_dropped_donation():
    x = jnp.ones((16,))
    step = lambda v: v + 1.0  # noqa: E731 — shape-preserving, aliasable

    ok = _contract(checks=[DonationAliased(min_aliased=1)],
                   lower=lambda: jax.jit(step, donate_argnums=(0,)).lower(x))
    assert run_contract(ok) == []

    dropped = _contract(checks=[DonationAliased(min_aliased=1)],
                        lower=lambda: jax.jit(step).lower(x))
    findings = run_contract(dropped)
    assert len(findings) == 1 and "donation dropped" in findings[0].message


# --------------------------------------------------------------------------- #
# Trace-time rule 5: recompilation sentinel
# --------------------------------------------------------------------------- #
def test_recompile_sentinel():
    f = jax.jit(lambda x: x * 2.0)
    f(jnp.ones((2,)))
    f(jnp.ones((3,)))  # second geometry -> second cache entry
    live = lambda: {"f": f, "n": 2}  # noqa: E731

    ok = _contract(checks=[RecompileCount(expect={"f": (1, 2), "n": 2})],
                   live=live)
    assert run_contract(ok) == []

    over = _contract(checks=[RecompileCount(expect={"f": 1})], live=live)
    findings = run_contract(over)
    assert len(findings) == 1 and "compiled 2 time(s); budget 1" in \
        findings[0].message, findings

    missing = _contract(checks=[RecompileCount(expect={"g": 1})], live=live)
    findings = run_contract(missing)
    assert findings and "not found in the live program map" in \
        findings[0].message


# --------------------------------------------------------------------------- #
# AST rule fixtures
# --------------------------------------------------------------------------- #
def test_ast_time_time():
    bad = "import time\nt0 = time.time()\n"
    assert _rules(lint_source(bad, "x.py")) == ["time-time"]
    aliased = "from time import time as now\nt0 = now()\n"
    assert _rules(lint_source(aliased, "x.py")) == ["time-time"]
    clean = "import time\nt0 = time.perf_counter()\n"
    assert lint_source(clean, "x.py", rules=("time-time",)) == []


def test_ast_prng_reuse_two_consumers():
    bad = textwrap.dedent("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a, b
    """)
    findings = lint_source(bad, "x.py", rules=("prng-reuse",))
    assert len(findings) == 1 and "two consumers" in findings[0].message


def test_ast_prng_reuse_branch_and_early_return_clean():
    clean = textwrap.dedent("""
        import jax
        def f(flag):
            key = jax.random.PRNGKey(0)
            if flag:
                return jax.random.normal(key, (3,))
            return jax.random.uniform(key, (3,))

        def g(flag):
            key = jax.random.PRNGKey(0)
            if flag:
                a = jax.random.normal(key, (3,))
            else:
                a = jax.random.uniform(key, (3,))
            return a
    """)
    assert lint_source(clean, "x.py", rules=("prng-reuse",)) == []


def test_ast_prng_reuse_in_loop():
    bad = textwrap.dedent("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            outs = []
            for i in range(3):
                outs.append(jax.random.normal(key, (3,)))
            return outs
    """)
    findings = lint_source(bad, "x.py", rules=("prng-reuse",))
    assert len(findings) == 1 and "inside a loop" in findings[0].message


def test_ast_prng_reuse_fold_in_clean():
    clean = textwrap.dedent("""
        import jax
        def f():
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(jax.random.fold_in(key, 0), (3,))
            b = jax.random.uniform(jax.random.fold_in(key, 1), (3,))
            return a, b
    """)
    assert lint_source(clean, "x.py", rules=("prng-reuse",)) == []


def test_ast_host_sync_in_jit():
    bad = textwrap.dedent("""
        import jax, numpy as np
        @jax.jit
        def step(x):
            return np.asarray(x).sum()
    """)
    findings = lint_source(bad, "x.py", rules=("host-sync-in-jit",))
    assert len(findings) == 1 and "np.asarray" in findings[0].message

    wrapped = textwrap.dedent("""
        import jax
        def step(x):
            return x.item()
        step_j = jax.jit(step)
    """)
    findings = lint_source(wrapped, "x.py", rules=("host-sync-in-jit",))
    assert len(findings) == 1 and "item" in findings[0].message

    clean = textwrap.dedent("""
        import numpy as np
        def host_side(x):
            return np.asarray(x).sum()
    """)
    assert lint_source(clean, "x.py", rules=("host-sync-in-jit",)) == []


def test_ast_mutable_default():
    bad = "def f(x, acc=[], *, m=dict()):\n    return acc, m\n"
    findings = lint_source(bad, "x.py", rules=("mutable-default",))
    assert len(findings) == 2
    clean = "def f(x, acc=None):\n    return acc\n"
    assert lint_source(clean, "x.py", rules=("mutable-default",)) == []


# --------------------------------------------------------------------------- #
# Suppressions
# --------------------------------------------------------------------------- #
def test_suppression_requires_justification(tmp_path):
    bare = tmp_path / "s.toml"
    bare.write_text('[[suppress]]\nrule = "time-time"\n'
                    'path = "src/repro/x.py"\n')
    with pytest.raises(SuppressionError, match="justification"):
        load_suppressions(bare)


def test_suppression_match_and_unused(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    mod = tmp_path / "src" / "repro" / "m.py"
    mod.write_text("import time\nstamp = time.time()\nt0 = time.time()\n")
    findings = [
        Finding("time-time", "src/repro/m.py:2", "wall clock"),
        Finding("time-time", "src/repro/m.py:3", "wall clock"),
    ]
    sups = [
        Suppression(rule="time-time", path="src/repro/m.py",
                    justification="intentional stamp", match="stamp ="),
        Suppression(rule="time-time", path="src/repro/other.py",
                    justification="stale entry"),
    ]
    kept, unused = filter_findings(findings, sups, tmp_path)
    assert [f.where for f in kept] == ["src/repro/m.py:3"]
    assert [s.path for s in unused] == ["src/repro/other.py"]


def test_checked_in_suppressions_are_valid_and_used():
    sups = load_suppressions(
        ROOT / "src" / "repro" / "analysis" / "suppressions.toml")
    assert sups, "the repo ships justified suppressions"
    assert all(s.justification.strip() for s in sups)


# --------------------------------------------------------------------------- #
# CLI: exit codes 0/1/2
# --------------------------------------------------------------------------- #
def _cli(*argv, cwd=None, timeout=120):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, cwd=cwd or ROOT, timeout=timeout,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})


def test_cli_clean_tree_ast_pass_exits_zero():
    r = _cli("--ast-only")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 gating finding(s)" in r.stdout


def test_cli_unknown_rule_exits_two():
    r = _cli("--rules", "nonsense")
    assert r.returncode == 2
    assert "unknown rule" in r.stderr


def test_cli_seeded_tree_exits_one_and_baseline_forgives(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "bad.py").write_text(
        "import time\n\ndef f(acc=[]):\n    return time.time(), acc\n")
    sup = tmp_path / "empty.toml"
    sup.write_text("")

    r = _cli("--ast-only", "--root", str(tmp_path),
             "--suppressions", str(sup))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "time-time" in r.stdout and "mutable-default" in r.stdout

    base = tmp_path / "baseline.json"
    r = _cli("--ast-only", "--root", str(tmp_path), "--suppressions",
             str(sup), "--write-baseline", str(base))
    assert r.returncode == 0
    assert json.loads(base.read_text())["fingerprints"]

    r = _cli("--ast-only", "--root", str(tmp_path), "--suppressions",
             str(sup), "--baseline", str(base))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[baselined]" in r.stdout


def test_cli_bare_suppression_exits_two(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "ok.py").write_text("x = 1\n")
    sup = tmp_path / "s.toml"
    sup.write_text('[[suppress]]\nrule = "time-time"\n'
                   'path = "src/repro/ok.py"\njustification = "  "\n')
    r = _cli("--ast-only", "--root", str(tmp_path),
             "--suppressions", str(sup))
    assert r.returncode == 2, r.stdout + r.stderr
    assert "justification" in r.stderr


def test_cli_unused_suppression_gates(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "ok.py").write_text("x = 1\n")
    sup = tmp_path / "s.toml"
    sup.write_text('[[suppress]]\nrule = "time-time"\n'
                   'path = "src/repro/gone.py"\n'
                   'justification = "file was deleted"\n')
    r = _cli("--ast-only", "--root", str(tmp_path),
             "--suppressions", str(sup))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "unused-suppression" in r.stdout
