"""Shared launcher for multi-virtual-device subprocess tests.

Mesh tests (token-sharded calibration, tensor-parallel serve) need
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, which must be set
before jax initializes — so each test body runs in a fresh subprocess with a
minimal, pinned environment.  Import as ``from _mesh_compat import
run_in_mesh_subprocess`` (pytest puts tests/ on sys.path).
"""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def run_in_mesh_subprocess(code: str, devices: int = 8,
                           timeout: int = 560) -> subprocess.CompletedProcess:
    """Run ``code`` under ``devices`` virtual CPU devices.

    JAX_PLATFORMS must survive into the subprocess: images that ship libtpu
    hang for minutes probing for TPU hardware otherwise.
    """
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "XLA_FLAGS":
                 f"--xla_force_host_platform_device_count={devices}",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
             "HOME": os.environ.get("HOME", "/root")},
        timeout=timeout)
