"""Full DartQuant flow on a trained tiny LM: train -> calibrate -> fuse ->
W4A4 quantize -> compare perplexity against RTN and QuaRot baselines.

    PYTHONPATH=src python examples/calibrate_and_quantize.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import calibrate_model, fuse_rotations, random_pack
from repro.core.rotations import online_hadamard
from repro.data.pipeline import batches, calibration_batch
from repro.models import model as M
from repro.models.common import cross_entropy
from repro.quant import act_quant, fake_quant_act, quantize_params
from repro.train.trainer import Trainer

CFG = get_config("llama2-7b").reduced().replace(
    n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab_size=256)

print("training a tiny llama on the synthetic corpus ...")
tr = Trainer(CFG, batch_size=8, seq_len=64, lr=5e-3)
tr.train(100, verbose=False)
params = tr.params


def ppl(cfg, p, a_bits=16, rot=None):
    b = next(batches(cfg, 8, 64, seed=99))
    toks, labels = jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])

    def run():
        logits, _ = M.forward(cfg, p, toks, rot=rot)
        return cross_entropy(logits, labels)
    if a_bits < 16:
        with act_quant(lambda x: fake_quant_act(x, a_bits)):
            return float(jnp.exp(jax.jit(run)()))
    return float(jnp.exp(jax.jit(run)()))


key = jax.random.PRNGKey(0)
rot = {"r4": online_hadamard}
print(f"fp32 ppl                 : {ppl(CFG, params):8.2f}")
print(f"RTN W4A4 ppl             : {ppl(CFG, quantize_params(CFG, params), 4):8.2f}")

hcfg, hp = fuse_rotations(CFG, params, random_pack(CFG, key))
print(f"QuaRot (Hadamard) W4A4   : {ppl(hcfg, quantize_params(hcfg, hp), 4, rot):8.2f}")

t0 = time.time()
pack = calibrate_model(CFG, params, jnp.asarray(calibration_batch(CFG, 8, 64)),
                       key=key, steps=80, lr_r1=0.05, lr_r2=0.05)
dcfg, dp = fuse_rotations(CFG, params, pack)
print(f"DartQuant W4A4           : {ppl(dcfg, quantize_params(dcfg, dp), 4, rot):8.2f}"
      f"   (calibrated in {time.time()-t0:.1f}s)")
