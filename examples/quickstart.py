"""DartQuant quickstart: calibrate a rotation with Whip + QR-Orth and watch
outliers/quantization error drop.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (calibrate_rotation, outlier_count, quant_error,
                        random_hadamard, whip)

key = jax.random.PRNGKey(0)
n, N = 256, 4096

# Synthetic LLM-like activations: Laplace bulk + heavy outlier channels
# (paper App. G: zero mean, unit variance, kurtosis 40-250).
k1, k2, k3 = jax.random.split(key, 3)
x = jax.random.laplace(k1, (N, n)) * 0.5
outlier_ch = jax.random.choice(k2, n, (8,), replace=False)
x = x.at[:, outlier_ch].multiply(12.0)
x = x / jnp.std(x)

print("DartQuant quickstart — rotational distribution calibration")
print(f"activations: {N} tokens x {n} dims, "
      f"kurtosis={float(jnp.mean((x - x.mean())**4) / jnp.var(x)**2):.0f}")

for name, r in [("identity", jnp.eye(n)),
                ("random Hadamard (QuaRot)", random_hadamard(n, k3))]:
    o = x @ r
    print(f"  {name:26s} quant_err={float(quant_error(o)):8.4f} "
          f"outliers/token={float(outlier_count(o)):6.2f} "
          f"whip={float(whip(o)):7.1f}")

r = calibrate_rotation(x, n, key, objective="whip", method="qr",
                       optimizer="sgd", steps=100, lr=0.2)
o = x @ r
print(f"  {'DartQuant (Whip+QR-Orth)':26s} quant_err={float(quant_error(o)):8.4f} "
      f"outliers/token={float(outlier_count(o)):6.2f} "
      f"whip={float(whip(o)):7.1f}")
print("done — see examples/calibrate_and_quantize.py for the full model flow")
