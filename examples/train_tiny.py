"""Train a small LM for a few hundred steps with checkpointing + fault
tolerance (end-to-end training driver on CPU).

    PYTHONPATH=src python examples/train_tiny.py
"""
import tempfile

from repro.configs import get_config
from repro.train.trainer import Trainer

cfg = get_config("llama2-7b").reduced().replace(
    n_layers=4, d_model=128, d_ff=256, n_heads=4, n_kv_heads=4, head_dim=32,
    vocab_size=512)

with tempfile.TemporaryDirectory() as d:
    tr = Trainer(cfg, batch_size=8, seq_len=64, lr=3e-3, ckpt_dir=d,
                 ckpt_every=50)
    hist = tr.train(200, log_every=50)
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"straggler events: {len(tr.monitor.events)}")
