"""Quantize-once → serve-from-artifact: the production deployment flow.

Step 1 runs DartQuant calibration once, folds R1/R2 into the weights, packs
every projection to int4 QTensors (fp16 scales), and writes a hash-verified
QuantArtifact.  Step 2 cold-boots the paged int4-KV runtime from that
artifact — packed weights straight onto the device through the Pallas
quant_matmul kernel, online R3/R4 resolved from the fused-rotation metadata,
and zero calls into the calibration stack.

Every decoder-only family serves through the same paged runtime: dense/MoE/
mixed GQA stacks on int4 KV pages, MLA (deepseek-v3) on quantized latent
pages, SSM (mamba2) and hybrid (zamba2) on int8 state slots — one token-level
continuous-batching scheduler for all of them (swap --arch below to try one;
the legacy lockstep engine survives only for encoder-decoder models).

Both steps write observability artifacts (``repro.obs``): the quantize pass
snapshots per-site calibration losses + QDQ health, the serve pass snapshots
TTFT/ITL histograms, page occupancy and prefix-cache counters — the metrics
summary printed at the end comes straight from those Prometheus textfiles.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import os
import tempfile

from repro.launch.quantize import main as quantize
from repro.launch.serve import main as serve

with tempfile.TemporaryDirectory() as artifact_dir:
    calib_prom = os.path.join(artifact_dir, "calibrate.prom")
    serve_prom = os.path.join(artifact_dir, "serve.prom")
    quantize(["--arch", "llama2-7b", "--steps", "20", "--a-bits", "8",
              "--kv-bits", "4", "--out", artifact_dir,
              "--metrics-out", calib_prom])
    serve(["--artifact", artifact_dir, "--requests", "8", "--slots", "4",
           "--prompt-len", "12", "--max-new", "12", "--page-size", "8",
           "--metrics-out", serve_prom])

    print("\n--- metrics snapshot (Prometheus textfile excerpts) ---")
    for label, path in (("quantize", calib_prom), ("serve", serve_prom)):
        with open(path) as f:
            lines = [ln.rstrip() for ln in f
                     if not ln.startswith("#") and "_bucket" not in ln]
        print(f"[{label}] {len(lines)} series:")
        for ln in lines:
            print(f"  {ln}")
