"""End-to-end serving driver: batched requests against a DartQuant W4A8KV4
model with continuous batching (the repo's 'serve a small model with batched
requests' deliverable).

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

main(["--arch", "llama2-7b", "--requests", "8", "--slots", "4",
      "--prompt-len", "12", "--max-new", "12", "--a-bits", "8",
      "--kv-bits", "4"])
