"""End-to-end serving driver: batched requests against a DartQuant W4A8KV4
model on the paged int4-KV runtime — page-pool cache, token-level continuous
batching with chunked prefill, Pallas paged attention, and the Pallas WHT
kernel as the online R3/R4 rotation.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from repro.launch.serve import main

main(["--arch", "llama2-7b", "--engine", "paged", "--requests", "8",
      "--slots", "4", "--prompt-len", "12", "--max-new", "12",
      "--page-size", "8", "--a-bits", "8", "--kv-bits", "4"])
