"""Scan-aware HLO cost analyzer for the roofline report.

XLA's built-in ``cost_analysis()`` counts a ``while`` (lax.scan) body ONCE —
useless for scan-over-layers models.  This module parses the *post-SPMD
optimized* HLO text (``compiled.as_text()``, i.e. the per-device program),
builds the computation call graph, extracts while-loop trip counts, and
accumulates with multipliers:

  * dot FLOPs (2 * prod(out) * prod(contracting))        -> compute term
  * dot operand+output bytes (HBM traffic lower bound)   -> memory term
  * collective payload bytes by op kind                  -> collective term

All quantities are PER DEVICE (the partitioned module is the per-device
program), which is exactly what the roofline wants.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_ATTR_RE = re.compile(
    r"(?:to_apply|calls|body|condition|branch_computations)=\{?%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(dt_dims: Tuple[str, str]) -> int:
    dims = dt_dims[1]
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if ((line.startswith("%") or line.startswith("ENTRY"))
                and "(" in line and line.rstrip().endswith("{")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is not None and stripped and stripped != "}":
            cur.lines.append(stripped)
        if not line.startswith(" ") and stripped == "}":
            cur = None
    return comps


def _trip_count(cond: Computation) -> int:
    """Max s32 constant in the while condition ~ scan trip count."""
    best = 1
    for line in cond.lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def compute_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}

    def visit(comp: Computation, m: float):
        if mult.get(comp.name, 0) >= m and comp.name in mult:
            # keep the max multiplier path (a computation reached twice)
            pass
        mult[comp.name] = max(mult.get(comp.name, 0.0), m)
        for line in comp.lines:
            cb = _COND_BODY_RE.search(line)
            if cb and " while(" in line:
                cond_name, body_name = cb.group(1), cb.group(2)
                cond = comps.get(cond_name)
                body = comps.get(body_name)
                trips = _trip_count(cond) if cond else 1
                if cond:
                    visit(cond, m * trips)
                if body:
                    visit(body, m * trips)
                continue
            for cal in _CALL_ATTR_RE.findall(line):
                child = comps.get(cal)
                if child and child.name != comp.name:
                    visit(child, m)

    visit(entry, 1.0)
    return mult


_DEF_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[\d,]*\})?)")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _first_shape(type_str: str):
    m = _SHAPE_RE.findall(type_str)
    return m[0] if m else None


def _symbol_table(header_and_lines: List[str]) -> Dict[str, str]:
    """Map value name -> type string (params + op results)."""
    table: Dict[str, str] = {}
    for line in header_and_lines:
        d = _DEF_RE.match(line)
        if d:
            table[d.group(1)] = d.group(2)
    return table


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = split_computations(hlo)
    mult = compute_multipliers(comps)
    flops = 0.0
    dot_bytes = 0.0
    coll_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    coll_count = 0

    # global symbol table: names are unique module-wide in optimized HLO
    sym: Dict[str, str] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if d:
                sym[d.group(1)] = line.split("=", 1)[1]
        if name != "__entry__":
            pass
    # parameters appear in headers; re-scan raw text headers for param types
    for line in hlo.splitlines():
        if (line.startswith("%") or line.startswith("ENTRY")) and \
                line.rstrip().endswith("{"):
            for pname, ptype in _PARAM_RE.findall(line):
                sym.setdefault(pname, ptype)

    def operand_types(operand_str: str) -> List[str]:
        return [sym.get(n, "") for n in _OPERAND_NAME_RE.findall(operand_str)]

    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0)
        for line in comp.lines:
            if " dot(" in line:
                d = _DEF_RE.match(line)
                if not d:
                    continue
                out_type = d.group(2)
                osh = _first_shape(out_type)
                if not osh:
                    continue
                out_elems = _shape_elems(osh)
                operand_str = line[line.index("dot(") + 4:].split(")", 1)[0]
                ops = operand_types(operand_str)
                csize = 1
                cm = _CONTRACT_RE.search(line)
                if cm and ops:
                    lsh = _first_shape(ops[0])
                    lhs_dims = lsh[1].split(",") if (lsh and lsh[1]) else []
                    for dd in (cm.group(1).split(",") if cm.group(1) else []):
                        if dd and int(dd) < len(lhs_dims):
                            csize *= int(lhs_dims[int(dd)])
                flops += m * 2.0 * out_elems * csize
                dot_bytes += m * (sum(_shape_bytes(t) for t in ops)
                                  + _shape_bytes(out_type))
                continue
            for kind in _COLLECTIVES:
                token = f" {kind}(" if f" {kind}(" in line else (
                    f" {kind}-start(" if f" {kind}-start(" in line else None)
                if token:
                    d = _DEF_RE.match(line)
                    out_type = d.group(2) if d else ""
                    idx = line.index(token) + len(token)
                    operand_str = line[idx:].split(")", 1)[0]
                    op_bytes = sum(_shape_bytes(t)
                                   for t in operand_types(operand_str))
                    out_b = _shape_bytes(out_type)
                    if kind == "all-gather":
                        payload = out_b                      # receive n-1 shards
                    elif kind == "all-reduce":
                        payload = 2 * op_bytes               # reduce + broadcast
                    else:                                    # rs / a2a / permute
                        payload = op_bytes
                    coll_bytes[kind] += m * payload
                    coll_count += int(m)
                    break

    return {
        "dot_flops": flops,
        "dot_bytes": dot_bytes,
        "collective_bytes": sum(coll_bytes.values()),
        "collective_by_kind": coll_bytes,
        "collective_count": coll_count,
    }


def top_collectives(hlo: str, k: int = 12) -> List[Tuple[float, str]]:
    """The §Perf 'profile': largest collectives (bytes x multiplier) w/ shapes."""
    comps = split_computations(hlo)
    mult = compute_multipliers(comps)
    sym: Dict[str, str] = {}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if d:
                sym[d.group(1)] = line.split("=", 1)[1]
    items = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 1.0)
        for line in comp.lines:
            for kind in _COLLECTIVES:
                token = f" {kind}(" if f" {kind}(" in line else (
                    f" {kind}-start(" if f" {kind}-start(" in line else None)
                if token:
                    d = _DEF_RE.match(line)
                    out_type = d.group(2) if d else "?"
                    idx = line.index(token) + len(token)
                    operand_str = line[idx:].split(")", 1)[0]
                    names_ = _OPERAND_NAME_RE.findall(operand_str)
                    op_b = sum(_shape_bytes(sym.get(n_, "")) for n_ in names_)
                    out_b = _shape_bytes(out_type)
                    payload = out_b if kind == "all-gather" else (
                        2 * op_b if kind == "all-reduce" else op_b)
                    meta = ""
                    mm = re.search(r'op_name="([^"]*)"', line)
                    if mm:
                        meta = mm.group(1)[-70:]
                    items.append((m * payload,
                                  f"{kind} x{int(m)} {out_type[:48]} :: {meta}"))
                    break
    items.sort(reverse=True)
    return items[:k]


# --------------------------------------------------------------------------- #
# Roofline terms (TPU v5e per chip)
# --------------------------------------------------------------------------- #
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link


def roofline_terms(stats: Dict[str, float]) -> Dict[str, float]:
    t_compute = stats["dot_flops"] / PEAK_FLOPS
    t_memory = stats["dot_bytes"] / HBM_BW
    t_coll = stats["collective_bytes"] / ICI_BW
    terms = {"t_compute": t_compute, "t_memory": t_memory,
             "t_collective": t_coll}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom
    total = max(t_compute, t_memory, t_coll)
    terms["roofline_fraction"] = t_compute / total if total > 0 else 0.0
    return terms
