"""Quantization-health taps at the QDQ hooks in ``repro.quant.quantizers``.

Two gauges of quantizer fit, sampled wherever codes are produced:

  * **clip rate** — fraction of codes landing on the extreme code points
    (``0``/``qmax`` asymmetric, ``-qmax-1``/``qmax`` symmetric).  A healthy
    absmax/min-max quantizer pins a sliver of mass at the boundary; a
    saturating one (massive activations the rotation failed to smooth, cf.
    DFRot) pins a lot.
  * **scale dynamic range** — ``log2(max(scale) / min(scale))`` across the
    tensor's quantization groups.  Rotation calibration exists to shrink
    exactly this spread; watching it at the QDQ hooks makes the paper's
    distribution claims measurable in-repo.

The tap is **armed at trace time**: ``quant_act``/``quant_weight`` call
``tap(...)``, which returns immediately while ``_TAP`` is None — nothing is
inserted into the traced program, so the disabled path (the default) adds no
callback, no host sync, and no compiled-code difference.  When armed (the
launch CLIs arm it behind ``--metrics-out``), the statistics are reduced to
two scalars on device and shipped to the registry via ``jax.debug.callback``
— jit/scan/vmap safe, paid only by runs that asked for it.  Programs traced
while armed keep their callbacks; arm/disarm around a region rather than
around long-lived engines.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from repro.obs.metrics import MetricsRegistry

__all__ = ["arm", "disarm", "armed", "tap", "sampling",
           "disarmed_callback_contract"]

_TAP: Optional[MetricsRegistry] = None

# clip rate lives in [0, 1]; dynamic range in log2 octaves
CLIP_BUCKETS = (0.0, 1e-4, 1e-3, 1e-2, 0.05, 0.1, 0.25, 0.5, 1.0)
DYNRANGE_BUCKETS = (0.5, 1.0, 2.0, 4.0, 6.0, 8.0, 12.0, 16.0, 24.0, 32.0)


def arm(registry: MetricsRegistry) -> None:
    """Publish QDQ health samples into ``registry`` for code traced from
    now on (module-global: one registry at a time)."""
    global _TAP
    _TAP = registry


def disarm() -> None:
    global _TAP
    _TAP = None


def armed() -> bool:
    return _TAP is not None


@contextmanager
def sampling(registry: MetricsRegistry):
    """Arm the tap for a region (and any jit tracing inside it)."""
    global _TAP
    prev = _TAP
    _TAP = registry
    try:
        yield registry
    finally:
        _TAP = prev


def disarmed_callback_contract(name: str, trace, *,
                               owner: str = "repro.obs.quant_health"):
    """The disarmed-observability guarantee, declared at the seam that owns
    the only sanctioned host callback: a program traced while the
    quant-health tap is disarmed must contain ZERO host-callback equations
    (``debug_callback``/``io_callback``/``pure_callback``) — a smuggled
    callback syncs the device every step for runs that never asked for
    observability.

    ``trace`` is a thunk returning the program's ``ClosedJaxpr``; the
    returned ``Contract`` refuses to trace while armed (the contract is
    about the disarmed path, and an armed trace would legitimately carry
    callbacks)."""
    from repro.analysis.rules import Contract, HostCallbackCount

    def checked_trace():
        if armed():
            raise RuntimeError(
                f"contract {name!r} asserts the disarmed path but the "
                "quant-health tap is armed; disarm() before tracing")
        return trace()

    return Contract(
        name=name, owner=owner,
        checks=(HostCallbackCount(expect=0),), trace=checked_trace,
        description="zero host callbacks in any program traced with "
                    "observability disarmed")


def _record(kind: str, clip_rate, dyn_range):
    reg = _TAP
    if reg is None:        # disarmed after tracing: drop the sample
        return
    reg.histogram(f"quant_{kind}_clip_rate", buckets=CLIP_BUCKETS,
                  help="fraction of codes at the extreme code points"
                  ).observe(float(clip_rate))
    reg.histogram(f"quant_{kind}_scale_dynamic_range_log2",
                  buckets=DYNRANGE_BUCKETS,
                  help="log2(max/min) of the tensor's quantization scales"
                  ).observe(float(dyn_range))
    reg.gauge(f"quant_{kind}_clip_rate_last").set(float(clip_rate))
    reg.gauge(f"quant_{kind}_scale_dynamic_range_log2_last").set(
        float(dyn_range))
    reg.counter(f"quant_{kind}_samples_total").inc()


def tap(kind: str, q, scale, bits: int, symmetric: bool) -> None:
    """Sample one quantization event.  ``q`` are the (pre-cast) codes,
    ``scale`` the per-group scales.  No-op unless armed at trace time."""
    if _TAP is None:
        return
    import jax
    import jax.numpy as jnp
    if symmetric:
        qmax = 2 ** (bits - 1) - 1
        lo_code, hi_code = -qmax - 1, qmax
    else:
        lo_code, hi_code = 0, 2 ** bits - 1
    clip = jnp.mean(((q <= lo_code) | (q >= hi_code))
                    .astype(jnp.float32))
    s = scale.astype(jnp.float32)
    dyn = jnp.log2(jnp.max(s) / jnp.maximum(jnp.min(s), 1e-30))
    jax.debug.callback(lambda c, d: _record(kind, c, d), clip, dyn)
