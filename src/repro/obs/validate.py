"""Validate serve observability artifacts (the CI smoke's parser).

  PYTHONPATH=src python -m repro.obs.validate \\
      --trace /tmp/trace.jsonl --metrics /tmp/metrics.prom \\
      --bench /tmp/BENCH_serve_bench.json

Checks that the JSONL span log parses and satisfies the event schema
(``repro.obs.trace.EVENT_FIELDS``) with a complete request lifecycle
present, that the Prometheus snapshot parses and contains the serve
stack's required metric families, and that ``BENCH_*.json`` benchmark
reports carry a complete environment fingerprint plus well-formed records
(repeats >= 1, non-empty units, ordered quartiles).  Exits non-zero with a
reason on any failure — wiring it after a serve/bench run turns
"observability emits something" into a hard CI assertion.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Set

from repro.obs.bench import BenchReport, read_bench_json
from repro.obs.trace import read_trace, validate_trace

# metric families every traced+metered serve run must publish
REQUIRED_SERVE_METRICS = (
    "serve_ttft_seconds",
    "serve_itl_seconds",
    "serve_decode_step_seconds",
    "serve_prefill_seconds",
    "serve_queue_seconds",
    "serve_prompt_tokens_total",
    "serve_prefix_hit_tokens_total",
    "serve_preemptions_total",
    "serve_cow_copies_total",
    "serve_pages_free",
    "serve_pages_shared",
)
# the lifecycle a non-empty serve trace must contain
REQUIRED_SERVE_EVENTS = {"enqueue", "admit", "first_token", "decode_step",
                         "finish"}


def parse_prom(path: str) -> Set[str]:
    """Parse a Prometheus text snapshot; returns the set of metric names
    (histogram series collapse to their family name)."""
    names: Set[str] = set()
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            if line.startswith("#"):
                parts = line.split()
                if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                    raise ValueError(f"{path}:{i + 1}: bad comment line")
                continue
            body = line.split()
            if len(body) != 2:
                raise ValueError(f"{path}:{i + 1}: expected 'name value'")
            float(body[1])                       # value must parse
            name = body[0].split("{")[0]
            for suffix in ("_bucket", "_sum", "_count"):
                if name.endswith(suffix):
                    name = name[: -len(suffix)]
                    break
            names.add(name)
    if not names:
        raise ValueError(f"{path}: no metrics found")
    return names


def check_trace(path: str) -> List[dict]:
    events = read_trace(path)
    validate_trace(events, require=REQUIRED_SERVE_EVENTS)
    finishes = [e for e in events if e["event"] == "finish"]
    bad = [e for e in finishes if e["ttft_s"] < 0 or e["n_tokens"] < 1]
    if bad:
        raise ValueError(f"finish events with impossible payloads: {bad[:3]}")
    rids = {e["rid"] for e in events if "rid" in e}
    unfinished = rids - {e["rid"] for e in finishes}
    if unfinished:
        raise ValueError(f"requests never finished: {sorted(unfinished)}")
    return events


# fingerprint keys every BENCH report must carry (repro.obs.bench emits
# more; these are the ones compare + humans depend on)
REQUIRED_BENCH_FINGERPRINT = ("jax", "backend", "device_kind",
                              "device_count", "cpu_count", "git_sha",
                              "smoke")


def check_bench(path: str) -> BenchReport:
    """Schema-check one ``BENCH_<module>.json`` report.

    ``read_bench_json`` already enforces the record invariants (non-empty
    name/unit, repeats >= 1) at construction; this adds the artifact-level
    checks: a complete fingerprint, at least one record, and internally
    consistent quartiles.
    """
    report = read_bench_json(path)
    fp = report.fingerprint or {}
    missing = [k for k in REQUIRED_BENCH_FINGERPRINT if k not in fp]
    if missing:
        raise ValueError(f"{path}: fingerprint missing {missing}")
    if not report.records:
        raise ValueError(f"{path}: report has no records")
    for rec in report.records:
        quartiles = (rec.q25, rec.median, rec.q75)
        if any(q is not None for q in quartiles):
            if any(q is None for q in quartiles):
                raise ValueError(f"{path}: record {rec.name!r} has partial "
                                 f"quartiles {quartiles}")
            if not (rec.q25 <= rec.median <= rec.q75):
                raise ValueError(f"{path}: record {rec.name!r} has "
                                 f"disordered quartiles {quartiles}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", default=None, help="JSONL span log to check")
    ap.add_argument("--metrics", default=None,
                    help="Prometheus textfile snapshot to check")
    ap.add_argument("--bench", action="append", default=[], metavar="JSON",
                    help="BENCH_<module>.json report to check (repeatable)")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics and not args.bench:
        ap.error("nothing to validate: pass --trace, --metrics and/or "
                 "--bench")
    try:
        if args.trace:
            events = check_trace(args.trace)
            n_req = len({e["rid"] for e in events if "rid" in e})
            print(f"[obs.validate] trace OK: {len(events)} events, "
                  f"{n_req} requests, all finished")
        if args.metrics:
            names = parse_prom(args.metrics)
            missing = [n for n in REQUIRED_SERVE_METRICS if n not in names]
            if missing:
                raise ValueError(f"metrics snapshot missing {missing}")
            print(f"[obs.validate] metrics OK: {len(names)} families, "
                  f"all {len(REQUIRED_SERVE_METRICS)} required present")
        for path in args.bench:
            report = check_bench(path)
            print(f"[obs.validate] bench OK: {report.module}, "
                  f"{len(report.records)} records, fingerprint complete")
    except (ValueError, KeyError, TypeError, OSError) as e:
        print(f"[obs.validate] FAIL: {e!r}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
