"""Zero-dependency metrics registry: counters, gauges, histograms.

One ``MetricsRegistry`` is the single metrics surface of a serve engine or a
calibration run: the scheduler's admission/preemption counters, the page
pool's occupancy gauges, the engines' step-timing histograms and the
calibration engine's per-site loss gauges all publish here.  Everything is
plain host-side Python arithmetic — no device work, no host sync, no
dependency beyond the standard library — so collection is always on and
effectively free; only *tracing* (``repro.obs.trace``) and *profiling*
(``repro.obs.obs``) are opt-in.

Metric families follow the Prometheus data model:

  Counter     monotone float; ``inc(n)``.  Cumulative over the registry's
              lifetime — per-call deltas are the caller's job (the scheduler
              snapshots at construction for its ``counters()`` compat view).
  Gauge       last-write value via ``set(v)``, or a live callable via
              ``set_fn(fn)`` (evaluated at render/snapshot time — used for
              page-pool occupancy and queue depth, which would otherwise
              need a write on every mutation).
  Histogram   fixed bucket boundaries chosen at creation; ``observe(v)``
              updates bucket counts, sum, count, exact min/max.  Percentiles
              (``percentile(q)``) interpolate linearly inside the selected
              bucket, with the exact observed min/max clamping the open-ended
              edge buckets — so p50/p95/p99 TTFT and inter-token latency come
              straight from the registry with bounded error (one bucket
              width), no sample retention.

Metrics are keyed by ``(name, labels)``; re-requesting an existing key
returns the same object (the idiomatic ``registry.counter("x").inc()`` call
sites need no pre-registration), and a name can only ever hold one metric
type.  ``render_prom()`` emits the Prometheus text exposition format —
``write_prom(path)`` is the textfile-collector snapshot the launch CLIs
write behind ``--metrics-out``.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Iterable, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS",
]

# log-spaced 100us .. 60s: covers a fused decode step on a TPU through a
# cold-compile prefill on the CPU CI box
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[dict]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Labels) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + body + "}"


class Counter:
    """Monotone cumulative counter (floats allowed: seconds totals)."""
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Labels = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n


class Gauge:
    """Last-write gauge, or a live view over a callable (``set_fn``)."""
    __slots__ = ("name", "labels", "_value", "_fn")

    def __init__(self, name: str, labels: Labels = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        self._fn = None
        self._value = float(v)

    def set_fn(self, fn: Callable[[], float]) -> None:
        """Collect-time gauge: ``fn`` is evaluated at read (replaces any
        previous fn/value — a new scheduler re-binds the queue-depth gauge)."""
        self._fn = fn

    @property
    def value(self) -> float:
        return float(self._fn()) if self._fn is not None else self._value


class Histogram:
    """Fixed-boundary histogram with exact count/sum/min/max.

    ``counts[i]`` holds observations in ``(bounds[i-1], bounds[i]]``;
    ``counts[-1]`` is the overflow bucket above ``bounds[-1]``.
    """
    __slots__ = ("name", "labels", "bounds", "counts", "count", "sum",
                 "_min", "_max")

    def __init__(self, name: str, labels: Labels = (),
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"bucket bounds must strictly increase: {bounds}")
        self.name = name
        self.labels = labels
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]): linear interpolation inside
        the bucket holding the target rank; exact min/max clamp the
        open-ended edge buckets.  Error is bounded by one bucket width."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self._min if i == 0 \
                    else max(self.bounds[i - 1], self._min)
                hi = self._max if i == len(self.bounds) \
                    else min(self.bounds[i], self._max)
                if hi < lo:
                    hi = lo
                return lo + (hi - lo) * ((target - cum) / c)
            cum += c
        return self._max


class MetricsRegistry:
    """The one metrics surface: name+labels -> Counter/Gauge/Histogram."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Labels], object] = {}
        self._types: Dict[str, type] = {}
        self._help: Dict[str, str] = {}

    # ------------------------------------------------------------- accessors
    def _get(self, cls, name: str, labels: Optional[dict], help: str = "",
             **kw):
        known = self._types.get(name)
        if known is not None and known is not cls:
            raise TypeError(f"metric {name!r} is a {known.__name__}, "
                            f"requested as {cls.__name__}")
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], **kw)
            self._metrics[key] = m
            self._types[name] = cls
            if help:
                self._help[name] = help
        return m

    def counter(self, name: str, labels: Optional[dict] = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, labels: Optional[dict] = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, labels: Optional[dict] = None,
                  help: str = "",
                  buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, labels, help, buckets=buckets)

    def value(self, name: str, labels: Optional[dict] = None) -> float:
        """Current value of a counter/gauge (KeyError when absent)."""
        m = self._metrics[(name, _labels_key(labels))]
        if isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a histogram; read its fields")
        return m.value

    def names(self):
        return sorted({name for name, _ in self._metrics})

    # -------------------------------------------------------------- exports
    def snapshot(self) -> Dict[str, float]:
        """Flat dict view (histograms expand to _count/_sum/_p50/p95/p99)."""
        out: Dict[str, float] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            tag = name + _fmt_labels(labels)
            if isinstance(m, Histogram):
                out[tag + "_count"] = m.count
                out[tag + "_sum"] = m.sum
                for q in (0.5, 0.95, 0.99):
                    out[tag + f"_p{int(q * 100)}"] = m.percentile(q)
            else:
                out[tag] = m.value
        return out

    def render_prom(self) -> str:
        """Prometheus text exposition format (textfile-collector snapshot)."""
        by_name: Dict[str, list] = {}
        for (name, _), m in sorted(self._metrics.items()):
            by_name.setdefault(name, []).append(m)
        lines = []
        for name, ms in by_name.items():
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(ms[0])]
            if name in self._help:
                lines.append(f"# HELP {name} {self._help[name]}")
            lines.append(f"# TYPE {name} {kind}")
            for m in ms:
                if isinstance(m, Histogram):
                    cum = 0
                    for b, c in zip(m.bounds, m.counts):
                        cum += c
                        lbl = _fmt_labels(m.labels + (("le", f"{b:g}"),))
                        lines.append(f"{name}_bucket{lbl} {cum}")
                    lbl = _fmt_labels(m.labels + (("le", "+Inf"),))
                    lines.append(f"{name}_bucket{lbl} {m.count}")
                    base = _fmt_labels(m.labels)
                    lines.append(f"{name}_sum{base} {m.sum:g}")
                    lines.append(f"{name}_count{base} {m.count}")
                else:
                    lines.append(
                        f"{name}{_fmt_labels(m.labels)} {m.value:g}")
        return "\n".join(lines) + "\n"

    def write_prom(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.render_prom())
