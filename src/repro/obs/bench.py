"""``repro.obs.bench`` — structured benchmark telemetry + regression gates.

The benchmark harness (``benchmarks/run.py``) historically printed one-shot
``name,value,unit`` CSV to stdout: human-readable, but invisible to CI — a PR
could halve decode tok/s and nothing would notice.  This module makes every
benchmark run a comparable, fingerprinted record:

  ``BenchRecord``   one metric: name/value/unit plus the measurement
                    discipline that produced it (warmup count, repeats,
                    median + inter-quartile range over the repeats).
                    Single-shot deterministic metrics (byte counts, token
                    counts) carry ``repeats=1`` and no IQR.
  ``BenchReport``   one benchmark module's records + an environment
                    fingerprint (jax/jaxlib version, backend, device kind,
                    device count, cpu count, git sha, smoke flag) so two
                    reports are only ever compared apples-to-apples.
  ``write_bench_json`` / ``read_bench_json``
                    the ``BENCH_<module>.json`` artifact convention — the
                    machine-readable perf trajectory CI uploads per run.
  ``measure`` / ``record_from_samples``
                    warmup+repeat timing helpers (``time.perf_counter``
                    only — wall clock is NTP-steppable) for the hot-path
                    benchmarks.
  ``compare``       the regression gate: ``python -m repro.obs.bench
                    compare baseline.json current.json`` exits non-zero when
                    a tracked metric regresses beyond its per-metric
                    tolerance — IQR-aware for timing/throughput metrics
                    (overlapping quartile ranges are noise, not regression),
                    strict equality for deterministic byte/count metrics.

Unit policy — the unit string decides how a metric is compared:

  strict (exact equality; any drift fails)
      B tok pages seqs devices steps flops flops_per_step count
  lower-is-better, tolerance + IQR gated
      s ms us us_per_step  (timings) and ppl mse abs % per_token whip (quality)
  higher-is-better, tolerance + IQR gated
      tok_per_s req_per_s flops_per_s x ratio tok_per_B

Unknown units are reported but never gate (forward compatibility: a new
benchmark row must not break the baseline comparison that predates it).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import platform
import statistics
import subprocess
import sys
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "BenchRecord", "BenchReport", "env_fingerprint", "write_bench_json",
    "read_bench_json", "measure", "record_from_samples", "publish_report",
    "compare_reports", "device_peaks", "peak_memory_bytes",
    "STRICT_UNITS", "TIME_UNITS", "QUALITY_UNITS", "RATE_UNITS",
]

SCHEMA_VERSION = 1

# --------------------------------------------------------------------------- #
# unit policy
# --------------------------------------------------------------------------- #
# deterministic byte/count metrics: same code + same config => same value
STRICT_UNITS = frozenset({"B", "tok", "pages", "seqs", "devices", "steps",
                          "flops", "flops_per_step", "count"})
# timings: lower is better, noisy on shared CPU runners -> tolerance + IQR
TIME_UNITS = frozenset({"s", "ms", "us", "us_per_step"})
# quality metrics: lower is better, float-noise tolerant
QUALITY_UNITS = frozenset({"ppl", "mse", "abs", "%", "per_token", "whip"})
# throughput/speedup/utilization: higher is better
RATE_UNITS = frozenset({"tok_per_s", "req_per_s", "flops_per_s", "x",
                        "ratio", "tok_per_B"})

# below this magnitude a relative comparison is undefined (zero baseline)
_ABS_FLOOR = 1e-12

# fingerprint keys that must MATCH for a comparison to be meaningful; the
# rest (git sha, jax version, device count...) are reported, not enforced
_FINGERPRINT_GATES = ("smoke", "backend")
_FINGERPRINT_KEYS = ("jax", "jaxlib", "backend", "device_kind",
                     "device_count", "cpu_count", "git_sha", "smoke")


# --------------------------------------------------------------------------- #
# records + reports
# --------------------------------------------------------------------------- #
@dataclass
class BenchRecord:
    """One benchmark metric and the discipline that produced it.

    ``value`` is the headline number (the median when ``repeats > 1``).
    ``q25``/``median``/``q75`` summarize the repeat distribution; they are
    ``None`` for single-shot records (deterministic counts, derived ratios).
    """
    name: str
    value: float
    unit: str
    repeats: int = 1
    warmup: int = 0
    q25: Optional[float] = None
    median: Optional[float] = None
    q75: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("record name must be non-empty")
        if not self.unit:
            raise ValueError(f"record {self.name!r}: unit must be non-empty")
        if self.repeats < 1:
            raise ValueError(f"record {self.name!r}: repeats must be >= 1")
        self.value = float(self.value)

    @property
    def iqr(self) -> Optional[float]:
        if self.q25 is None or self.q75 is None:
            return None
        return self.q75 - self.q25

    def to_dict(self) -> dict:
        return {"name": self.name, "value": self.value, "unit": self.unit,
                "repeats": self.repeats, "warmup": self.warmup,
                "q25": self.q25, "median": self.median, "q75": self.q75}

    @classmethod
    def from_dict(cls, d: dict) -> "BenchRecord":
        return cls(name=d["name"], value=d["value"], unit=d["unit"],
                   repeats=int(d.get("repeats", 1)),
                   warmup=int(d.get("warmup", 0)),
                   q25=d.get("q25"), median=d.get("median"),
                   q75=d.get("q75"))


@dataclass
class BenchReport:
    """All of one benchmark module's records + the environment fingerprint."""
    module: str
    fingerprint: dict
    records: List[BenchRecord] = field(default_factory=list)

    def add(self, rec: BenchRecord) -> BenchRecord:
        self.records.append(rec)
        return rec

    def to_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION, "module": self.module,
                "fingerprint": dict(self.fingerprint),
                "records": [r.to_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: dict) -> "BenchReport":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"unsupported bench schema {d.get('schema')!r} "
                             f"(expected {SCHEMA_VERSION})")
        return cls(module=d["module"], fingerprint=dict(d["fingerprint"]),
                   records=[BenchRecord.from_dict(r) for r in d["records"]])


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def env_fingerprint(smoke: bool = False) -> dict:
    """The environment a benchmark ran in: enough to decide whether two
    reports are comparable (smoke flag, backend) and to explain a drift
    that is environmental rather than a code regression (versions, device)."""
    import jax
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except ImportError:                      # pragma: no cover - jax ships it
        jaxlib_version = "unknown"
    dev = jax.devices()[0]
    return {
        "jax": jax.__version__,
        "jaxlib": jaxlib_version,
        "backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count() or 1,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": _git_sha(),
        "smoke": bool(smoke),
    }


def bench_path(out_dir: str, module: str) -> Path:
    short = module.rsplit(".", 1)[-1]
    return Path(out_dir) / f"BENCH_{short}.json"


def write_bench_json(report: BenchReport, out_dir: str) -> Path:
    """Write ``BENCH_<module>.json`` (module short name) into ``out_dir``."""
    path = bench_path(out_dir, report.module)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report.to_dict(), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def read_bench_json(path: str) -> BenchReport:
    with open(path) as f:
        return BenchReport.from_dict(json.load(f))


# --------------------------------------------------------------------------- #
# measurement discipline
# --------------------------------------------------------------------------- #
def record_from_samples(name: str, samples: Sequence[float], unit: str,
                        warmup: int = 0) -> BenchRecord:
    """Summarize repeated measurements: value = median, q25/q75 = IQR.
    ``statistics.quantiles`` needs n >= 2; a single sample degrades to a
    repeats=1 record with the quartiles pinned to it."""
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError(f"record {name!r}: no samples")
    med = statistics.median(xs)
    if len(xs) >= 2:
        q25, _, q75 = statistics.quantiles(xs, n=4, method="inclusive")
    else:
        q25 = q75 = med
    return BenchRecord(name=name, value=med, unit=unit, repeats=len(xs),
                       warmup=warmup, q25=q25, median=med, q75=q75)


def measure(name: str, fn: Callable[[], object], unit: str = "s",
            repeats: int = 5, warmup: int = 1) -> BenchRecord:
    """Warmup+repeat timing of ``fn`` with ``time.perf_counter``.

    ``fn`` must block on its own device work (``jax.block_until_ready``)
    or the bracket times async dispatch instead of execution.
    """
    if repeats < 1:
        raise ValueError("repeats must be >= 1")
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return record_from_samples(name, samples, unit, warmup=warmup)


def publish_report(report: BenchReport, registry) -> None:
    """Mirror a report into a ``MetricsRegistry``: one ``bench_value`` gauge
    per record (labels: module/name/unit), so benchmark outcomes ride the
    same Prometheus surface as the serve/calibration metrics."""
    for r in report.records:
        registry.gauge("bench_value",
                       {"module": report.module, "name": r.name,
                        "unit": r.unit},
                       help="benchmark record (see BENCH_*.json)"
                       ).set(r.value)


# --------------------------------------------------------------------------- #
# device peaks + memory watermarks (analytic utilization estimates)
# --------------------------------------------------------------------------- #
# (peak f32-equivalent FLOP/s, peak HBM bytes/s) per device kind.  Analytic
# datasheet numbers: utilization rows are ESTIMATES for trend-tracking, not
# measurements.  CPU peak is per-core (scaled by cpu_count at lookup):
# ~2 FMA ports x 8 f32 lanes x 2 flops x ~2GHz.
_DEVICE_PEAKS: Dict[str, Tuple[float, float]] = {
    "TPU v4": (275e12, 1.2e12),
    "TPU v5 lite": (197e12, 0.82e12),
    "TPU v5e": (197e12, 0.82e12),
    "TPU v5p": (459e12, 2.77e12),
    "TPU v6 lite": (918e12, 1.64e12),
    "TPU v6e": (918e12, 1.64e12),
}
_CPU_PEAK_PER_CORE = (64e9, 10e9)


def device_peaks() -> Optional[Tuple[float, float]]:
    """(peak FLOP/s, peak bytes/s) for the default device, or ``None`` when
    the device kind is unknown (utilization rows are skipped, not guessed)."""
    import jax
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "")
    if jax.default_backend() == "cpu":
        n = os.cpu_count() or 1
        return (_CPU_PEAK_PER_CORE[0] * n, _CPU_PEAK_PER_CORE[1])
    for key, peaks in _DEVICE_PEAKS.items():
        if key.lower() in str(kind).lower():
            return peaks
    return None


def peak_memory_bytes() -> Tuple[float, str]:
    """Device peak-memory watermark: ``device.memory_stats()`` where the
    backend exposes it (TPU/GPU), else the live-buffer ``nbytes`` total —
    a lower bound, labelled as such via the returned source tag."""
    import jax
    dev = jax.devices()[0]
    stats = None
    try:
        stats = dev.memory_stats()
    except (AttributeError, NotImplementedError, RuntimeError):
        stats = None
    if stats:
        for key in ("peak_bytes_in_use", "bytes_in_use"):
            if key in stats:
                return float(stats[key]), f"memory_stats.{key}"
    live = sum(getattr(a, "nbytes", 0) for a in jax.live_arrays())
    return float(live), "live_arrays.nbytes"


# --------------------------------------------------------------------------- #
# compare: the regression gate
# --------------------------------------------------------------------------- #
@dataclass
class MetricVerdict:
    name: str
    status: str          # "ok" | "regressed" | "missing" | "new" | "info"
    detail: str


def _direction(unit: str) -> Optional[str]:
    if unit in STRICT_UNITS:
        return "strict"
    if unit in TIME_UNITS or unit in QUALITY_UNITS:
        return "lower"
    if unit in RATE_UNITS:
        return "higher"
    return None


def _iqr_overlaps(base: BenchRecord, cur: BenchRecord, direction: str) -> bool:
    """True when the repeat distributions overlap — the observed median
    shift is within measurement noise.  Requires quartiles on both sides."""
    if base.repeats < 2 or cur.repeats < 2:
        return False
    if None in (base.q25, base.q75, cur.q25, cur.q75):
        return False
    if direction == "lower":
        return cur.q25 <= base.q75
    return cur.q75 >= base.q25


def _check_record(base: BenchRecord, cur: BenchRecord, tol: float
                  ) -> MetricVerdict:
    name = base.name
    if cur.unit != base.unit:
        return MetricVerdict(name, "regressed",
                             f"unit changed {base.unit!r} -> {cur.unit!r}")
    direction = _direction(base.unit)
    if direction is None:
        return MetricVerdict(
            name, "info", f"unknown unit {base.unit!r}: not gated "
            f"({base.value:g} -> {cur.value:g})")
    if direction == "strict":
        if cur.value != base.value:
            return MetricVerdict(
                name, "regressed",
                f"deterministic metric changed: {base.value:g} -> "
                f"{cur.value:g} [{base.unit}] (strict)")
        return MetricVerdict(name, "ok", f"= {base.value:g} [{base.unit}]")
    if not (math.isfinite(base.value) and math.isfinite(cur.value)):
        return MetricVerdict(
            name, "regressed",
            f"non-finite value: {base.value} -> {cur.value}")
    if abs(base.value) < _ABS_FLOOR:
        # relative change from a (near-)zero baseline is undefined; report,
        # don't gate — the strict units are where exact zeros matter
        return MetricVerdict(
            name, "info",
            f"zero baseline: relative comparison undefined "
            f"({base.value:g} -> {cur.value:g} [{base.unit}])")
    # tol bounds the permitted multiplicative slowdown in both domains:
    # lower-better values may grow to (1+tol)x the baseline, higher-better
    # values may fall to baseline/(1+tol).  An additive margin would make
    # the higher-better gate vacuous for tol >= 1 (a throughput can only
    # drop 100% of itself), breaking loose CI tolerances.
    if direction == "lower":
        regressed = cur.value > base.value + tol * abs(base.value)
        change = (cur.value - base.value) / abs(base.value)
    else:
        if base.value > 0:
            regressed = cur.value < base.value / (1.0 + tol)
        else:        # negative higher-better baseline: additive fallback
            regressed = cur.value < base.value - tol * abs(base.value)
        change = (base.value - cur.value) / abs(base.value)
    if regressed and _iqr_overlaps(base, cur, direction):
        return MetricVerdict(
            name, "ok",
            f"median moved {change:+.1%} but IQRs overlap "
            f"(noise at repeats={cur.repeats}) [{base.unit}]")
    if regressed:
        return MetricVerdict(
            name, "regressed",
            f"{base.value:g} -> {cur.value:g} [{base.unit}] "
            f"({'+' if direction == 'lower' else '-'}{abs(change):.1%} "
            f"worse; tol {tol:.0%})")
    return MetricVerdict(
        name, "ok", f"{base.value:g} -> {cur.value:g} [{base.unit}]")


def _tol_for(rec: BenchRecord, timing_tol: float, quality_tol: float,
             overrides: Dict[str, float]) -> float:
    if rec.name in overrides:
        return overrides[rec.name]
    if rec.unit in QUALITY_UNITS:
        return quality_tol
    return timing_tol


def compare_reports(base: BenchReport, cur: BenchReport, *,
                    timing_tol: float = 0.5, quality_tol: float = 0.25,
                    tol_overrides: Optional[Dict[str, float]] = None,
                    allow_env_mismatch: bool = False
                    ) -> Tuple[List[MetricVerdict], List[str]]:
    """Compare two reports record-by-record.

    Returns (verdicts, errors).  ``errors`` are comparison-level failures
    (fingerprint gate mismatch, module mismatch); any ``regressed`` or
    ``missing`` verdict is a metric-level failure.  Metrics present only in
    ``cur`` are new — noted, never gated (a baseline refresh picks them up).
    """
    errors: List[str] = []
    if base.module != cur.module:
        errors.append(f"module mismatch: baseline {base.module!r} vs "
                      f"current {cur.module!r}")
        return [], errors
    for key in _FINGERPRINT_GATES:
        bv, cv = base.fingerprint.get(key), cur.fingerprint.get(key)
        if bv != cv:
            msg = (f"fingerprint {key!r} mismatch: baseline {bv!r} vs "
                   f"current {cv!r}")
            if allow_env_mismatch:
                errors_note = msg  # surfaced through a verdict below
                _ = errors_note
            else:
                errors.append(msg + " (pass --allow-env-mismatch to "
                              "compare anyway)")
    if errors:
        return [], errors
    overrides = tol_overrides or {}
    cur_by_name = {r.name: r for r in cur.records}
    verdicts: List[MetricVerdict] = []
    for b in base.records:
        c = cur_by_name.pop(b.name, None)
        if c is None:
            verdicts.append(MetricVerdict(
                b.name, "missing",
                f"tracked metric absent from current run [{b.unit}]"))
            continue
        verdicts.append(_check_record(
            b, c, _tol_for(b, timing_tol, quality_tol, overrides)))
    for name in cur_by_name:
        verdicts.append(MetricVerdict(
            name, "new", "not in baseline (refresh baselines to track)"))
    return verdicts, errors


def _parse_overrides(pairs: Iterable[str]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for p in pairs:
        if "=" not in p:
            raise ValueError(f"--tol expects name=fraction, got {p!r}")
        name, _, v = p.rpartition("=")
        out[name] = float(v)
    return out


def _resolve_pairs(base: str, cur: str) -> List[Tuple[Path, Path]]:
    """File-vs-file or dir-vs-dir: in dir mode every baseline BENCH_*.json
    must have a same-named counterpart in the current dir."""
    bp, cp = Path(base), Path(cur)
    if bp.is_dir() != cp.is_dir():
        raise ValueError("baseline and current must both be files or both "
                         "be directories")
    if not bp.is_dir():
        return [(bp, cp)]
    pairs = []
    base_files = sorted(bp.glob("BENCH_*.json"))
    if not base_files:
        raise ValueError(f"{bp}: no BENCH_*.json baselines found")
    for b in base_files:
        pairs.append((b, cp / b.name))
    return pairs


def cmd_compare(args) -> int:
    try:
        pairs = _resolve_pairs(args.baseline, args.current)
        overrides = _parse_overrides(args.tol or [])
    except (ValueError, OSError) as e:
        print(f"[bench.compare] ERROR: {e}", file=sys.stderr)
        return 2
    n_regressed = n_missing = n_ok = 0
    failed = False
    for bpath, cpath in pairs:
        try:
            base = read_bench_json(bpath)
        except (OSError, ValueError, KeyError) as e:
            print(f"[bench.compare] ERROR reading baseline {bpath}: {e}",
                  file=sys.stderr)
            return 2
        if not cpath.exists():
            print(f"[bench.compare] FAIL {base.module}: current report "
                  f"{cpath} missing (module failed or was not run)")
            failed = True
            continue
        try:
            cur = read_bench_json(cpath)
        except (OSError, ValueError, KeyError) as e:
            print(f"[bench.compare] ERROR reading current {cpath}: {e}",
                  file=sys.stderr)
            return 2
        verdicts, errors = compare_reports(
            base, cur, timing_tol=args.timing_tol,
            quality_tol=args.quality_tol, tol_overrides=overrides,
            allow_env_mismatch=args.allow_env_mismatch)
        for e in errors:
            print(f"[bench.compare] FAIL {base.module}: {e}")
            failed = True
        for v in verdicts:
            bad = v.status in ("regressed", "missing")
            if bad:
                failed = True
                n_regressed += v.status == "regressed"
                n_missing += v.status == "missing"
            else:
                n_ok += v.status == "ok"
            if bad or v.status in ("new", "info") or args.verbose:
                print(f"[bench.compare] {v.status.upper():9s} "
                      f"{base.module}:{v.name}: {v.detail}")
        # environment drift is worth a line even when everything passes
        for key in _FINGERPRINT_KEYS:
            bv, cv = base.fingerprint.get(key), cur.fingerprint.get(key)
            if bv != cv and key not in _FINGERPRINT_GATES and args.verbose:
                print(f"[bench.compare] note {base.module}: fingerprint "
                      f"{key} {bv!r} -> {cv!r}")
    status = "FAIL" if failed else "OK"
    print(f"[bench.compare] {status}: {n_ok} ok, {n_regressed} regressed, "
          f"{n_missing} missing across {len(pairs)} report(s)")
    return 1 if failed else 0


def cmd_fingerprint(args) -> int:
    print(json.dumps(env_fingerprint(smoke=args.smoke), indent=1,
                     sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.bench",
        description="Benchmark telemetry: compare BENCH_*.json reports "
                    "(regression gate) or print the environment fingerprint.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    cp = sub.add_parser("compare", help="gate current vs baseline reports")
    cp.add_argument("baseline", help="baseline BENCH_*.json file or directory")
    cp.add_argument("current", help="current BENCH_*.json file or directory")
    cp.add_argument("--timing-tol", type=float, default=0.5,
                    help="relative tolerance for timing/throughput metrics "
                         "(default 0.5; CI on shared CPU runners passes a "
                         "looser one)")
    cp.add_argument("--quality-tol", type=float, default=0.25,
                    help="relative tolerance for quality metrics "
                         "(ppl/mse/...; default 0.25)")
    cp.add_argument("--tol", action="append", metavar="NAME=FRAC",
                    help="per-metric tolerance override (repeatable)")
    cp.add_argument("--allow-env-mismatch", action="store_true",
                    help="compare across smoke/backend fingerprint "
                         "mismatches (off by default)")
    cp.add_argument("--verbose", action="store_true",
                    help="print every metric verdict, not just failures")
    cp.set_defaults(fn=cmd_compare)
    fp = sub.add_parser("fingerprint", help="print the env fingerprint")
    fp.add_argument("--smoke", action="store_true")
    fp.set_defaults(fn=cmd_fingerprint)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
