"""The ``Obs`` bundle: one metrics registry + optional tracer + profiling.

Every serve engine, page pool, scheduler and calibration run takes an
``Obs`` (or creates a default one).  The three concerns have three costs:

  * **metrics** are always on — pure host arithmetic into a
    ``MetricsRegistry`` (no device work, no sync);
  * **tracing** is on only when a ``Tracer`` is attached — otherwise no
    event dict is ever built and no span-bracketing device fence runs;
  * **profiling** is on only when ``profile_dir`` is set —
    ``annotate(name)`` then wraps the jitted decode/prefill/calibrate calls
    in ``jax.profiler.TraceAnnotation`` so the device trace lines up with
    the host-side spans, and ``start_profile``/``stop_profile`` bracket the
    run with ``jax.profiler.start_trace``/``stop_trace``.  With
    ``profile_dir=None`` the annotation context is a cached ``nullcontext``
    — nothing is inserted into or around compiled code.

The disabled path is the default path and it is a no-op by construction:
``Obs()`` has no tracer and no profile dir, so serving with it is
bit-identical to (and as fast as) serving before this layer existed.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

__all__ = ["Obs", "record_calibration"]

_NULL_CTX = nullcontext()


class Obs:
    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 profile_dir: Optional[str] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.profile_dir = profile_dir
        self._profiling = False

    @property
    def tracing(self) -> bool:
        return self.tracer is not None

    def emit(self, event: str, **fields) -> None:
        """Emit a span event iff a tracer is attached (else: no-op)."""
        if self.tracer is not None:
            self.tracer.emit(event, **fields)

    # ------------------------------------------------------------ profiling
    def annotate(self, name: str):
        """Context manager naming a region in the device trace; a cached
        nullcontext when profiling is off (nothing enters compiled code)."""
        if self.profile_dir is None:
            return _NULL_CTX
        import jax
        return jax.profiler.TraceAnnotation(name)

    def start_profile(self) -> None:
        if self.profile_dir is not None and not self._profiling:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True

    def stop_profile(self) -> None:
        if self._profiling:
            import jax
            jax.profiler.stop_trace()
            self._profiling = False

    def close(self) -> None:
        self.stop_profile()
        if self.tracer is not None:
            self.tracer.close()


def record_calibration(obs: Obs, site: str, loss_history, aux=None) -> None:
    """Stream a calibration site's on-device loss/metric histories into the
    registry (+ one ``calib_site`` span per site when tracing).

    ``loss_history`` is ``CalibResult.loss_history`` — [steps] for a single
    site or [L, steps] for the batched engine, in which case each layer
    publishes as ``site[i]``.  Histories are pulled from the device here —
    calibration is offline and the caller reads them anyway, so this is the
    one place the obs layer is allowed to sync.
    """
    lh = np.asarray(loss_history, np.float64)
    aux = {k: np.asarray(v, np.float64) for k, v in dict(aux or {}).items()}
    m = obs.metrics
    batched = lh.ndim == 2
    for i, h in enumerate(lh if batched else lh[None]):
        name = f"{site}[{i}]" if batched else site
        lbl = {"site": name}
        m.gauge("calib_loss_initial", lbl,
                help="objective at step 0 (pre-update)").set(float(h[0]))
        m.gauge("calib_loss_final", lbl,
                help="objective at the last pre-update step").set(
                    float(h[-1]))
        m.counter("calib_steps_total", lbl,
                  help="optimizer steps run for this site").inc(h.shape[0])
        ev_aux = {}
        for k, v in aux.items():
            series = v[i] if batched else v
            m.gauge("calib_metric_final", {**lbl, "metric": k}).set(
                float(series[-1]))
            ev_aux[f"{k}_final"] = float(series[-1])
        if obs.tracing:
            obs.emit("calib_site", site=name, steps=int(h.shape[0]),
                     loss_initial=float(h[0]), loss_final=float(h[-1]),
                     loss_history=[float(x) for x in h], **ev_aux)
