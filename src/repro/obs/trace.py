"""Structured request-lifecycle tracing: span events over a pluggable sink.

The serve stack emits one event stream per engine describing every request's
lifecycle — the span chain the scheduler and engine produce is

    enqueue -> admit -> prefill_chunk* -> first_token
            -> decode_step* -> (preempt -> admit -> ...)* -> finish

Every event is a flat JSON object with the base fields

    event     event type (one of ``EVENT_TYPES``)
    t_wall    wall-clock seconds (``time.time()``; for humans/correlation)
    t_mono    monotonic seconds (``time.perf_counter()``; for intervals)

plus per-type payload fields (``EVENT_FIELDS``).  Requests are identified by
``rid`` — assigned once at enqueue and *stable across preemption/requeue*,
unlike ``seq_id`` which changes on re-admission — so one request's spans can
always be stitched back together.  The ``finish`` event carries the derived
latencies: TTFT, mean inter-token latency, queue time, pages held.

Sinks are pluggable: ``JsonlSink`` appends one JSON object per line (the
``--trace-out`` artifact), ``ListSink`` retains events in memory (tests).
Tracing is strictly opt-in: with no tracer configured the serve stack never
constructs an event dict, never formats JSON, and never syncs the device for
a span — the disabled path is a no-op.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Set

__all__ = ["Tracer", "JsonlSink", "ListSink", "EVENT_TYPES", "EVENT_FIELDS",
           "read_trace", "validate_trace"]

BASE_FIELDS: Set[str] = {"event", "t_wall", "t_mono"}

# per-type payload contract (required keys; extra keys are allowed)
EVENT_FIELDS: Dict[str, Set[str]] = {
    "enqueue":       {"rid", "prompt_len", "max_new"},
    "admit":         {"rid", "seq_id", "slot", "cached_len", "queue_s"},
    "prefill_chunk": {"rid", "seq_id", "tokens", "duration_s"},
    "first_token":   {"rid", "seq_id", "ttft_s"},
    "decode_step":   {"n_running", "duration_s", "rids"},
    "preempt":       {"rid", "seq_id", "pos", "pages_held"},
    "finish":        {"rid", "seq_id", "n_tokens", "pages_held", "ttft_s",
                      "queue_s", "itl_mean_s"},
    "calib_site":    {"site", "steps", "loss_initial", "loss_final"},
}
EVENT_TYPES: Set[str] = set(EVENT_FIELDS)


class ListSink:
    """In-memory sink (tests / programmatic inspection)."""

    def __init__(self):
        self.events: List[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append-mode; flushed per event so a crashed
    serving loop still leaves a parseable trace behind."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")

    def emit(self, event: dict) -> None:
        json.dump(event, self._f, separators=(",", ":"))
        self._f.write("\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class Tracer:
    """Stamps base fields and forwards to the sink.  Construction is the
    opt-in: code paths hold ``tracer=None`` when tracing is off and skip
    event assembly entirely."""

    def __init__(self, sink):
        self.sink = sink

    def emit(self, event: str, **fields) -> None:
        if event not in EVENT_TYPES:
            raise ValueError(f"unknown trace event {event!r}; "
                             f"known: {sorted(EVENT_TYPES)}")
        rec = {"event": event, "t_wall": time.time(),
               "t_mono": time.perf_counter(), **fields}
        self.sink.emit(rec)

    def close(self) -> None:
        self.sink.close()


def read_trace(path: str) -> List[dict]:
    """Parse a JSONL trace file back into event dicts."""
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not valid JSON: {e}")
    return events


def validate_trace(events: List[dict],
                   require: Optional[Set[str]] = None) -> None:
    """Schema check: base fields present, event types known, per-type
    required payload fields present.  ``require`` additionally asserts that
    those event types occur at least once.  Raises ``ValueError``."""
    if not events:
        raise ValueError("trace is empty")
    seen: Set[str] = set()
    for i, ev in enumerate(events):
        missing = BASE_FIELDS - ev.keys()
        if missing:
            raise ValueError(f"event {i}: missing base fields {missing}")
        kind = ev["event"]
        if kind not in EVENT_TYPES:
            raise ValueError(f"event {i}: unknown type {kind!r}")
        missing = EVENT_FIELDS[kind] - ev.keys()
        if missing:
            raise ValueError(f"event {i} ({kind}): missing fields {missing}")
        seen.add(kind)
    if require:
        absent = set(require) - seen
        if absent:
            raise ValueError(f"trace has no {sorted(absent)} events "
                             f"(saw {sorted(seen)})")
