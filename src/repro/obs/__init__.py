"""``repro.obs`` — structured tracing + metrics across serve and calibration.

  * ``MetricsRegistry`` (``metrics.py``): zero-dependency counters / gauges /
    fixed-bucket histograms with percentile math; Prometheus textfile
    snapshots via ``write_prom``.
  * ``Tracer`` (``trace.py``): per-request lifecycle span events
    (enqueue -> admit -> prefill_chunk* -> decode_step* -> preempt ->
    finish) as JSONL through a pluggable sink.
  * ``Obs`` (``obs.py``): the bundle the serve/calibration stacks carry —
    always-on metrics, opt-in tracing, opt-in ``jax.profiler`` annotation.
  * ``quant_health``: trace-time-gated QDQ taps (clip rate, scale dynamic
    range) publishing through ``jax.debug.callback``.
  * ``validate``: CLI checker for ``--trace-out`` / ``--metrics-out`` /
    ``BENCH_*.json`` artifacts (the CI smoke's parser).
  * ``bench``: structured benchmark telemetry — ``BenchRecord`` /
    ``BenchReport`` with an environment fingerprint and warmup+repeat
    median/IQR discipline, the ``BENCH_<module>.json`` artifact convention,
    and the ``python -m repro.obs.bench compare`` regression gate CI runs
    against committed baselines.

The contract that everything here honors: the **disabled path is a no-op** —
no host sync, no callback into jitted code, no event assembly.  Metrics
counters are plain host ints and stay on unconditionally.
"""
from repro.obs.bench import (BenchRecord, BenchReport, env_fingerprint,
                             measure, read_bench_json, record_from_samples,
                             write_bench_json)
from repro.obs.metrics import (Counter, DEFAULT_LATENCY_BUCKETS, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.obs import Obs, record_calibration
from repro.obs.trace import (EVENT_FIELDS, EVENT_TYPES, JsonlSink, ListSink,
                             Tracer, read_trace, validate_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_LATENCY_BUCKETS",
    "Obs", "record_calibration",
    "Tracer", "JsonlSink", "ListSink", "read_trace", "validate_trace",
    "EVENT_TYPES", "EVENT_FIELDS",
    "BenchRecord", "BenchReport", "env_fingerprint", "measure",
    "record_from_samples", "read_bench_json", "write_bench_json",
]
