"""Distribution utilities: sharding specs + compressed collectives.

Single-host safe: importing this package never touches jax device state; the
``Sharding`` helper only binds to a mesh the caller constructed.
"""
from repro.dist.collectives import (all_reduce_compressed_tree, compress_grad,
                                    init_error_feedback, psum_compressed)
from repro.dist.sharding import (Sharding, calib_data_axes, calib_group_size,
                                 calib_specs, place_calib_acts)
