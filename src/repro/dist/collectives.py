"""Compressed gradient collectives (int8 + error feedback).

``compress_grad`` quantizes a gradient tensor to int8 with a per-tensor scale
and carries the quantization residual forward as error feedback (1-bit
Adam-style): the residual is added to the NEXT step's gradient before
quantization, so compression error does not accumulate over training.

``all_reduce_compressed_tree`` is the collective counterpart: each data shard
quantizes locally, the int8 payloads are all-reduced (summed in f32 after
dequant — a real deployment would sum int32 payloads; the math is identical
for the mean), and the result is averaged over the data axis.  ~4x smaller
reduction payload than f32 gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def compress_grad(g: jax.Array, err: jax.Array):
    """int8-quantize ``g + err``; returns ``(q, scale, new_err)``.

    ``q.astype(f32) * scale + new_err`` reconstructs ``g + err`` exactly, so
    feeding ``new_err`` back next step makes the scheme unbiased over time.
    """
    c = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(c)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, c - deq


def init_error_feedback(grads):
    """Zero error-feedback buffers matching a gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def all_reduce_compressed_tree(grads, errs, mesh, axis: str = "data"):
    """Mean-all-reduce a gradient pytree over ``axis`` with int8 payloads.

    Returns ``(reduced_grads, new_errs)``.  Inputs are taken replicated over
    the mesh (each shard holds its local gradient tensor); the quantization
    happens per shard, the reduction on the compressed representation.
    """
    n = int(mesh.shape[axis])

    def reduce_one(g, e):
        q, scale, new_e = compress_grad(g, e)

        def red(qv, sv):
            return jax.lax.psum(qv.astype(jnp.float32) * sv, axis) / n

        out = shard_map(red, mesh=mesh, in_specs=(P(), P()),
                        out_specs=P(), check_rep=False)(q, scale)
        return out, new_e

    flat, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    outs, new_errs = [], []
    for g, e in zip(flat, flat_e):
        o, ne = reduce_one(g, e)
        outs.append(o)
        new_errs.append(ne)
    return jax.tree.unflatten(tree, outs), jax.tree.unflatten(tree, new_errs)
