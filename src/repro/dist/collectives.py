"""Compressed gradient collectives (int8 + error feedback).

``compress_grad`` quantizes a gradient tensor to int8 with a per-tensor scale
and carries the quantization residual forward as error feedback (1-bit
Adam-style): the residual is added to the NEXT step's gradient before
quantization, so compression error does not accumulate over training.

``all_reduce_compressed_tree`` is the collective counterpart: each data shard
quantizes locally, the int8 payloads are all-reduced (summed in f32 after
dequant — a real deployment would sum int32 payloads; the math is identical
for the mean), and the result is averaged over the data axis.  ~4x smaller
reduction payload than f32 gradients.

Two modes:
  * replicated (default, legacy): every shard holds the SAME full gradient;
    the psum averages n identical compressed copies — a broadcast-consistency
    primitive, not a real reduction.
  * sharded (``sharded=True``): leaves carry a leading per-shard axis of size
    ``mesh.shape[axis]`` sharded over ``axis`` — each shard's slice is its OWN
    local gradient.  Quantization and error feedback stay per-shard (the error
    buffer never crosses devices), only the int8 payload is reduced.

``psum_compressed`` is the in-``shard_map`` primitive both modes build on; the
token-sharded calibration engine (``repro.core.qr_orth``) calls it directly
for its per-step whip-gradient psum when ``compressed_grads=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def compress_grad(g: jax.Array, err: jax.Array):
    """int8-quantize ``g + err``; returns ``(q, scale, new_err)``.

    ``q.astype(f32) * scale + new_err`` reconstructs ``g + err`` exactly, so
    feeding ``new_err`` back next step makes the scheme unbiased over time.
    """
    c = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(c)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, c - deq


def init_error_feedback(grads):
    """Zero error-feedback buffers matching a gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def psum_compressed(g: jax.Array, err: jax.Array, axis):
    """SUM-reduce a local gradient over ``axis`` with an int8 payload.

    Must be called inside a ``shard_map`` body: ``g`` is this shard's local
    gradient, ``err`` its local error-feedback buffer.  Returns
    ``(g_reduced, new_err)`` — the reduced gradient is replicated, the new
    error buffer stays local (it is this shard's quantization residual).
    """
    q, scale, new_err = compress_grad(g, err)
    return jax.lax.psum(q.astype(jnp.float32) * scale, axis), new_err


def all_reduce_compressed_tree(grads, errs, mesh, axis: str = "data", *,
                               sharded: bool = False):
    """Mean-all-reduce a gradient pytree over ``axis`` with int8 payloads.

    Returns ``(reduced_grads, new_errs)``.

    ``sharded=False`` (legacy): inputs are replicated over the mesh; the psum
    averages ``n`` identical compressed copies.

    ``sharded=True``: every leaf carries a leading per-shard axis of size
    ``mesh.shape[axis]``, sharded over ``axis`` (shard i's slice is its local
    gradient).  Reduced gradients come back replicated (leading axis dropped);
    error buffers keep the leading axis and stay sharded — feed them back on
    the next call so per-shard quantization error cancels over time.
    """
    n = int(mesh.shape[axis])

    def reduce_replicated(g, e):
        q, scale, new_e = compress_grad(g, e)

        def red(qv, sv):
            return jax.lax.psum(qv.astype(jnp.float32) * sv, axis) / n

        out = shard_map(red, mesh=mesh, in_specs=(P(), P()),
                        out_specs=P(), check_rep=False)(q, scale)
        return out, new_e

    def reduce_sharded(g, e):
        assert g.shape[0] == n, (g.shape, n)

        def red(gl, el):
            out, new_e = psum_compressed(gl[0], el[0], axis)
            return out / n, new_e[None]

        nd = g.ndim - 1
        return shard_map(red, mesh=mesh,
                         in_specs=(P(axis, *([None] * nd)),
                                   P(axis, *([None] * nd))),
                         out_specs=(P(*([None] * nd)),
                                    P(axis, *([None] * nd))),
                         check_rep=False)(g, e)

    reduce_one = reduce_sharded if sharded else reduce_replicated
    flat, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    outs, new_errs = [], []
    for g, e in zip(flat, flat_e):
        o, ne = reduce_one(g, e)
        outs.append(o)
        new_errs.append(ne)
    return jax.tree.unflatten(tree, outs), jax.tree.unflatten(tree, new_errs)
