"""Mesh-aware sharding helper consumed by the model code and launchers.

Models call ``shd(x, name)`` at annotation points (see
``repro.models.common.NO_SHARD`` for the single-device no-op); ``Sharding``
resolves the name to a ``PartitionSpec`` over the bound mesh and applies a
``with_sharding_constraint``.  It also derives parameter / batch / cache
specs for jit ``in_shardings`` from pytree structure alone, so the same rules
cover every architecture in ``repro.configs`` without per-model tables:

  * params — the largest axis divisible by the 'model' axis size is
    tensor-parallel sharded; vectors and small leaves replicate.  Leading
    layer-stack axes are never sharded (they are scanned over).
  * batch  — leading (batch) axis over all non-'model' axes (data ± pod).
  * cache  — axis 1 (batch; caches are stacked [L, B, ...]) over data axes.

Any mesh with a 'model' axis and one or more data-like axes works; the 'pod'
axis of the multi-pod production mesh composes into the data group
automatically.

Calibration sharding (``calib_specs`` / ``place_calib_acts``) follows the same
convention without needing a ``ModelConfig``: captured activations shard their
token axis over the data group ('pod' x 'data'), rotation latents and
optimizer state replicate.  These are the rules the token-sharded calibration
engine (``repro.core.qr_orth``) places its inputs with.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------- #
# Calibration specs: token axis over the data group, latents replicated
# --------------------------------------------------------------------------- #
def calib_data_axes(mesh) -> Tuple[str, ...]:
    """The data group of a mesh: every axis except 'model' (pod composes in)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def calib_group_size(mesh, data_axes: Optional[Tuple[str, ...]] = None) -> int:
    """Number of token shards = product of the data-group axis sizes."""
    axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
    k = 1
    for a in axes:
        k *= int(mesh.shape[a])
    return k


def calib_specs(mesh, data_axes: Optional[Tuple[str, ...]] = None
                ) -> Dict[str, P]:
    """PartitionSpec rules for the token-sharded calibration engine.

      x      [N, n]     single-site activations, tokens over the data group
      xs     [L, N, n]  batched sites: sites replicated, tokens sharded
      mask   [N]        token-validity weights (padding rows are 0)
      latent [n, n]     rotation latent / optimizer state — replicated
    """
    axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
    d = axes[0] if len(axes) == 1 else axes
    return {
        "x": P(d, None),
        "xs": P(None, d, None),
        "mask": P(d),
        "latent": P(),
    }


def place_calib_acts(acts: Dict[str, jax.Array], mesh,
                     data_axes: Optional[Tuple[str, ...]] = None
                     ) -> Dict[str, jax.Array]:
    """device_put captured activation pools token-sharded over the data group.

    2-D pools ([N, n]) shard axis 0, 3-D pools ([L, N, n]) shard axis 1.
    ``NamedSharding`` needs the token axis divisible by the group size, so
    pools are TRIMMED (never padded — padding would look like real tokens to
    consumers) to the nearest multiple: at most ``group - 1`` randomly-sampled
    tokens are dropped per pool, harmless at calibration-set scale.
    """
    k = calib_group_size(mesh, data_axes)
    specs = calib_specs(mesh, data_axes)

    def put(name, v):
        axis = 1 if v.ndim == 3 else 0
        if v.shape[axis] < k:
            raise ValueError(
                f"calibration pool {name!r} has {v.shape[axis]} tokens, "
                f"fewer than the {k} shards of the data group — shrink the "
                f"mesh or capture more tokens")
        n = v.shape[axis] - v.shape[axis] % k
        v = jax.lax.slice_in_dim(v, 0, n, axis=axis)
        s = specs["xs"] if v.ndim == 3 else specs["x"]
        return jax.device_put(v, NamedSharding(mesh, s))

    return {name: put(name, v) for name, v in acts.items()}


class Sharding:
    def __init__(self, cfg: ModelConfig, mesh):
        self.cfg = cfg
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.model_axis = "model" if "model" in axes else None
        dp = tuple(a for a in axes if a != "model")
        # one PartitionSpec entry covering the whole data group
        self.dp: object = dp[0] if len(dp) == 1 else dp
        self.model_size = int(mesh.shape["model"]) if self.model_axis else 1
        m, d = self.model_axis, self.dp
        self._act_specs: Dict[str, P] = {
            # [B, S, D] residual stream / [B, S, F] ffn hidden
            "act_bsd": P(d, None, None),
            "act_bsf": P(d, None, None),
            # [B, S, V] logits: vocab tensor-parallel (see cross_entropy)
            "logits": P(d, None, m),
            # [B, S, H, hd] attention tensors
            "act_bshd_heads": P(d, None, m, None),
            "act_bskd_heads": P(d, None, m, None),
            "act_bshd_seq": P(d, m, None, None),
            "act_bshd_rep": P(d, None, None, None),
            # [B, S, H, P] ssm heads
            "ssm_bshp": P(d, None, m, None),
            # [G, g, D] grouped tokens / [G, E, cap, D] dispatched experts
            "moe_gtd": P(d, None, None),
            "moe_gecd": P(d, m, None, None),
        }

    # -- activation constraints (models call shd(x, name)) ------------------ #
    def spec(self, name: str) -> P:
        return self._act_specs[name]

    def __call__(self, x, name: str):
        s = self._act_specs.get(name)
        if s is None or x.ndim != len(s):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, s))

    # -- pytree spec derivation --------------------------------------------- #
    def named(self, spec_tree):
        """PartitionSpec tree -> NamedSharding tree on the bound mesh."""
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))

    def _leaf_param_spec(self, leaf) -> P:
        shape = tuple(leaf.shape)
        ms = self.model_size
        if self.model_axis is None or len(shape) < 2 or ms <= 1:
            return P(*([None] * len(shape)))
        # candidate tensor-parallel axes: divisible by the model axis and big
        # enough that splitting pays; never the leading layer-stack axis when
        # the leaf is stacked (ndim >= 3).
        first = 1 if len(shape) >= 3 else 0
        best, best_size = None, 0
        for i in range(first, len(shape)):
            if shape[i] % ms == 0 and shape[i] >= 2 * ms and shape[i] > best_size:
                best, best_size = i, shape[i]
        spec = [None] * len(shape)
        if best is not None:
            spec[best] = self.model_axis
        return P(*spec)

    def param_specs(self, params):
        """Tensor-parallel specs for a params pytree (arrays or SDS)."""
        return jax.tree.map(self._leaf_param_spec, params)

    def batch_specs(self, batch):
        """Data-parallel specs: leading axis over the data group."""
        return jax.tree.map(
            lambda x: P(*((self.dp,) + (None,) * (x.ndim - 1)))
            if x.ndim >= 1 else P(), batch)

    def cache_specs(self, cache):
        """Decode caches are stacked [L, B, ...]: shard B over data."""
        return jax.tree.map(
            lambda x: P(*((None, self.dp) + (None,) * (x.ndim - 2)))
            if x.ndim >= 2 else P(), cache)
