"""Mesh-aware sharding helper consumed by the model code and launchers.

Models call ``shd(x, name)`` at annotation points (see
``repro.models.common.NO_SHARD`` for the single-device no-op); ``Sharding``
resolves the name to a ``PartitionSpec`` over the bound mesh and applies a
``with_sharding_constraint``.  It also derives parameter / batch / cache
specs for jit ``in_shardings`` from pytree structure alone, so the same rules
cover every architecture in ``repro.configs`` without per-model tables:

  * params — the largest axis divisible by the 'model' axis size is
    tensor-parallel sharded; vectors and small leaves replicate.  Leading
    layer-stack axes are never sharded (they are scanned over).
  * batch  — leading (batch) axis over all non-'model' axes (data ± pod).
  * cache  — axis 1 (batch; caches are stacked [L, B, ...]) over data axes.

Any mesh with a 'model' axis and one or more data-like axes works; the 'pod'
axis of the multi-pod production mesh composes into the data group
automatically.

Calibration sharding (``calib_specs`` / ``place_calib_acts``) follows the same
convention without needing a ``ModelConfig``: captured activations shard their
token axis over the data group ('pod' x 'data'), rotation latents and
optimizer state replicate.  These are the rules the token-sharded calibration
engine (``repro.core.qr_orth``) places its inputs with.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------- #
# Calibration specs: token axis over the data group, latents replicated
# --------------------------------------------------------------------------- #
def calib_data_axes(mesh) -> Tuple[str, ...]:
    """The data group of a mesh: every axis except 'model' (pod composes in)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def calib_group_size(mesh, data_axes: Optional[Tuple[str, ...]] = None) -> int:
    """Number of token shards = product of the data-group axis sizes."""
    axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
    k = 1
    for a in axes:
        k *= int(mesh.shape[a])
    return k


def calib_specs(mesh, data_axes: Optional[Tuple[str, ...]] = None
                ) -> Dict[str, P]:
    """PartitionSpec rules for the token-sharded calibration engine.

      x      [N, n]     single-site activations, tokens over the data group
      xs     [L, N, n]  batched sites: sites replicated, tokens sharded
      mask   [N]        token-validity weights (padding rows are 0)
      latent [n, n]     rotation latent / optimizer state — replicated
    """
    axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
    d = axes[0] if len(axes) == 1 else axes
    return {
        "x": P(d, None),
        "xs": P(None, d, None),
        "mask": P(d),
        "latent": P(),
    }


def place_calib_acts(acts: Dict[str, jax.Array], mesh,
                     data_axes: Optional[Tuple[str, ...]] = None
                     ) -> Dict[str, jax.Array]:
    """device_put captured activation pools token-sharded over the data group.

    2-D pools ([N, n]) shard axis 0, 3-D pools ([L, N, n]) shard axis 1.
    ``NamedSharding`` needs the token axis divisible by the group size, so
    pools are TRIMMED (never padded — padding would look like real tokens to
    consumers) to the nearest multiple: at most ``group - 1`` randomly-sampled
    tokens are dropped per pool, harmless at calibration-set scale.
    """
    k = calib_group_size(mesh, data_axes)
    specs = calib_specs(mesh, data_axes)

    def put(name, v):
        axis = 1 if v.ndim == 3 else 0
        if v.shape[axis] < k:
            raise ValueError(
                f"calibration pool {name!r} has {v.shape[axis]} tokens, "
                f"fewer than the {k} shards of the data group — shrink the "
                f"mesh or capture more tokens")
        n = v.shape[axis] - v.shape[axis] % k
        v = jax.lax.slice_in_dim(v, 0, n, axis=axis)
        s = specs["xs"] if v.ndim == 3 else specs["x"]
        return jax.device_put(v, NamedSharding(mesh, s))

    return {name: put(name, v) for name, v in acts.items()}


class Sharding:
    def __init__(self, cfg: ModelConfig, mesh):
        self.cfg = cfg
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.model_axis = "model" if "model" in axes else None
        dp = tuple(a for a in axes if a != "model")
        # one PartitionSpec entry covering the whole data group
        self.dp: object = dp[0] if len(dp) == 1 else dp
        self.model_size = int(mesh.shape["model"]) if self.model_axis else 1
        m, d = self.model_axis, self.dp
        self._act_specs: Dict[str, P] = {
            # [B, S, D] residual stream / [B, S, F] ffn hidden
            "act_bsd": P(d, None, None),
            "act_bsf": P(d, None, None),
            # [B, S, V] logits: vocab tensor-parallel (see cross_entropy)
            "logits": P(d, None, m),
            # [B, S, H, hd] attention tensors
            "act_bshd_heads": P(d, None, m, None),
            "act_bskd_heads": P(d, None, m, None),
            "act_bshd_seq": P(d, m, None, None),
            "act_bshd_rep": P(d, None, None, None),
            # [B, S, H, P] ssm heads
            "ssm_bshp": P(d, None, m, None),
            # [G, g, D] grouped tokens / [G, E, cap, D] dispatched experts
            "moe_gtd": P(d, None, None),
            "moe_gecd": P(d, m, None, None),
        }

    # -- activation constraints (models call shd(x, name)) ------------------ #
    def spec(self, name: str) -> P:
        return self._act_specs[name]

    def __call__(self, x, name: str):
        s = self._act_specs.get(name)
        if s is None or x.ndim != len(s):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, s))

    # -- pytree spec derivation --------------------------------------------- #
    def named(self, spec_tree):
        """PartitionSpec tree -> NamedSharding tree on the bound mesh."""
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))

    def _leaf_param_spec(self, leaf) -> P:
        shape = tuple(leaf.shape)
        ms = self.model_size
        if self.model_axis is None or len(shape) < 2 or ms <= 1:
            return P(*([None] * len(shape)))
        # candidate tensor-parallel axes: divisible by the model axis and big
        # enough that splitting pays; never the leading layer-stack axis when
        # the leaf is stacked (ndim >= 3).
        first = 1 if len(shape) >= 3 else 0
        best, best_size = None, 0
        for i in range(first, len(shape)):
            if shape[i] % ms == 0 and shape[i] >= 2 * ms and shape[i] > best_size:
                best, best_size = i, shape[i]
        spec = [None] * len(shape)
        if best is not None:
            spec[best] = self.model_axis
        return P(*spec)

    def param_specs(self, params):
        """Tensor-parallel specs for a params pytree (arrays or SDS)."""
        return jax.tree.map(self._leaf_param_spec, params)

    def batch_specs(self, batch):
        """Data-parallel specs: leading axis over the data group."""
        return jax.tree.map(
            lambda x: P(*((self.dp,) + (None,) * (x.ndim - 1)))
            if x.ndim >= 1 else P(), batch)

    def cache_specs(self, cache):
        """Decode caches are stacked [L, B, ...]: shard B over data."""
        return jax.tree.map(
            lambda x: P(*((None, self.dp) + (None,) * (x.ndim - 2)))
            if x.ndim >= 2 else P(), cache)


# --------------------------------------------------------------------------- #
# Serve tensor parallelism: explicit Megatron-style specs for the paged
# engine's shard_map.  Unlike Sharding._leaf_param_spec (a shape heuristic for
# GSPMD jit), these rules are *path-keyed* — the shard_map body computes with
# the local array blocks directly, so every leaf's partitioning must agree
# exactly with the psum seams in repro.models.{attention,ffn}:
#
#   column (out-dim)  wq wk wv wq_b wkv_b  + w_gate/w_up/fc1 when the FFN
#                     shards; their biases shard the same way
#   row (in-dim)      wo                   + w_down/fc2 when the FFN shards;
#                     after-psum biases (bo, b2) replicate
#   expert (E-dim)    MoE expert stacks when moe_impl == 'ragged' and E
#                     divides; the router replicates (identical routing per
#                     shard, see ffn.moe_tp_local)
#   replicated        everything else: norms, embeddings, lm_head, router,
#                     wq_a/wkv_a (the MLA latent path feeds the replicated
#                     latent pages), and ALL SSM leaves — the Mamba2 gating
#                     norm spans the full d_inner, so sharding it would cost
#                     a second psum per layer; SSM blocks replicate instead.
#
# The FFN shards only when no online R4 rotation is active: the R4 Walsh-
# Hadamard globally mixes the hidden dim, so applying it shard-locally would
# break bit-parity with the single-device engine.  On the production path
# (quantized artifact, R4 fused into the weights) the FFN therefore
# replicates and the decode step carries EXACTLY ONE psum per layer — at the
# attention output projection.
# --------------------------------------------------------------------------- #
_TP_ATTN_COL = {"wq", "wk", "wv", "wq_b", "wkv_b"}
_TP_ATTN_COL_BIAS = {"bq", "bk", "bv"}
_TP_ATTN_ROW = {"wo"}
_TP_FFN_COL = {"w_gate", "w_up", "fc1"}
_TP_FFN_COL_BIAS = {"b1"}
_TP_FFN_ROW = {"w_down", "fc2"}
_TP_MOE_STACK = {"w_gate", "w_up", "w_down"}


def tp_degree(mesh) -> int:
    """Size of the mesh 'model' axis (1 when absent or mesh is None)."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    return int(mesh.shape["model"])


def _axis_spec(ndim: int, axis: int) -> P:
    spec = [None] * ndim
    spec[axis] = "model"
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", p))) for p in path)


def _leaf_mode(names: Tuple[str, ...], ffn_sharded: bool,
               moe_sharded: bool) -> str:
    name = names[-1]
    if "moe" in names and "shared" not in names:
        if name in _TP_MOE_STACK:
            return "expert" if moe_sharded else "rep"
        return "rep"                      # router / router_bias
    if name in _TP_ATTN_COL:
        return "col"
    if name in _TP_ATTN_ROW:
        return "row"
    if name in _TP_ATTN_COL_BIAS:
        return "colbias"
    if name in _TP_FFN_COL:
        return "col" if ffn_sharded else "rep"
    if name in _TP_FFN_ROW:
        return "row" if ffn_sharded else "rep"
    if name in _TP_FFN_COL_BIAS:
        return "colbias" if ffn_sharded else "rep"
    return "rep"


def _check_div(n: int, tp: int, what: str, where: str) -> None:
    if n % tp:
        raise ValueError(
            f"serve TP: {where}: {what} = {n} is not divisible by the "
            f"model-axis size {tp} — pick a mesh that divides it (or "
            f"--mesh 1)")


def _array_tp_spec(leaf, mode: str, tp: int, where: str) -> P:
    nd = leaf.ndim
    if mode == "rep" or nd == 0:
        return P()
    if mode == "col":
        _check_div(leaf.shape[nd - 2], tp, "out-features", where)
        return _axis_spec(nd, nd - 2)
    if mode == "row":
        _check_div(leaf.shape[nd - 1], tp, "in-features", where)
        return _axis_spec(nd, nd - 1)
    if mode == "colbias":
        _check_div(leaf.shape[nd - 1], tp, "bias length", where)
        return _axis_spec(nd, nd - 1)
    # expert stacks: [..., E, f, d] / [..., E, d, f]
    _check_div(leaf.shape[nd - 3], tp, "n_experts", where)
    return _axis_spec(nd, nd - 3)


def _qtensor_tp_spec(qt, mode: str, tp: int, where: str):
    """Spec-QTensor: a QTensor whose q/scale slots hold PartitionSpecs and
    whose static aux matches the parameter leaf exactly, so it flattens
    leaf-aligned with the params tree (shard_map in_specs / tree.map)."""
    from repro.quant.quantizers import QTensor
    nd = qt.q.ndim
    if mode in ("col", "expert"):
        ax = nd - 2 if mode == "col" else nd - 3
        _check_div(qt.q.shape[ax], tp,
                   "out-features" if mode == "col" else "n_experts", where)
        qs, ss = _axis_spec(nd, ax), _axis_spec(qt.scale.ndim, ax)
    elif mode == "row":
        # row-sharding splits the stored (possibly nibble-packed) in-dim:
        # the blocks must be padding-free and group/byte aligned per shard,
        # else shard-local dequantization would see phantom columns
        if qt.in_features is not None and qt.in_features != qt.stored_in_dim:
            raise ValueError(
                f"serve TP: {where}: packed weight has in-feature padding "
                f"({qt.in_features} logical vs {qt.stored_in_dim} stored) — "
                "row-sharding would split mid-pad; use --mesh 1 or repack "
                "with an aligned group size")
        _check_div(qt.q.shape[nd - 1], tp, "stored in-features", where)
        if qt.group > 0:
            _check_div(qt.stored_in_dim // tp, qt.group,
                       "per-shard in-features (scale-group alignment)", where)
        qs = _axis_spec(nd, nd - 1)
        # per-channel scales ([..., out, 1]) replicate; grouped scales split
        # with their columns
        ss = P() if qt.scale.shape[-1] == 1 \
            else _axis_spec(qt.scale.ndim, qt.scale.ndim - 1)
    else:
        qs, ss = P(), P()
    spec = object.__new__(QTensor)
    spec.q, spec.scale, spec.zero = qs, ss, None
    spec.bits, spec.group = qt.bits, qt.group
    spec.in_features, spec.packed = qt.in_features, qt.packed
    return spec


def serve_param_specs(cfg: ModelConfig, params, tp: int, *,
                      ffn_sharded: bool, moe_sharded: bool):
    """PartitionSpec tree for the paged serve shard_map (QTensor leaves get
    spec-QTensors).  Raises with an actionable message on any dimension the
    mesh cannot divide."""
    from repro.quant.quantizers import QTensor
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QTensor))
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        mode = _leaf_mode(names, ffn_sharded, moe_sharded)
        where = "/".join(names)
        if isinstance(leaf, QTensor):
            specs.append(_qtensor_tp_spec(leaf, mode, tp, where))
        else:
            specs.append(_array_tp_spec(leaf, mode, tp, where))
    return jax.tree_util.tree_unflatten(treedef, specs)


@dataclass(frozen=True)
class ServeTPPlan:
    """Everything the paged engine needs to run one decode/prefill program
    tensor-parallel over the mesh 'model' axis: the per-leaf parameter
    specs, the per-adapter pool specs, and the two trace-time flags that
    gate the FFN/MoE psums (see repro.models.common.tp_context)."""
    mesh: Any
    tp: int
    cfg: ModelConfig
    ffn_sharded: bool
    moe_sharded: bool
    param_specs: Any
    pool_specs: Any

    def local_cfg(self) -> ModelConfig:
        """Per-shard config: head counts divided over the model axis (the
        layer code derives every other dimension from array shapes)."""
        cfg, tp = self.cfg, self.tp
        if cfg.attn_type == "gqa":
            return dataclasses.replace(cfg, n_heads=cfg.n_heads // tp,
                                       n_kv_heads=cfg.n_kv_heads // tp)
        if cfg.attn_type == "mla":
            return dataclasses.replace(cfg, n_heads=cfg.n_heads // tp)
        return cfg

    def psums_per_token(self) -> int:
        """Decode-step collective count (the acceptance check's analytic
        reference): one psum per attention layer, plus the FFN/MoE psums
        when those sub-blocks shard."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        if cfg.family == "hybrid":
            groups = cfg.n_layers // cfg.shared_attn_every
            return groups * (1 + int(self.ffn_sharded))
        n_moe = 0
        if cfg.n_experts:
            n_moe = (cfg.n_layers - cfg.n_dense_layers) \
                if cfg.n_dense_layers else cfg.n_layers
        n_mlp = cfg.n_layers - n_moe
        shared_ffn = n_moe if cfg.n_shared_experts else 0
        return (cfg.n_layers
                + int(self.ffn_sharded) * (n_mlp + shared_ffn)
                + int(self.moe_sharded) * n_moe)

    def psum_bytes_per_token(self, dtype_bytes: int = 4) -> int:
        """Interconnect bytes one decoded token pays to psums (f32 partials
        by default — the compute dtype of the reduced test configs)."""
        return self.psums_per_token() * self.cfg.d_model * dtype_bytes


def serve_tp_plan(cfg: ModelConfig, params, mesh, *, rot=None,
                  kv_bits: int = 4, state_bits: int = 8
                  ) -> Optional[ServeTPPlan]:
    """Build the serve-TP plan for a mesh, or None when the mesh has a
    trivial 'model' axis (single-device serving, zero TP machinery)."""
    tp = tp_degree(mesh)
    if tp <= 1:
        return None
    if cfg.attn_type == "gqa":
        _check_div(cfg.n_heads, tp, "n_heads", cfg.arch_id)
        _check_div(cfg.n_kv_heads, tp, "n_kv_heads", cfg.arch_id)
    elif cfg.attn_type == "mla":
        _check_div(cfg.n_heads, tp, "n_heads", cfg.arch_id)
    # FFN shards only without an online R4 (the WHT mixes the full hidden
    # dim) and when every FFN hidden divides evenly (int4 nibble pairs must
    # not straddle a shard boundary)
    r4_online = rot is not None and rot.get("r4") is not None
    f_dims = [cfg.d_ff]
    if cfg.n_experts and cfg.n_shared_experts:
        f_dims.append(cfg.ffn_hidden * cfg.n_shared_experts)
    ffn_sharded = (not r4_online) and cfg.family != "ssm" and all(
        f % tp == 0 and (f // tp) % 2 == 0 for f in f_dims)
    moe_sharded = bool(cfg.n_experts) and cfg.moe_impl == "ragged" \
        and cfg.n_experts % tp == 0
    param_specs = serve_param_specs(cfg, params, tp,
                                    ffn_sharded=ffn_sharded,
                                    moe_sharded=moe_sharded)
    from repro.serve.cache_adapters import adapters_for
    ads = adapters_for(cfg, kv_bits=kv_bits, state_bits=state_bits)
    pool_specs = {name: ad.partition_specs(tp) for name, ad in ads.items()}
    return ServeTPPlan(mesh=mesh, tp=tp, cfg=cfg, ffn_sharded=ffn_sharded,
                       moe_sharded=moe_sharded, param_specs=param_specs,
                       pool_specs=pool_specs)


def place_serve_params(params, plan: ServeTPPlan):
    """device_put a param tree against the plan's specs, shard-wise.

    Host leaves (the artifact loader's np.memmap views) go through
    ``jax.make_array_from_callback``: each device reads ONLY its own block
    off the mmap — a big packed artifact cold-boots without ever
    materializing a full projection weight on one device (the manifest's
    64-byte-aligned per-tensor offsets make the per-shard reads free).
    Already-committed jax.Arrays take the plain device_put path (a no-op
    when they are already placed correctly)."""
    import numpy as np
    mesh = plan.mesh

    def put(leaf, spec):
        sharding = NamedSharding(mesh, spec)
        if isinstance(leaf, jax.Array):
            return jax.device_put(leaf, sharding)
        arr = np.asarray(leaf)
        return jax.make_array_from_callback(
            arr.shape, sharding,
            lambda idx, a=arr: np.ascontiguousarray(a[idx]))

    return jax.tree.map(put, params, plan.param_specs)


def place_serve_pool(state, plan: ServeTPPlan):
    """device_put the page-pool state against the plan's adapter specs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(plan.mesh, s)),
        state, plan.pool_specs,
        is_leaf=lambda x: isinstance(x, P))
