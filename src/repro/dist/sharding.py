"""Mesh-aware sharding helper consumed by the model code and launchers.

Models call ``shd(x, name)`` at annotation points (see
``repro.models.common.NO_SHARD`` for the single-device no-op); ``Sharding``
resolves the name to a ``PartitionSpec`` over the bound mesh and applies a
``with_sharding_constraint``.  It also derives parameter / batch / cache
specs for jit ``in_shardings`` from pytree structure alone, so the same rules
cover every architecture in ``repro.configs`` without per-model tables:

  * params — the largest axis divisible by the 'model' axis size is
    tensor-parallel sharded; vectors and small leaves replicate.  Leading
    layer-stack axes are never sharded (they are scanned over).
  * batch  — leading (batch) axis over all non-'model' axes (data ± pod).
  * cache  — axis 1 (batch; caches are stacked [L, B, ...]) over data axes.

Any mesh with a 'model' axis and one or more data-like axes works; the 'pod'
axis of the multi-pod production mesh composes into the data group
automatically.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


class Sharding:
    def __init__(self, cfg: ModelConfig, mesh):
        self.cfg = cfg
        self.mesh = mesh
        axes = tuple(mesh.axis_names)
        self.model_axis = "model" if "model" in axes else None
        dp = tuple(a for a in axes if a != "model")
        # one PartitionSpec entry covering the whole data group
        self.dp: object = dp[0] if len(dp) == 1 else dp
        self.model_size = int(mesh.shape["model"]) if self.model_axis else 1
        m, d = self.model_axis, self.dp
        self._act_specs: Dict[str, P] = {
            # [B, S, D] residual stream / [B, S, F] ffn hidden
            "act_bsd": P(d, None, None),
            "act_bsf": P(d, None, None),
            # [B, S, V] logits: vocab tensor-parallel (see cross_entropy)
            "logits": P(d, None, m),
            # [B, S, H, hd] attention tensors
            "act_bshd_heads": P(d, None, m, None),
            "act_bskd_heads": P(d, None, m, None),
            "act_bshd_seq": P(d, m, None, None),
            "act_bshd_rep": P(d, None, None, None),
            # [B, S, H, P] ssm heads
            "ssm_bshp": P(d, None, m, None),
            # [G, g, D] grouped tokens / [G, E, cap, D] dispatched experts
            "moe_gtd": P(d, None, None),
            "moe_gecd": P(d, m, None, None),
        }

    # -- activation constraints (models call shd(x, name)) ------------------ #
    def spec(self, name: str) -> P:
        return self._act_specs[name]

    def __call__(self, x, name: str):
        s = self._act_specs.get(name)
        if s is None or x.ndim != len(s):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, s))

    # -- pytree spec derivation --------------------------------------------- #
    def named(self, spec_tree):
        """PartitionSpec tree -> NamedSharding tree on the bound mesh."""
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))

    def _leaf_param_spec(self, leaf) -> P:
        shape = tuple(leaf.shape)
        ms = self.model_size
        if self.model_axis is None or len(shape) < 2 or ms <= 1:
            return P(*([None] * len(shape)))
        # candidate tensor-parallel axes: divisible by the model axis and big
        # enough that splitting pays; never the leading layer-stack axis when
        # the leaf is stacked (ndim >= 3).
        first = 1 if len(shape) >= 3 else 0
        best, best_size = None, 0
        for i in range(first, len(shape)):
            if shape[i] % ms == 0 and shape[i] >= 2 * ms and shape[i] > best_size:
                best, best_size = i, shape[i]
        spec = [None] * len(shape)
        if best is not None:
            spec[best] = self.model_axis
        return P(*spec)

    def param_specs(self, params):
        """Tensor-parallel specs for a params pytree (arrays or SDS)."""
        return jax.tree.map(self._leaf_param_spec, params)

    def batch_specs(self, batch):
        """Data-parallel specs: leading axis over the data group."""
        return jax.tree.map(
            lambda x: P(*((self.dp,) + (None,) * (x.ndim - 1)))
            if x.ndim >= 1 else P(), batch)

    def cache_specs(self, cache):
        """Decode caches are stacked [L, B, ...]: shard B over data."""
        return jax.tree.map(
            lambda x: P(*((None, self.dp) + (None,) * (x.ndim - 2)))
            if x.ndim >= 2 else P(), cache)
