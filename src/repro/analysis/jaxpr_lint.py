"""Trace-time program lint: structural checks over ``ClosedJaxpr``s.

The repo's hardest-won program invariants — exactly one psum per layer on
the TP serve path, zero host callbacks when observability is disarmed,
packed ``QTensor`` payloads staying integer outside the sanctioned dequant
sites, donated pool buffers actually aliased — used to be enforced by
one-off test assertions (the worst a substring match over
``str(jax.make_jaxpr(...))``).  This module walks the jaxpr *structurally*:

  * ``iter_eqns``        — depth-first equation walk that recurses into
                           every sub-jaxpr (``pjit``/``shard_map``/``scan``/
                           ``while``/``cond``/custom-derivative calls),
                           tagging each equation with its enclosing
                           primitive path (so a rule can ask "is this psum
                           inside a scan body?").
  * ``collective_census``— count/kind of collective equations.
  * ``callback_census``  — host-callback primitives
                           (``debug_callback``/``io_callback``/
                           ``pure_callback``).
  * ``packed_taint``     — forward dataflow from designated invars (packed
                           quantized payloads) with a visitor for dtype
                           rules.
  * ``aliased_donations``— ``tf.aliasing_output`` markers in a lowered
                           module (the compiled-executable side of
                           ``donate_argnums``).

Rules that interpret these walks live in ``repro.analysis.rules``;
contract declaration (the shared source of truth between the owning
modules, pytest, and CI) lives in ``repro.analysis`` itself.

Everything here duck-types the jaxpr data structures (``.eqns``,
``.jaxpr``, ``.invars``…) rather than importing private jax classes, so
the walker survives jax's module reshuffles as long as the IR shape holds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, List, Set, Tuple

__all__ = [
    "EqnSite", "iter_eqns", "collective_census", "callback_census",
    "packed_taint", "packed_payload_indices", "aliased_donations",
    "eqn_site_names", "COLLECTIVE_PRIMS", "CALLBACK_PRIMS",
]

# collective primitives the census recognizes (jax primitive names)
COLLECTIVE_PRIMS = ("psum", "all_gather", "all_to_all", "ppermute",
                    "reduce_scatter", "pmax", "pmin", "axis_index")

# host-callback primitives: anything that re-enters Python from compiled code
CALLBACK_PRIMS = ("debug_callback", "io_callback", "pure_callback",
                  "python_callback", "callback")

# primitives whose sub-jaxprs are an *opaque compiled kernel* — the fused
# dequant inside a Pallas kernel is the sanctioned site by construction, so
# dtype rules must not descend into it (censuses still may).
OPAQUE_KERNEL_PRIMS = ("pallas_call",)


def _is_jaxpr(obj) -> bool:
    return hasattr(obj, "eqns") and hasattr(obj, "invars")


def _as_jaxpr(obj):
    """Unwrap a ClosedJaxpr (``.jaxpr``) to the raw jaxpr, else pass through."""
    inner = getattr(obj, "jaxpr", None)
    return inner if inner is not None and _is_jaxpr(inner) else obj


def _sub_jaxprs(eqn) -> List[Tuple[str, Any]]:
    """Every (param_name, jaxpr) reachable from an equation's params."""
    out = []
    for k, v in eqn.params.items():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            j = _as_jaxpr(item)
            if _is_jaxpr(j):
                out.append((k, j))
    return out


@dataclass(frozen=True)
class EqnSite:
    """One equation plus where the walker found it."""
    eqn: Any
    path: Tuple[str, ...]       # enclosing primitive names, outermost first

    @property
    def prim(self) -> str:
        return self.eqn.primitive.name

    def in_scope(self, prim_name: str) -> bool:
        return prim_name in self.path

    @property
    def in_scan(self) -> bool:
        return "scan" in self.path or "while" in self.path

    @property
    def in_opaque_kernel(self) -> bool:
        return any(p in OPAQUE_KERNEL_PRIMS for p in self.path)


def iter_eqns(closed_jaxpr, _path: Tuple[str, ...] = (),
              _depth: int = 0) -> Iterator[EqnSite]:
    """Depth-first walk over every equation, recursing into sub-jaxprs.

    Each structural occurrence is visited once — matching what
    ``str(jaxpr)`` prints, which the old substring censuses counted — and
    tagged with the stack of enclosing primitive names.
    """
    if _depth > 64:     # cycle/pathology guard; real jaxprs are shallow
        return
    jaxpr = _as_jaxpr(closed_jaxpr)
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, _path)
        for _, sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, _path + (eqn.primitive.name,),
                                 _depth + 1)


def eqn_site_names(eqn) -> Set[str]:
    """Function names on the equation's trace-time Python call stack.

    Used to attribute an equation to the source seam that traced it (e.g.
    a ``convert_element_type`` inside ``dense_weight``).  Returns an empty
    set when jax recorded no traceback (rules should treat that
    conservatively).
    """
    names: Set[str] = set()
    src = getattr(eqn, "source_info", None)
    tb = getattr(src, "traceback", None)
    if tb is not None:
        try:
            for frame in tb.frames:
                names.add(frame.function_name)
        except Exception:
            pass
    ns = getattr(src, "name_stack", None)
    if ns is not None:
        names.update(str(ns).replace("(", "/").replace(")", "/").split("/"))
    names.discard("")
    return names


# --------------------------------------------------------------------------- #
# Censuses
# --------------------------------------------------------------------------- #
def collective_census(closed_jaxpr,
                      prims: Tuple[str, ...] = COLLECTIVE_PRIMS
                      ) -> Dict[str, List[EqnSite]]:
    """Map collective primitive name -> structural occurrence sites."""
    out: Dict[str, List[EqnSite]] = {}
    for site in iter_eqns(closed_jaxpr):
        if site.prim in prims:
            out.setdefault(site.prim, []).append(site)
    return out


def callback_census(closed_jaxpr) -> List[EqnSite]:
    """Every host-callback equation in the program."""
    return [s for s in iter_eqns(closed_jaxpr) if s.prim in CALLBACK_PRIMS]


# --------------------------------------------------------------------------- #
# Packed-payload taint walk
# --------------------------------------------------------------------------- #
def packed_payload_indices(example_args) -> Set[int]:
    """Flat invar indices of packed/quantized ``QTensor`` code payloads.

    ``example_args`` is the tuple of arguments a program was traced with;
    the returned indices address the program's flattened invars (jax's
    default pytree flatten order, which ``QTensor`` registers as
    ``(q, scale, zero)``).
    """
    import jax
    from repro.quant.quantizers import QTensor

    outer, _ = jax.tree_util.tree_flatten(
        example_args, is_leaf=lambda x: isinstance(x, QTensor))
    idx = 0
    payloads: Set[int] = set()
    for leaf in outer:
        n = len(jax.tree_util.tree_leaves(leaf))
        if isinstance(leaf, QTensor) and leaf.bits < 16:
            payloads.add(idx)           # q is the first registered child
        idx += n
    return payloads


def _is_float_var(v) -> bool:
    try:
        return "float" in str(v.aval.dtype)
    except Exception:
        return False


def packed_taint(closed_jaxpr, payload_invars: Set[int],
                 visit: Callable[[EqnSite, bool], None],
                 _path: Tuple[str, ...] = (), _depth: int = 0) -> None:
    """Forward *code* taint from designated invars (packed integer
    payloads).

    ``visit(site, tainted)`` is called for every equation with whether any
    of its inputs descend from a payload invar **while still integer**:
    taint propagates only to non-float outputs — the moment codes are
    converted to a float dtype they stop being packed payload (the convert
    itself is visited as tainted; whether it was sanctioned is the rule's
    call), so ordinary float math downstream of a legitimate dequant is
    never flagged.  Binding follows the suffix-aligned argument convention
    into sub-jaxprs (``pjit``/``scan``/``cond``/``while``-body/
    ``shard_map``, whose body invars are a suffix of the call equation's
    invars).
    """
    if _depth > 64:
        return
    jaxpr = _as_jaxpr(closed_jaxpr)
    tainted: Set[int] = set()       # id() of tainted Var objects
    for i, v in enumerate(jaxpr.invars):
        if i in payload_invars:
            tainted.add(id(v))

    def var_tainted(v) -> bool:
        return id(v) in tainted

    for eqn in jaxpr.eqns:
        hit = any(var_tainted(v) for v in eqn.invars
                  if not isinstance(v, (int, float)))
        site = EqnSite(eqn, _path)
        visit(site, hit)
        if hit:
            for v in eqn.outvars:
                if not _is_float_var(v):
                    tainted.add(id(v))
        for _, sub in _sub_jaxprs(eqn):
            sj = _as_jaxpr(sub)
            n = len(sj.invars)
            bind = eqn.invars[-n:] if 0 < n <= len(eqn.invars) else []
            sub_payloads = {i for i, v in enumerate(bind) if var_tainted(v)}
            packed_taint(sub, sub_payloads, visit,
                         _path + (eqn.primitive.name,), _depth + 1)


# --------------------------------------------------------------------------- #
# Donation / aliasing
# --------------------------------------------------------------------------- #
def aliased_donations(lowered) -> int:
    """Number of program inputs the lowered module aliases to outputs.

    ``jax.jit(fn, donate_argnums=...).lower(*args)`` records accepted
    donations as ``tf.aliasing_output`` attributes on the MLIR arguments —
    the marker the compiled executable honors.  A donated-but-unaliased
    buffer (shape/dtype mismatch with every output) never receives the
    attribute, which is exactly the regression the donation audit exists
    to catch.
    """
    text = lowered.as_text() if hasattr(lowered, "as_text") else str(lowered)
    return text.count("tf.aliasing_output")
