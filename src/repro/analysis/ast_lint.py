"""Repo-discipline AST lint over ``src/repro``.

Four rules, each encoding a convention this repo has already paid for
violating once:

  ``time-time``         ``time.time()`` measures wall-clock time — NTP
                        steps corrupt elapsed-time brackets.  Intervals
                        must use ``time.perf_counter()``; the few
                        intentional wall-clock *stamps* (trace correlation
                        fields, checkpoint metadata) are suppressed with a
                        justification in ``suppressions.toml``.
  ``prng-reuse``        a PRNG key passed to two consumers without an
                        intervening ``split``/``fold_in`` correlates the
                        streams (the PR-5 calibration bug: capture
                        sampling and rotation inits shared a key).
                        Branch-aware: uses in mutually exclusive ``if``
                        arms do not conflict; a consumer inside a loop of
                        a key created outside it is flagged.
  ``host-sync-in-jit``  ``.item()`` / ``np.asarray`` / ``np.array`` /
                        ``jax.device_get`` / ``block_until_ready`` inside
                        a function decorated with (or passed to)
                        ``jax.jit`` — a host sync inside a traced function
                        either fails at trace time or silently fences the
                        program it was supposed to stay out of.
  ``mutable-default``   mutable default arguments ([], {}, set(), ...).

``lint_file``/``lint_tree`` return ``repro.analysis.rules.Finding``s with
``path:line`` locations; suppression handling lives in the CLI layer.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.rules import Finding

__all__ = ["AST_RULES", "lint_source", "lint_file", "lint_tree"]

AST_RULES = ("time-time", "prng-reuse", "host-sync-in-jit",
             "mutable-default")

# callees that *derive* a new key rather than consuming one
_KEY_DERIVERS = {"split", "fold_in", "key_data", "PRNGKey", "key",
                 "wrap_key_data", "clone"}
# assignments from these calls introduce a key variable
_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data"}

_SYNC_CALLS = {"asarray", "array", "device_get", "block_until_ready",
               "item"}


def _call_name(func: ast.AST) -> str:
    """Terminal name of a call target: ``jax.random.split`` -> ``split``."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _qual_parts(func: ast.AST) -> List[str]:
    """Dotted parts of a call target: ``np.asarray`` -> ['np','asarray']."""
    parts: List[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


# --------------------------------------------------------------------------- #
# time-time
# --------------------------------------------------------------------------- #
def _rule_time_time(tree: ast.AST, path: str, src_lines) -> List[Finding]:
    out = []
    # names bound by `from time import time [as alias]`
    aliases: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name == "time":
                    aliases.add(a.asname or a.name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        hit = (isinstance(f, ast.Attribute) and f.attr == "time"
               and isinstance(f.value, ast.Name) and f.value.id == "time") \
            or (isinstance(f, ast.Name) and f.id in aliases)
        if hit:
            out.append(Finding(
                "time-time", f"{path}:{node.lineno}",
                "time.time() is wall-clock (NTP-steppable); use "
                "time.perf_counter() for intervals, or suppress an "
                "intentional wall-clock stamp with a justification"))
    return out


# --------------------------------------------------------------------------- #
# mutable-default
# --------------------------------------------------------------------------- #
def _rule_mutable_default(tree: ast.AST, path: str, _src) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        args = node.args
        for d in list(args.defaults) + [d for d in args.kw_defaults if d]:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in ("list", "dict", "set", "bytearray"))
            if bad:
                fn = getattr(node, "name", "<lambda>")
                out.append(Finding(
                    "mutable-default", f"{path}:{d.lineno}",
                    f"mutable default argument in {fn}(); default to None "
                    "and construct inside the body"))
    return out


# --------------------------------------------------------------------------- #
# host-sync-in-jit
# --------------------------------------------------------------------------- #
def _is_jit_expr(node: ast.AST) -> bool:
    """``jax.jit`` / ``jit`` / ``partial(jax.jit, ...)`` / ``jax.jit(...)``."""
    if isinstance(node, ast.Call):
        parts = _qual_parts(node.func)
        if parts and parts[-1] == "partial":
            return any(_is_jit_expr(a) for a in node.args)
        return parts[-1:] == ["jit"] if parts else False
    parts = _qual_parts(node)
    return bool(parts) and parts[-1] == "jit"


def _rule_host_sync(tree: ast.AST, path: str, _src) -> List[Finding]:
    out = []
    # function names passed to jax.jit(...) in this module
    jit_wrapped: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit_expr(node.func) and \
                node.args and isinstance(node.args[0], ast.Name):
            jit_wrapped.add(node.args[0].id)

    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jitted = any(_is_jit_expr(d) for d in node.decorator_list) \
            or node.name in jit_wrapped
        if not jitted:
            continue
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            parts = _qual_parts(sub.func)
            if not parts:
                continue
            name = parts[-1]
            if name in _SYNC_CALLS and (
                    name not in ("asarray", "array")
                    or parts[0] in ("np", "numpy", "onp")):
                out.append(Finding(
                    "host-sync-in-jit", f"{path}:{sub.lineno}",
                    f"{'.'.join(parts)}() inside jit-traced function "
                    f"{node.name}(): host syncs do not belong in compiled "
                    "programs"))
    return out


# --------------------------------------------------------------------------- #
# prng-reuse
# --------------------------------------------------------------------------- #
class _KeyUse:
    __slots__ = ("line", "branch", "loops", "snippet")

    def __init__(self, line, branch, loops, snippet):
        self.line, self.branch, self.loops = line, branch, loops
        self.snippet = snippet


def _branches_compatible(a: Tuple, b: Tuple) -> bool:
    """Two branch paths conflict unless they take different arms of some
    shared ``if``."""
    arms_a = dict(a)
    for node_id, arm in b:
        if node_id in arms_a and arms_a[node_id] != arm:
            return False
    return True


def _terminates(stmts) -> bool:
    """Does this block unconditionally leave the enclosing scope/loop?"""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


class _PrngScope(ast.NodeVisitor):
    """Per-function-scope key tracking with branch and loop context."""

    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._seen: Set[Tuple[str, int]] = set()
        self._reset_scope()
        self.branch: Tuple = ()
        self.loops: Tuple = ()

    def _reset_scope(self):
        self.gen: Dict[str, int] = {}
        self.born_loops: Dict[Tuple[str, int], Tuple] = {}
        self.uses: Dict[Tuple[str, int], List[_KeyUse]] = {}

    # ---- block walking with early-return awareness ------------------------
    def _visit_block(self, stmts):
        """Visit a statement list; an ``if`` whose body terminates (return/
        raise/break/continue) makes the REST of the block its implicit
        else-arm — the early-return idiom must not read as key reuse."""
        stmts = list(stmts)
        for i, s in enumerate(stmts):
            if isinstance(s, ast.If) and _terminates(s.body) and not s.orelse:
                self.visit(s.test)
                outer = self.branch
                self.branch = outer + ((id(s), "body"),)
                self._visit_block(s.body)
                self.branch = outer + ((id(s), "orelse"),)
                self._visit_block(stmts[i + 1:])
                self.branch = outer
                return
            self.visit(s)

    # ---- scope boundaries -------------------------------------------------
    def visit_FunctionDef(self, node):
        outer = (self.gen, self.born_loops, self.uses, self.branch,
                 self.loops)
        self._reset_scope()
        self.branch, self.loops = (), ()
        self._visit_block(node.body)
        self._flush()
        (self.gen, self.born_loops, self.uses, self.branch,
         self.loops) = outer

    visit_AsyncFunctionDef = visit_FunctionDef

    # ---- key births / rebinds --------------------------------------------
    def _is_key_rhs(self, value: ast.AST) -> bool:
        if isinstance(value, ast.Call):
            return _call_name(value.func) in _KEY_MAKERS
        if isinstance(value, ast.Subscript):
            return self._is_key_rhs(value.value) or (
                isinstance(value.value, ast.Name)
                and value.value.id in self.gen)
        if isinstance(value, ast.Name):
            return value.id in self.gen
        return False

    def _bind(self, target: ast.AST):
        names = []
        if isinstance(target, ast.Name):
            names = [target.id]
        elif isinstance(target, (ast.Tuple, ast.List)):
            names = [e.id for e in target.elts if isinstance(e, ast.Name)]
        for n in names:
            self.gen[n] = self.gen.get(n, 0) + 1
            self.born_loops[(n, self.gen[n])] = self.loops

    def visit_Assign(self, node):
        self.visit(node.value)
        if self._is_key_rhs(node.value):
            for t in node.targets:
                self._bind(t)

    def visit_AugAssign(self, node):
        self.visit(node.value)

    # ---- consumers --------------------------------------------------------
    def _key_expr(self, arg: ast.AST) -> Optional[Tuple[str, str]]:
        """(var_name, display) when ``arg`` reads a tracked key."""
        if isinstance(arg, ast.Name) and arg.id in self.gen:
            return arg.id, arg.id
        if isinstance(arg, ast.Subscript) and \
                isinstance(arg.value, ast.Name) and \
                arg.value.id in self.gen and \
                isinstance(arg.slice, ast.Constant):
            return (f"{arg.value.id}[{arg.slice.value!r}]",
                    f"{arg.value.id}[{arg.slice.value!r}]")
        return None

    def visit_Call(self, node):
        callee = _call_name(node.func)
        if callee not in _KEY_DERIVERS:
            for arg in list(node.args) + [k.value for k in node.keywords]:
                ref = self._key_expr(arg)
                if ref is None:
                    continue
                var, disp = ref
                base = var.split("[")[0]
                key = (var, self.gen.get(base, 0))
                self.uses.setdefault(key, []).append(_KeyUse(
                    node.lineno, self.branch, self.loops,
                    f"{disp} -> {callee or '<call>'}"))
        self.generic_visit(node)

    # ---- control flow -----------------------------------------------------
    def visit_If(self, node):
        self.visit(node.test)
        outer = self.branch
        self.branch = outer + ((id(node), "body"),)
        self._visit_block(node.body)
        self.branch = outer + ((id(node), "orelse"),)
        self._visit_block(node.orelse)
        self.branch = outer

    def _visit_loop(self, node):
        outer = self.loops
        self.loops = outer + (id(node),)
        self._visit_block(node.body)
        self.loops = outer
        self._visit_block(node.orelse)

    def visit_For(self, node):
        self.visit(node.iter)
        self._visit_loop(node)

    def visit_While(self, node):
        self.visit(node.test)
        self._visit_loop(node)

    # ---- reporting --------------------------------------------------------
    def _emit(self, line: int, var: str, msg: str):
        if (var, line) in self._seen:
            return
        self._seen.add((var, line))
        self.findings.append(Finding(
            "prng-reuse", f"{self.path}:{line}", msg))

    def _flush(self):
        for (var, gen), uses in self.uses.items():
            base = var.split("[")[0]
            born = self.born_loops.get((base, gen), ())
            # consumer inside a loop the key was created outside of
            for u in uses:
                if len(u.loops) > len(born):
                    self._emit(
                        u.line, var,
                        f"PRNG key {var!r} consumed inside a loop it was "
                        "created outside of; fold_in/split per iteration")
            if len(uses) < 2:
                continue
            for i, a in enumerate(uses):
                for b in uses[i + 1:]:
                    if a.line != b.line and \
                            _branches_compatible(a.branch, b.branch):
                        self._emit(
                            b.line, var,
                            f"PRNG key {var!r} passed to two consumers "
                            f"({a.snippet} at line {a.line}, then "
                            f"{b.snippet}) without split/fold_in")


def _rule_prng_reuse(tree: ast.AST, path: str, _src) -> List[Finding]:
    scope = _PrngScope(path)
    # module level counts as a scope too (launch CLIs build keys inline)
    for node in tree.body:
        scope.visit(node)
    scope._flush()
    return scope.findings


_RULE_FNS = {
    "time-time": _rule_time_time,
    "prng-reuse": _rule_prng_reuse,
    "host-sync-in-jit": _rule_host_sync,
    "mutable-default": _rule_mutable_default,
}


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def lint_source(src: str, path: str,
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    tree = ast.parse(src, filename=path)
    lines = src.splitlines()
    out: List[Finding] = []
    for rule in rules or AST_RULES:
        if rule not in _RULE_FNS:
            raise ValueError(f"unknown AST rule {rule!r}; "
                             f"known: {', '.join(AST_RULES)}")
        out.extend(_RULE_FNS[rule](tree, path, lines))
    return out


def lint_file(path: Path, root: Optional[Path] = None,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    rel = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(), rel, rules)


def lint_tree(root: Path, subdir: str = "src/repro",
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every ``.py`` under ``root/subdir`` (repo-relative locations)."""
    out: List[Finding] = []
    for p in sorted((root / subdir).rglob("*.py")):
        out.extend(lint_file(p, root=root, rules=rules))
    return out
