"""repro.analysis: compiled-program contract checker + repo-discipline lint.

Two layers:

  * **Trace-time program lint** (:mod:`repro.analysis.jaxpr_lint`,
    :mod:`repro.analysis.rules`): walk ``ClosedJaxpr``s structurally and
    check ``Contract`` objects declared at the seams that own them
    (``repro.serve.engine``, ``repro.core.qr_orth``,
    ``repro.models.common``, ``repro.obs.quant_health``).
  * **AST repo lint** (:mod:`repro.analysis.ast_lint`): convention rules
    over ``src/repro`` source, with a checked-in suppression file
    (``analysis/suppressions.toml``) requiring a justification per entry.

CLI: ``python -m repro.analysis`` (see ``__main__.py``).  Exit codes mirror
``repro.obs.bench compare``: 0 clean, 1 findings, 2 usage/config error.
"""
from repro.analysis.ast_lint import (AST_RULES, lint_file, lint_source,
                                     lint_tree)
from repro.analysis.jaxpr_lint import (CALLBACK_PRIMS, COLLECTIVE_PRIMS,
                                       EqnSite, aliased_donations,
                                       callback_census, collective_census,
                                       iter_eqns, packed_payload_indices,
                                       packed_taint)
from repro.analysis.rules import (ALLOWED_DEQUANT_SITES, CollectiveCensus,
                                  Contract, DonationAliased, Finding,
                                  HostCallbackCount, PackedDtypeAudit,
                                  RecompileCount, run_contract,
                                  run_contracts)
from repro.analysis.suppress import (Suppression, filter_findings,
                                     load_suppressions)

__all__ = [
    # contracts + trace-time rules
    "Contract", "Finding", "run_contract", "run_contracts",
    "CollectiveCensus", "HostCallbackCount", "PackedDtypeAudit",
    "DonationAliased", "RecompileCount", "ALLOWED_DEQUANT_SITES",
    # jaxpr walking
    "EqnSite", "iter_eqns", "collective_census", "callback_census",
    "packed_taint", "packed_payload_indices", "aliased_donations",
    "COLLECTIVE_PRIMS", "CALLBACK_PRIMS",
    # AST lint
    "AST_RULES", "lint_source", "lint_file", "lint_tree",
    # suppressions
    "Suppression", "load_suppressions", "filter_findings",
]
