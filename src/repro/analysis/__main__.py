"""``python -m repro.analysis`` — the repo's gating static-analysis run.

Two passes, one verdict:

  1. **AST repo lint** over ``src/repro`` (rules: ``time-time``,
     ``prng-reuse``, ``host-sync-in-jit``, ``mutable-default``), filtered
     through ``src/repro/analysis/suppressions.toml`` — every suppression
     must carry a justification (a bare one is a config error, exit 2),
     and a suppression that matches nothing is itself reported
     (``unused-suppression``).
  2. **Trace-time contracts** on smoke-geometry programs: the sharded
     calibration scan census (structural — valid on one device), and the
     packed-artifact serve engine's contracts (disarmed-obs callbacks,
     packed-dtype audit, donation aliasing).  With ``--devices N >= 2``
     the TP decode census runs too (``XLA_FLAGS`` virtual host devices
     are set before jax imports — pass the flag rather than exporting).

Options::

  --rules a,b,...        run only these rule ids (AST rule names and/or
                         trace rule ids: collective-census, host-callback,
                         packed-dtype, donation, recompile)
  --ast-only             skip the trace-time contract pass (fast; no jax)
  --contracts-only       skip the AST pass
  --baseline FILE        known-findings file: matching fingerprints are
                         reported but do not gate
  --write-baseline FILE  record current findings as the baseline and exit 0
  --devices N            virtual CPU devices for the contract pass

Exit codes mirror ``repro.obs.bench compare``: 0 clean, 1 findings,
2 usage/config error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

from repro.analysis.ast_lint import AST_RULES, lint_tree
from repro.analysis.rules import Finding, run_contract
from repro.analysis.suppress import (SuppressionError, filter_findings,
                                     load_suppressions)

TRACE_RULES = ("collective-census", "host-callback", "packed-dtype",
               "donation", "recompile")

_DEF_SUPPRESSIONS = Path(__file__).resolve().parent / "suppressions.toml"


def _find_root(start: Path) -> Path:
    """Repo root = nearest ancestor holding src/repro."""
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    raise SystemExit(f"error: no src/repro found above {start}")


def _smoke_contracts(devices: int):
    """The declared contracts, instantiated on smoke geometry.

    One engine per declaring seam: the sharded calibration scan (psum
    census is structural, so the host mesh suffices), and the packed-int4
    serve engine on the artifact path (online R3/R4, A8, quantized KV).
    """
    import jax

    from repro.configs import get_config
    from repro.core import qr_orth
    from repro.core.whip import whip
    from repro.kernels.hadamard.ops import online_hadamard
    from repro.launch.mesh import make_calib_mesh
    from repro.models import model as M
    from repro.quant import pack_params
    from repro.serve import PagedServeEngine

    contracts = []
    contracts.append(qr_orth.sharded_scan_contract(make_calib_mesh(), whip))

    key = jax.random.PRNGKey(0)
    rot = {"r3": online_hadamard, "r4": online_hadamard}
    cfg = get_config("llama2-7b").reduced()
    eng = PagedServeEngine(cfg, pack_params(cfg, M.init_params(cfg, key)),
                           rot=rot, a_bits=8, kv_bits=4, batch_slots=2,
                           max_seq=64, page_size=8)
    contracts += eng.analysis_contracts()

    if devices >= 2:
        from repro.launch.mesh import make_serve_mesh
        cfg8 = cfg.replace(n_heads=8, n_kv_heads=8, head_dim=8)
        eng8 = PagedServeEngine(
            cfg8,
            pack_params(cfg8, M.init_params(cfg8, jax.random.fold_in(key, 1))),
            rot=rot, a_bits=8, kv_bits=4, mesh=make_serve_mesh(devices),
            batch_slots=2, max_seq=64, page_size=8)
        tp = [c for c in eng8.analysis_contracts()
              if c.name == "serve/tp-decode-collectives"]
        if not tp:
            raise SystemExit(
                "error: --devices >= 2 but the TP engine declared no "
                "collective-census contract")
        contracts += tp
    return contracts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="compiled-program contract checker + repo lint")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--root", default="", help="repo root (default: auto)")
    ap.add_argument("--suppressions", default="",
                    help=f"suppression file (default: {_DEF_SUPPRESSIONS})")
    ap.add_argument("--baseline", default="",
                    help="known-findings JSON; matches do not gate")
    ap.add_argument("--write-baseline", default="",
                    help="write current findings as the baseline, exit 0")
    ap.add_argument("--ast-only", action="store_true")
    ap.add_argument("--contracts-only", action="store_true")
    ap.add_argument("--devices", type=int, default=1,
                    help="virtual CPU devices for the contract pass")
    args = ap.parse_args(argv)

    if args.ast_only and args.contracts_only:
        print("error: --ast-only and --contracts-only are exclusive",
              file=sys.stderr)
        return 2

    known = AST_RULES + TRACE_RULES
    selected = tuple(r.strip() for r in args.rules.split(",") if r.strip())
    for r in selected:
        if r not in known:
            print(f"error: unknown rule {r!r}; known: {', '.join(known)}",
                  file=sys.stderr)
            return 2
    want = (lambda r: r in selected) if selected else (lambda r: True)

    root = Path(args.root) if args.root else _find_root(Path.cwd())
    findings: list = []

    # ---- pass 1: AST lint ------------------------------------------------ #
    if not args.contracts_only:
        ast_rules = tuple(r for r in AST_RULES if want(r))
        if ast_rules:
            raw = lint_tree(root, rules=ast_rules)
            sup_path = Path(args.suppressions) if args.suppressions \
                else _DEF_SUPPRESSIONS
            try:
                sups = load_suppressions(sup_path)
            except SuppressionError as e:
                print(f"error: {e}", file=sys.stderr)
                return 2
            kept, unused = filter_findings(raw, sups, root)
            findings += kept
            findings += [
                Finding("unused-suppression",
                        str(sup_path.relative_to(root)) if
                        sup_path.is_relative_to(root) else str(sup_path),
                        f"suppression (rule={s.rule}, path={s.path}, "
                        f"match={s.match!r}) matched no finding — delete it")
                for s in unused]

    # ---- pass 2: trace-time contracts ------------------------------------ #
    if not args.ast_only and any(want(r) for r in TRACE_RULES):
        if args.devices > 1 and "xla_force_host_platform_device_count" \
                not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") +
                f" --xla_force_host_platform_device_count={args.devices}"
            ).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        for contract in _smoke_contracts(args.devices):
            relevant = [c for c in contract.checks if want(c.rule)]
            if not relevant:
                continue
            findings += run_contract(
                type(contract)(name=contract.name, owner=contract.owner,
                               checks=tuple(relevant), trace=contract.trace,
                               lower=contract.lower, live=contract.live,
                               description=contract.description))

    # ---- verdict --------------------------------------------------------- #
    if args.write_baseline:
        Path(args.write_baseline).write_text(json.dumps(
            {"fingerprints": sorted({f.fingerprint for f in findings})},
            indent=2) + "\n")
        print(f"wrote {len(findings)} finding fingerprint(s) to "
              f"{args.write_baseline}")
        return 0

    baselined = set()
    if args.baseline:
        try:
            baselined = set(json.loads(Path(args.baseline).read_text())
                            .get("fingerprints", []))
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2

    gating = []
    for f in findings:
        tag = ""
        if f.fingerprint in baselined:
            tag = "  [baselined]"
        else:
            gating.append(f)
        print(f"{f}{tag}")

    n_old = len(findings) - len(gating)
    suffix = f" ({n_old} baselined)" if n_old else ""
    print(f"repro.analysis: {len(gating)} gating finding(s)"
          f"{suffix}")
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
