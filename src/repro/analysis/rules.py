"""Contract declarations + the trace-time rules that check them.

A ``Contract`` is declared *at the seam that owns the invariant* —
``repro.serve.engine`` declares its decode program's collective budget,
``repro.core.qr_orth`` its sharded scan's psum count, ``repro.obs.
quant_health`` the disarmed-path zero-callback guarantee — and is the ONE
source of truth the owning module, pytest, and the CI gate all consume.

A contract bundles a lazily-evaluated program (``trace`` -> ``ClosedJaxpr``,
``lower`` -> a ``jax.stages.Lowered``, ``live`` -> live jitted callables)
with a tuple of checks:

  ``CollectiveCensus``   count/kind of collectives (replaces the
                         ``str(jax.make_jaxpr(...))`` substring match)
  ``HostCallbackCount``  host-callback primitive budget (0 = the disarmed
                         observability guarantee)
  ``PackedDtypeAudit``   packed QTensor payloads never materialize as
                         floats outside the sanctioned dequant sites, and
                         matmuls consuming them accumulate in f32
  ``DonationAliased``    donated buffers are actually aliased in the
                         lowered module
  ``RecompileCount``     jitted-program cache sizes after a geometry sweep
                         match the declared compile budget

``run_contract(contract)`` returns ``Finding``s (empty = contract holds).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.analysis.jaxpr_lint import (CALLBACK_PRIMS, EqnSite,
                                       aliased_donations, callback_census,
                                       collective_census, eqn_site_names,
                                       iter_eqns, packed_payload_indices,
                                       packed_taint)

__all__ = [
    "Finding", "Contract", "run_contract", "run_contracts",
    "CollectiveCensus", "HostCallbackCount", "PackedDtypeAudit",
    "DonationAliased", "RecompileCount", "ALLOWED_DEQUANT_SITES",
]


@dataclass(frozen=True)
class Finding:
    """One violation.  ``where`` is ``path:line`` for AST findings and
    ``<contract-name>/<detail>`` for trace-time findings."""
    rule: str
    where: str
    message: str
    contract: Optional[str] = None

    @property
    def fingerprint(self) -> str:
        """Stable identity for baselines: rule + location sans line number."""
        loc = self.where.rsplit(":", 1)[0] if self.where.rpartition(
            ":")[2].isdigit() else self.where
        return f"{self.rule}|{loc}|{self.message.split(' (')[0]}"

    def __str__(self) -> str:
        c = f" [{self.contract}]" if self.contract else ""
        return f"{self.rule}: {self.where}{c}: {self.message}"


class ContractContext:
    """Lazily traces/lowers the contract's program once and shares it
    across the contract's checks."""

    def __init__(self, contract: "Contract"):
        self.contract = contract
        self._jaxpr = None
        self._lowered = None

    @property
    def jaxpr(self):
        if self._jaxpr is None:
            if self.contract.trace is None:
                raise ValueError(
                    f"contract {self.contract.name!r} has jaxpr checks but "
                    "no trace= callable")
            self._jaxpr = self.contract.trace()
        return self._jaxpr

    @property
    def lowered(self):
        if self._lowered is None:
            if self.contract.lower is None:
                raise ValueError(
                    f"contract {self.contract.name!r} has lowering checks "
                    "but no lower= callable")
            self._lowered = self.contract.lower()
        return self._lowered


@dataclass(frozen=True)
class Contract:
    """A declared program invariant: what to trace and what must hold."""
    name: str
    owner: str                                   # declaring module
    checks: Tuple[Any, ...]
    trace: Optional[Callable[[], Any]] = None    # () -> ClosedJaxpr
    lower: Optional[Callable[[], Any]] = None    # () -> jax.stages.Lowered
    live: Optional[Callable[[], Mapping[str, Any]]] = None  # jitted fns
    description: str = ""


def run_contract(contract: Contract) -> list:
    ctx = ContractContext(contract)
    findings: list = []
    for check in contract.checks:
        findings.extend(check.run(ctx))
    return findings


def run_contracts(contracts: Sequence[Contract]) -> list:
    out: list = []
    for c in contracts:
        out.extend(run_contract(c))
    return out


# --------------------------------------------------------------------------- #
# Rule 1: collective census
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class CollectiveCensus:
    """Structural collective budget: ``expect`` maps primitive name ->
    exact occurrence count; ``forbid`` primitives must not appear at all;
    ``require_in_scan`` additionally demands every expected collective sit
    inside a scanned body (the per-layer placement: a collective hoisted
    out of — or duplicated into — the layer scan changes the count *per
    token* even when the structural total looks right)."""
    expect: Mapping[str, int] = field(default_factory=dict)
    forbid: Tuple[str, ...] = ()
    require_in_scan: bool = False
    rule = "collective-census"

    def run(self, ctx: ContractContext) -> list:
        census = collective_census(ctx.jaxpr)
        name = ctx.contract.name
        out = []
        for prim, want in sorted(self.expect.items()):
            sites = census.get(prim, [])
            if len(sites) != want:
                out.append(Finding(
                    self.rule, f"{name}/{prim}",
                    f"expected {want} {prim} equation(s), found "
                    f"{len(sites)}", contract=name))
            elif self.require_in_scan and want > 0:
                loose = [s for s in sites if not s.in_scan]
                if loose:
                    out.append(Finding(
                        self.rule, f"{name}/{prim}",
                        f"{len(loose)} of {len(sites)} {prim} equation(s) "
                        "sit outside the layer scan body", contract=name))
        for prim in self.forbid:
            sites = census.get(prim, [])
            if sites:
                out.append(Finding(
                    self.rule, f"{name}/{prim}",
                    f"forbidden collective {prim} appears "
                    f"{len(sites)} time(s)", contract=name))
        return out


# --------------------------------------------------------------------------- #
# Rule 2: host-callback budget
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class HostCallbackCount:
    """Exact host-callback primitive budget.  ``expect=0`` is the disarmed
    observability guarantee: a program traced with tracing/quant-health off
    must contain no ``debug_callback``/``io_callback``/``pure_callback`` —
    a smuggled callback syncs the device every step."""
    expect: int = 0
    rule = "host-callback"

    def run(self, ctx: ContractContext) -> list:
        sites = callback_census(ctx.jaxpr)
        name = ctx.contract.name
        if len(sites) == self.expect:
            return []
        prims = sorted({s.prim for s in sites}) or ["none"]
        return [Finding(
            self.rule, f"{name}/callbacks",
            f"expected {self.expect} host-callback equation(s), found "
            f"{len(sites)} ({', '.join(prims)})", contract=name)]


# --------------------------------------------------------------------------- #
# Rule 3: packed-payload dtype promotion
# --------------------------------------------------------------------------- #
# the sanctioned dequant seams: the fused Pallas kernel dispatch, its jnp
# oracle, and the declared non-GEMM dense_weight sites (MoE expert stacks,
# absorbed-MLA wkv_b).  Pallas kernel bodies are opaque by construction.
ALLOWED_DEQUANT_SITES = ("quant_matmul", "qtensor_matmul", "qlinear_matmul",
                         "dense_weight")

# seams whose dot_generals ARE the quantized matmul: anything traced from
# them must accumulate in f32 (f16/bf16 accumulation silently ruins W4A4 at
# scale while staying invisible on toy shapes)
QUANT_MATMUL_SITES = ("quant_matmul", "qtensor_matmul", "qlinear_matmul")

_FLOAT_DTYPES = ("float16", "bfloat16", "float32", "float64")


@dataclass(frozen=True)
class PackedDtypeAudit:
    """Packed/quantized QTensor payloads must stay integer in device
    memory: any ``convert_element_type`` to a float dtype on a value
    carrying code taint (see ``packed_taint`` — taint dies at the float
    boundary, so downstream float math is never flagged) is a violation
    unless traced from one of ``allowed_sites``.  Additionally, every
    ``dot_general`` traced from a quantized-matmul seam
    (``QUANT_MATMUL_SITES``) must produce f32/f64 — the accumulator
    contract.

    ``payload_args`` returns the traced example arguments (the same tuple
    passed to ``jax.make_jaxpr``) so the audit can find which flat invars
    are packed codes."""
    payload_args: Callable[[], Any]
    allowed_sites: Tuple[str, ...] = ALLOWED_DEQUANT_SITES
    matmul_sites: Tuple[str, ...] = QUANT_MATMUL_SITES
    rule = "packed-dtype"

    def run(self, ctx: ContractContext) -> list:
        payloads = packed_payload_indices(self.payload_args())
        name = ctx.contract.name
        if not payloads:
            return [Finding(
                self.rule, f"{name}/payloads",
                "contract declares a packed-dtype audit but the traced "
                "arguments carry no quantized QTensor payloads",
                contract=name)]
        out = []

        def visit(site: EqnSite, tainted: bool):
            if not tainted or site.in_opaque_kernel:
                return
            if site.prim == "convert_element_type":
                new = str(site.eqn.params.get("new_dtype", ""))
                if any(f in new for f in _FLOAT_DTYPES):
                    sites = eqn_site_names(site.eqn)
                    if not sites & set(self.allowed_sites):
                        where = ", ".join(sorted(
                            s for s in sites if not s.startswith("_"))[:5]) \
                            or "<no source>"
                        out.append(Finding(
                            self.rule, f"{name}/dequant",
                            f"packed payload materialized as {new} outside "
                            f"the sanctioned dequant sites (traced from: "
                            f"{where})", contract=name))

        packed_taint(ctx.jaxpr, payloads, visit)

        for site in iter_eqns(ctx.jaxpr):
            if site.prim != "dot_general" or site.in_opaque_kernel:
                continue
            if not eqn_site_names(site.eqn) & set(self.matmul_sites):
                continue
            try:
                out_dt = str(site.eqn.outvars[0].aval.dtype)
            except Exception:
                continue
            if out_dt not in ("float32", "float64"):
                out.append(Finding(
                    self.rule, f"{name}/accum",
                    f"quantized matmul accumulates in {out_dt}; the "
                    "quant-matmul seams must accumulate in f32",
                    contract=name))
        return out


# --------------------------------------------------------------------------- #
# Rule 4: donation audit
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DonationAliased:
    """Donated buffers must actually alias: ``jax.jit(..., donate_argnums)``
    only *offers* the buffers — a shape/dtype mismatch with every output
    silently drops the donation and the step copies the whole pool every
    token.  The lowered module records accepted donations as
    ``tf.aliasing_output`` argument attributes; this check requires at
    least ``min_aliased`` of them."""
    min_aliased: int
    rule = "donation"

    def run(self, ctx: ContractContext) -> list:
        n = aliased_donations(ctx.lowered)
        name = ctx.contract.name
        if n >= self.min_aliased:
            return []
        return [Finding(
            self.rule, f"{name}/aliasing",
            f"expected >= {self.min_aliased} donated inputs aliased to "
            f"outputs in the lowered module, found {n} (donation dropped: "
            "the program copies instead of reusing the buffers)",
            contract=name)]


# --------------------------------------------------------------------------- #
# Rule 5: recompilation sentinel
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class RecompileCount:
    """Program-cache budget after a geometry sweep.

    ``expect`` maps program name -> exact jit-cache entry count (or a
    ``(min, max)`` range).  The contract's ``live`` callable returns the
    live jitted callables (measured via their ``_cache_size``) or plain
    integers — the engine exposes ``program_cache_sizes()``.  A count above
    budget means the cache key leaked a traced-value dependency (every
    decode step recompiles); below budget means the sweep never exercised
    the declared geometry."""
    expect: Mapping[str, Any]
    rule = "recompile"

    def run(self, ctx: ContractContext) -> list:
        if ctx.contract.live is None:
            raise ValueError(
                f"contract {ctx.contract.name!r} declares RecompileCount "
                "but no live= callable")
        live = ctx.contract.live()
        name = ctx.contract.name
        out = []
        for prog, want in sorted(self.expect.items()):
            fn = live.get(prog)
            if fn is None:
                out.append(Finding(
                    self.rule, f"{name}/{prog}",
                    f"program {prog!r} not found in the live program map",
                    contract=name))
                continue
            got = fn if isinstance(fn, int) else fn._cache_size()
            lo, hi = want if isinstance(want, tuple) else (want, want)
            if not (lo <= got <= hi):
                bound = f"{lo}" if lo == hi else f"[{lo}, {hi}]"
                out.append(Finding(
                    self.rule, f"{name}/{prog}",
                    f"program {prog!r} compiled {got} time(s); budget "
                    f"{bound} for this geometry sweep", contract=name))
        return out
