"""Suppression file handling for the AST lint.

``analysis/suppressions.toml`` is the checked-in allowlist of *intentional*
rule violations.  Every entry must carry a non-empty ``justification`` —
a suppression without a reason is itself a config error (exit 2), which is
the mechanism that keeps the file honest: you cannot silence a finding
without writing down why.

Entry schema (array-of-tables)::

    [[suppress]]
    rule = "time-time"                 # required: rule id
    path = "src/repro/obs/trace.py"    # required: repo-relative file
    match = "t_wall"                   # optional: substring of source line
    justification = "..."              # required, non-empty

``match`` narrows the suppression to findings whose *source line* contains
the substring; without it the (rule, path) pair suppresses the whole file,
which the loader accepts but the README discourages.

TOML parsing prefers :mod:`tomllib` (3.11+) then :mod:`tomli`; a minimal
internal parser handles the restricted subset above so the checker runs on
the 3.10 CI image without new dependencies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from repro.analysis.rules import Finding

__all__ = ["Suppression", "load_suppressions", "filter_findings",
           "SuppressionError"]


class SuppressionError(ValueError):
    """Malformed suppression file — a config error, not a finding."""


@dataclass
class Suppression:
    rule: str
    path: str
    justification: str
    match: str = ""
    used: bool = field(default=False, compare=False)

    def covers(self, finding: Finding, src_line: str) -> bool:
        if finding.rule != self.rule:
            return False
        floc = finding.where.rsplit(":", 1)[0]
        if floc != self.path:
            return False
        return (self.match in src_line) if self.match else True


def _parse_minimal_toml(text: str) -> List[Dict[str, str]]:
    """Fallback parser for the restricted array-of-tables subset."""
    entries: List[Dict[str, str]] = []
    cur: Dict[str, str] = None  # type: ignore[assignment]
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[suppress]]":
            cur = {}
            entries.append(cur)
            continue
        if "=" in line and cur is not None:
            key, _, val = line.partition("=")
            key, val = key.strip(), val.strip()
            if len(val) >= 2 and val[0] == val[-1] and val[0] in "\"'":
                cur[key] = val[1:-1]
                continue
        raise SuppressionError(
            f"suppressions.toml:{lineno}: cannot parse {raw!r} "
            "(restricted subset: [[suppress]] tables with string keys)")
    return entries


def _load_toml(path: Path) -> List[Dict[str, str]]:
    text = path.read_text()
    try:
        import tomllib as toml_mod          # 3.11+
    except ModuleNotFoundError:
        try:
            import tomli as toml_mod        # common on 3.10 images
        except ModuleNotFoundError:
            return _parse_minimal_toml(text)
    data = toml_mod.loads(text)
    return list(data.get("suppress", []))


def load_suppressions(path: Path) -> List[Suppression]:
    """Parse and validate; raises :class:`SuppressionError` on a missing
    field or an empty justification."""
    if not path.exists():
        return []
    out = []
    for i, entry in enumerate(_load_toml(path)):
        missing = [k for k in ("rule", "path", "justification")
                   if not str(entry.get(k, "")).strip()]
        if missing:
            raise SuppressionError(
                f"suppression entry #{i + 1} missing required "
                f"field(s): {', '.join(missing)} — every suppression must "
                "say which rule, which file, and WHY")
        out.append(Suppression(
            rule=str(entry["rule"]), path=str(entry["path"]),
            justification=str(entry["justification"]),
            match=str(entry.get("match", ""))))
    return out


def _source_line(root: Path, finding: Finding) -> str:
    loc, _, line = finding.where.rpartition(":")
    if not line.isdigit():
        return ""
    try:
        lines = (root / loc).read_text().splitlines()
        return lines[int(line) - 1]
    except (OSError, IndexError):
        return ""


def filter_findings(findings: Sequence[Finding],
                    suppressions: Sequence[Suppression],
                    root: Path) -> Tuple[List[Finding], List[Suppression]]:
    """Drop suppressed findings.  Returns ``(kept, unused_suppressions)``
    — unused entries are reported (a stale suppression hides nothing but
    rots the file)."""
    kept: List[Finding] = []
    for f in findings:
        src = _source_line(root, f)
        hit = None
        for s in suppressions:
            if s.covers(f, src):
                hit = s
                break
        if hit is None:
            kept.append(f)
        else:
            hit.used = True
    unused = [s for s in suppressions if not s.used]
    return kept, unused
