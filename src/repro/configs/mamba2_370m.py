"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    pos_embed="none",
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    attn_shard="head",   # SSM heads (32) TP-sharded
    max_seq_len=1 << 20,
)
