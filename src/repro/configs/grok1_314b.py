"""grok-1-314b [moe] — 8 experts top-2 GQA [hf:xai-org/grok-1]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    attn_type="gqa",
    attn_softcap=30.0,
    logit_softcap=30.0,
    n_experts=8,
    moe_top_k=2,
    moe_impl="einsum",             # 8 experts: capacity/einsum dispatch under GSPMD
    attn_shard="head",             # 48 % 16 == 0
    max_seq_len=8192,
    skip_shapes=("long_500k",),
    param_dtype="bfloat16",        # 314B fully-FSDP
    opt_state_dtype="bfloat16",
)
