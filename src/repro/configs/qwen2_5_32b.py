"""qwen2.5-32b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-32B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    attn_type="gqa",
    qkv_bias=True,
    rope_theta=1e6,
    attn_shard="seq",    # 40 heads % 16 != 0 -> sequence-parallel attention
    max_seq_len=131072,
    skip_shapes=("long_500k",),   # full attention: quadratic at 500k,
    param_dtype="bfloat16",       # bf16 params + fp32 opt state (FSDP)
)
