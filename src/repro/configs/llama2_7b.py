"""llama2-7b — the paper's own primary evaluation model (DartQuant Tab. 2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    attn_type="gqa",
    attn_shard="head",
    max_seq_len=4096,
    skip_shapes=("long_500k",),
)
