"""mistral-nemo-12b [dense] — 128k ctx GQA [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    attn_type="gqa",
    rope_theta=1e6,
    attn_shard="head",   # 32 % 16 == 0
    max_seq_len=131072,
    skip_shapes=("long_500k",),
    param_dtype="bfloat16",       # bf16 params + fp32 opt state (FSDP)
)
