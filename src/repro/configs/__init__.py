"""Architecture registry: ``get_config("<arch-id>")`` -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, QuantConfig, ShapeCell, SHAPES  # noqa: F401

_REGISTRY = {
    "mamba2-370m": "mamba2_370m",
    "qwen2.5-32b": "qwen2_5_32b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "yi-34b": "yi_34b",
    "gemma2-2b": "gemma2_2b",
    "grok-1-314b": "grok1_314b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "chameleon-34b": "chameleon_34b",
    "zamba2-7b": "zamba2_7b",
    "whisper-medium": "whisper_medium",
    "llama2-7b": "llama2_7b",
}

ARCH_IDS = tuple(k for k in _REGISTRY if k != "llama2-7b")
ALL_ARCH_IDS = tuple(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    return mod.CONFIG


def cells(arch_id: str):
    """Valid (arch, shape) cells for an arch (honouring skip_shapes)."""
    cfg = get_config(arch_id)
    return [s for name, s in SHAPES.items() if name not in cfg.skip_shapes]
