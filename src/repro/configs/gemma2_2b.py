"""gemma2-2b [dense] — local+global alternating, logit softcap [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_type="gqa",
    attn_softcap=50.0,
    logit_softcap=30.0,
    local_window=4096,
    layer_pattern="LG",            # alternate local / global
    tie_embeddings=True,
    sandwich_norm=True,
    embed_scale=True,
    attn_shard="seq",              # 8 heads % 16 != 0
    max_seq_len=8192,
    # half the layers are *global* full attention -> quadratic at 500k; skipped
    skip_shapes=("long_500k",),
)
