"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attn_type="gqa",
    rope_theta=5e6,
    attn_shard="seq",    # 56 heads % 16 != 0
    max_seq_len=32768,
    skip_shapes=("long_500k",),
    param_dtype="bfloat16",       # bf16 params + fp32 opt state (FSDP)
)
