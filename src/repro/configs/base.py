"""Model/architecture configuration system.

One ``ModelConfig`` describes everything the model factory needs: block kinds
(attention/SSM/MoE/enc-dec), shapes, quantization + rotation (DartQuant) options,
and sharding hints.  Each assigned architecture gets a module in this package
exporting ``CONFIG``; ``repro.configs.get_config(arch_id)`` resolves them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class QuantConfig:
    """Quantization settings (paper: W4A4KV4 / W4A8 / W4A4KV16)."""
    w_bits: int = 4
    a_bits: int = 4
    kv_bits: int = 16
    w_group_size: int = -1          # -1 = per output channel
    w_sym: bool = True              # per-channel symmetric weights
    a_sym: bool = False             # per-token asymmetric activations
    w_clip_ratio: float = 1.0
    use_gptq: bool = True
    # rotation sites (DartQuant)
    use_r1: bool = True             # residual-stream rotation (fused)
    use_r2: bool = True             # per-layer V->O head rotation (fused)
    use_r3: bool = True             # online Hadamard on Q/K (KV-cache quant)
    use_r4: bool = True             # online Hadamard before down-proj

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    arch_id: str = "unnamed"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    # transformer core ------------------------------------------------------
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 128
    vocab_size: int = 256
    max_seq_len: int = 8192
    # attention -------------------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    qkv_bias: bool = False
    o_bias: bool = False
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0       # gemma2/grok attention logit softcap
    logit_softcap: float = 0.0      # final-logit softcap (gemma2)
    local_window: int = 0           # sliding-window size for local layers
    # per-layer pattern for local/global alternation; "L"/"G" string cycled
    layer_pattern: str = ""
    tie_embeddings: bool = False
    sandwich_norm: bool = False     # gemma2: post-norms after attn/mlp
    embed_scale: bool = False       # gemma2: scale embeddings by sqrt(d)
    # MLA (deepseek-v3) ------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE --------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden (deepseek style)
    n_dense_layers: int = 0         # leading dense layers before MoE layers
    moe_impl: str = "einsum"        # einsum (capacity) | ragged (sort+ragged_dot EP)
    capacity_factor: float = 1.25
    router_scale: bool = False      # deepseek-v3 sigmoid routing + normalization
    mtp_depth: int = 0              # deepseek-v3 multi-token-prediction modules
    # SSM (mamba2) -----------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one shared attention+MLP block applied every N layers
    shared_attn_every: int = 0
    # encoder-decoder (whisper) -----------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper: 30s audio -> 1500 frames (stub input)
    # mlp / norm flavour -------------------------------------------------------
    mlp_type: str = "swiglu"        # swiglu | gelu (whisper plain MLP w/ bias)
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    pos_embed: str = "rope"         # rope | learned | none
    norm_eps: float = 1e-5
    # dtypes -------------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    # training ----------------------------------------------------------------
    remat: bool = True
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2
    # sharding hints ------------------------------------------------------------
    # attention TP mode: "head" (heads divisible by TP) | "seq" (sequence parallel)
    attn_shard: str = "head"
    # MoE expert-parallel axes: "model" (EP=16) | "all" (EP over data x model,
    # experts fully local per device — DeepSeek-style large EP)
    ep_axes: str = "model"
    # TP-shard attention weights even when activations are sequence-parallel
    # (kills the full-weight FSDP gather; GSPMD inserts small act reshards)
    attn_weight_tp: bool = False
    # Megatron-style sequence-parallel residual stream: activations between
    # blocks shard over ('model', seq) — divides activation-save memory by TP,
    # enabling accum=1 (one param gather per step instead of per microbatch)
    seq_parallel_residual: bool = False
    # quantization ---------------------------------------------------------------
    quant: QuantConfig = field(default_factory=QuantConfig)
    # which shapes are valid ("skip long_500k for full-attention archs")
    skip_shapes: Tuple[str, ...] = ()

    # ------------------------------------------------------------------ helpers
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 512 so embeddings/logits shard
        over TP=16 (MaxText-style vocab padding). Data uses vocab_size."""
        return -(-self.vocab_size // 512) * 512

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.attn_type == "mla":
            return self.qk_nope_head_dim + self.qk_rope_head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def ffn_hidden(self) -> int:
        return self.moe_d_ff if (self.n_experts and self.moe_d_ff) else self.d_ff

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        hd = self.resolved_head_dim

        def attn_params() -> int:
            if self.attn_type == "mla":
                p = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * hd
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            if self.attn_type == "none":
                return 0
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def dense_mlp(dff: int) -> int:
            mult = 3 if self.mlp_type == "swiglu" else 2
            return mult * d * dff

        def ssm_params() -> int:
            di, cd, nh = self.d_inner, self.conv_dim, self.ssm_nheads
            return d * (2 * di + 2 * self.ssm_groups * self.ssm_state + nh) + \
                self.ssm_conv * cd + di * d + di + 3 * nh

        if self.family == "ssm":
            total += self.n_layers * ssm_params()
        elif self.family == "hybrid":
            total += self.n_layers * (ssm_params() + d)
            n_shared = 1
            total += n_shared * (attn_params() + dense_mlp(self.d_ff) + 2 * d)
        elif self.is_encoder_decoder:
            total += self.n_encoder_layers * (attn_params() + dense_mlp(self.d_ff))
            total += self.n_layers * (2 * attn_params() + dense_mlp(self.d_ff))
        else:
            n_moe = (self.n_layers - self.n_dense_layers) if self.n_experts else 0
            n_dense = self.n_layers - n_moe
            total += self.n_layers * attn_params()
            total += n_dense * dense_mlp(self.d_ff)
            if n_moe:
                per_expert = dense_mlp(self.ffn_hidden)
                total += n_moe * (self.n_experts * per_expert
                                  + self.n_shared_experts * per_expert
                                  + self.n_experts * d)  # router
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        mult = 3 if self.mlp_type == "swiglu" else 2
        per_expert = mult * d * self.ffn_hidden
        n_moe = self.n_layers - self.n_dense_layers
        inactive = n_moe * (self.n_experts - self.moe_top_k) * per_expert
        return int(self.n_params() - inactive)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            max_seq_len=256,
            remat=False,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.attn_type == "mla":
            kw.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16, head_dim=0)
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 8),
                      moe_top_k=min(self.moe_top_k, 2),
                      moe_d_ff=64 if self.moe_d_ff else 0,
                      n_dense_layers=min(self.n_dense_layers, 1),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      mtp_depth=min(self.mtp_depth, 1),
                      # no token dropping in smoke tests (keeps prefill==forward)
                      capacity_factor=float(min(self.n_experts, 8)))
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
            if self.shared_attn_every:
                kw.update(shared_attn_every=2, n_layers=4)
        if self.is_encoder_decoder:
            kw.update(n_encoder_layers=2, encoder_seq=32)
        if self.layer_pattern:
            kw.update(local_window=32)
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shape cells (assigned): every arch pairs with these four shapes.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
