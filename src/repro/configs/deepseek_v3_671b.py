"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,                # spec line (GQA kv=128); MLA uses latent cache
    d_ff=18432,                    # dense-layer FFN (first n_dense_layers)
    moe_d_ff=2048,                 # per-expert hidden (spec d_ff=2048)
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    n_dense_layers=3,
    moe_impl="ragged",             # 256 experts: sort + ragged_dot shard_map EP
    router_scale=True,             # sigmoid routing w/ weight normalization
    mtp_depth=1,
    attn_shard="head",             # 128 % 16 == 0
    max_seq_len=131072,
    skip_shapes=("long_500k",),
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",    # 671B: fully-sharded bf16 opt state to fit HBM
)
