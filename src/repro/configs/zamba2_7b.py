"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block [arXiv:2411.15242].

81 Mamba2 layers; a single *shared* attention+MLP block is applied every
``shared_attn_every`` layers (weights reused each application).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,                  # 3584 / 32
    d_ff=14336,
    vocab_size=32000,
    attn_type="gqa",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,           # 81 layers -> 13 shared-attention applications
    attn_shard="head",             # 32 % 16 == 0
    max_seq_len=1 << 20,
)
