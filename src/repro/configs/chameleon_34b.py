"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

Backbone only: image modality enters as VQ codes in the (shared) vocab; the
VQ-GAN tokenizer frontend is a stub — ``input_specs`` supplies token ids that
may be text or image codes, embedded by the same table (early fusion).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    attn_type="gqa",
    attn_shard="head",             # 64 % 16 == 0
    max_seq_len=8192,
    skip_shapes=("long_500k",),
    param_dtype="bfloat16",       # bf16 params + fp32 opt state (FSDP)
)
