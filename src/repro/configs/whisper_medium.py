"""whisper-medium [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

Backbone only: the mel+conv frontend is a stub; ``input_specs`` provides
precomputed frame embeddings ``[B, encoder_seq, d_model]``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,                   # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    attn_type="gqa",
    is_encoder_decoder=True,
    encoder_seq=1500,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_embed="learned",
    qkv_bias=True,
    o_bias=True,
    tie_embeddings=True,
    attn_shard="head",             # 16 % 16 == 0
    max_seq_len=32768,
    skip_shapes=("long_500k",),
)
