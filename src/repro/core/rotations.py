"""Computational invariance: rotation construction + fusion into weights.

Sites (paper App. A):
  R1  residual-stream rotation, fused into every weight touching the stream
      (consumers: right-multiply by R1; producers: left-multiply by R1^T;
      embedding/lm_head/pos-embeds rotated; norm scales absorbed first).
  R2  per-layer head-dim rotation between V and O, fused into wv / wo.
  R3  online Hadamard on Q/K after RoPE (cancels in qk^T; smooths KV cache).
  R4  online Hadamard before down-proj; its inverse is fused into w_down.

LayerNorm models (whisper) are first converted to RMS-equivalent form by
folding the centering matrix M = I - 11^T/d into all producers (SliceGPT):
after that the stream is zero-mean and rotation commutes exactly.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------- #
# Hadamard construction (randomized; Sylvester x Paley factors)
# --------------------------------------------------------------------------- #
def _gf_elements(q: int):
    """Elements + ops of GF(q) for q = p^k (k<=3 needed: q in {11, 19, 27})."""
    for p in (3, 7, 11, 19, 23, 31):
        k = 0
        n = q
        while n % p == 0:
            n //= p
            k += 1
        if n == 1:
            break
    else:
        raise ValueError(q)
    if k == 1:
        elems = list(range(q))
        sub = lambda a, b: (a - b) % q
        mul = lambda a, b: (a * b) % q
        return elems, sub, mul
    # GF(27) = GF(3)[x] / (x^3 + 2x + 1)  (irreducible over GF(3))
    assert q == 27, "only GF(27) needed beyond primes"
    elems = [(a, b, c) for a in range(3) for b in range(3) for c in range(3)]

    def sub(a, b):
        return tuple((x - y) % 3 for x, y in zip(a, b))

    def mul(a, b):
        # polynomial product then reduce by x^3 = x + 2  (= -2x - 1 mod 3)
        coef = [0] * 5
        for i, x in enumerate(a):
            for j, y in enumerate(b):
                coef[i + j] = (coef[i + j] + x * y) % 3
        for d in (4, 3):
            c = coef[d]
            if c:
                coef[d] = 0
                coef[d - 3] = (coef[d - 3] + 2 * c) % 3   # +2c from x^3 -> 2
                coef[d - 2] = (coef[d - 2] + c) % 3       # +c  from x^3 -> x
        return tuple(coef[:3])

    return elems, sub, mul


def _paley(q: int) -> np.ndarray:
    """Paley-I Hadamard of order q+1 (q = p^k ≡ 3 mod 4). Orders 12, 20, 28."""
    elems, sub, mul = _gf_elements(q)
    zero = elems[0] if not isinstance(elems[0], tuple) else (0, 0, 0)
    squares = {mul(e, e) for e in elems if e != zero}
    Q = np.zeros((q, q))
    for i, ei in enumerate(elems):
        for j, ej in enumerate(elems):
            if i != j:
                Q[i, j] = 1.0 if sub(ei, ej) in squares else -1.0
    # Paley I: H = I + S, S = [[0, 1^T], [-1, Q]] skew => H H^T = (q+1) I
    H = np.ones((q + 1, q + 1))
    H[1:, 1:] = Q + np.eye(q)
    H[1:, 0] = -1.0
    return H


_SMALL = {12: _paley(11), 20: _paley(19), 28: _paley(27)}


def hadamard_matrix(n: int) -> np.ndarray:
    """Unnormalized +-1 Hadamard of order n (Sylvester doubling x Paley)."""
    if n == 1:
        return np.ones((1, 1))
    if n in _SMALL:
        return _SMALL[n]
    if n % 2 == 0 and _is_constructible(n // 2):
        h = hadamard_matrix(n // 2)
        return np.block([[h, h], [h, -h]])
    for m, Hm in _SMALL.items():
        if n % m == 0 and _is_constructible(n // m):
            return np.kron(Hm, hadamard_matrix(n // m))
    raise ValueError(f"no Hadamard construction for n={n}")


def _is_constructible(n: int) -> bool:
    if n == 1 or n in _SMALL:
        return True
    if n % 2 == 0 and _is_constructible(n // 2):
        return True
    for m in _SMALL:
        if n % m == 0 and _is_constructible(n // m):
            return True
    return False


def hadamard_chain(n: int) -> list:
    """Ordered Kronecker factor chain mirroring hadamard_matrix's recursion:
    hadamard_matrix(n) == kron(chain[0], kron(chain[1], ...))."""
    if n == 1:
        return []
    if n in _SMALL:
        return [n]
    if n % 2 == 0 and _is_constructible(n // 2):
        return [2] + hadamard_chain(n // 2)
    for m in _SMALL:
        if n % m == 0 and _is_constructible(n // m):
            return [m] + hadamard_chain(n // m)
    raise ValueError(f"no Hadamard construction for n={n}")


def _kernel_wht() -> bool:
    """True when the Pallas WHT kernel is the fast path (real accelerator).

    In interpret mode (CPU CI) the kernel is strictly slower than the jnp
    matmul reference, so dispatch stays off there by default.
    """
    from repro.kernels.common import use_interpret   # lazy: no cycle at import
    return not use_interpret()


def random_hadamard(n: int, key, use_kernel: Optional[bool] = None) -> jax.Array:
    """Randomized orthogonal Hadamard: H diag(s) / sqrt(n), s ~ Rademacher.

    On accelerator backends the matrix is built by pushing the identity
    through the two-factor Pallas WHT kernel — the host never materializes
    the n x n Sylvester/Paley product, which dominates calibration init time
    for d_model-sized sites.  ``use_kernel`` pins either path (parity tests);
    falls back to a random orthogonal matrix when no construction exists.
    """
    if _is_constructible(n):
        s = jax.random.rademacher(key, (n,), jnp.float32)
        if use_kernel if use_kernel is not None else _kernel_wht():
            from repro.kernels.hadamard import ops as _ops   # lazy: ops imports us
            return _ops.online_hadamard(jnp.eye(n, dtype=jnp.float32)) * s[None, :]
        h = jnp.asarray(hadamard_matrix(n), jnp.float32) / np.sqrt(n)
        return h * s[None, :]
    z = jax.random.normal(key, (n, n), jnp.float32)
    q, r = jnp.linalg.qr(z)
    return q * jnp.sign(jnp.diagonal(r))[None, :]


def online_hadamard(x: jax.Array, use_kernel: Optional[bool] = None) -> jax.Array:
    """Apply the (deterministic, unrandomized) WHT to the last dim: x @ H/sqrt(n).

    The R3/R4 online op of the calibration engine.  Dispatches to the Pallas
    two-factor kernel (repro.kernels.hadamard) on real accelerator backends;
    keeps the jnp matmul reference under interpret mode (CPU CI), where the
    kernel is slower.  ``use_kernel`` pins either path for parity tests.
    Requires a constructible last dim.
    """
    if use_kernel if use_kernel is not None else _kernel_wht():
        from repro.kernels.hadamard import ops as _ops       # lazy: ops imports us
        return _ops.online_hadamard(x)
    n = x.shape[-1]
    h = jnp.asarray(hadamard_matrix(n), x.dtype) / np.sqrt(n).astype(np.float32)
    return x @ h


# --------------------------------------------------------------------------- #
# Einsum helpers (leading dims broadcast over layer stacks)
# --------------------------------------------------------------------------- #
def _rot_in(w, R):       # consumer weight [..., out, in]: w @ R on the in dim
    return jnp.einsum("...oi,ij->...oj", w, R.astype(w.dtype))


def _rot_out(w, R):      # producer weight [..., out, in]: R^T @ w on the out dim
    return jnp.einsum("...oi,oj->...ji", w, R.astype(w.dtype))


def _rot_vec(v, R):      # row vector on the stream: v @ R
    return jnp.einsum("...o,oj->...j", v, R.astype(v.dtype))


# --------------------------------------------------------------------------- #
# Norm absorption
# --------------------------------------------------------------------------- #
def _absorb_scale_into(ws: list, norm: dict):
    """Fold rms scale gamma into consumer weights; returns new weights + unit norm."""
    gamma = norm["scale"]
    new = [w * gamma[..., None, :].astype(w.dtype) for w in ws]
    out_norm = dict(norm)
    out_norm["scale"] = jnp.ones_like(gamma)
    return new, out_norm


def _centering(d: int) -> jax.Array:
    return jnp.eye(d, dtype=jnp.float32) - jnp.full((d, d), 1.0 / d, jnp.float32)


# --------------------------------------------------------------------------- #
# Block-level fusion (dense transformer block, stacked over leading dims)
# --------------------------------------------------------------------------- #
def _fuse_dense_block(cfg: ModelConfig, blk: dict, R1, R2s=None,
                      R1_kv: Optional[jax.Array] = None,
                      enc_gamma: Optional[jax.Array] = None) -> dict:
    """R1 on stream; optional R2 [.., hd, hd]; cross-attn consumes R1_kv space."""
    blk = dict(blk)
    attn = dict(blk["attn"])
    mla = cfg.attn_type == "mla"

    consumers = ["wq_a", "wkv_a"] if mla else ["wq", "wk", "wv"]
    # absorb ln1 into attention consumers
    ws, blk["ln1"] = _absorb_scale_into([attn[c] for c in consumers], blk["ln1"])
    for c, w in zip(consumers, ws):
        attn[c] = w
    if R1 is not None:
        for c in consumers:
            attn[c] = _rot_in(attn[c], R1)
        attn["wo"] = _rot_out(attn["wo"], R1)
        if "bo" in attn:
            attn["bo"] = _rot_vec(attn["bo"], R1)
    if R2s is not None:
        hd = cfg.resolved_head_dim
        if mla:
            vd, nope = cfg.v_head_dim, cfg.qk_nope_head_dim
            wkv_b = attn["wkv_b"]
            lead = wkv_b.shape[:-2]
            wkv_b = wkv_b.reshape(lead + (cfg.n_heads, nope + vd, cfg.kv_lora_rank))
            wv = jnp.einsum("...hok,...oj->...hjk", wkv_b[..., nope:, :], R2s)
            wkv_b = wkv_b.at[..., nope:, :].set(wv)
            attn["wkv_b"] = wkv_b.reshape(lead + ((nope + vd) * cfg.n_heads,
                                                  cfg.kv_lora_rank))
            wo = attn["wo"]
            wo = wo.reshape(wo.shape[:-1] + (cfg.n_heads, vd))
            attn["wo"] = jnp.einsum("...dho,...oj->...dhj", wo,
                                    R2s).reshape(attn["wo"].shape)
        else:
            wv = attn["wv"]
            lead = wv.shape[:-2]
            wv = wv.reshape(lead + (cfg.n_kv_heads, hd, cfg.d_model))
            attn["wv"] = jnp.einsum("...hod,...oj->...hjd", wv,
                                    R2s).reshape(attn["wv"].shape)
            if "bv" in attn:
                bv = attn["bv"].reshape(lead + (cfg.n_kv_heads, hd))
                attn["bv"] = jnp.einsum("...ho,...oj->...hj", bv,
                                        R2s).reshape(attn["bv"].shape)
            wo = attn["wo"]
            wo = wo.reshape(wo.shape[:-1] + (cfg.n_heads, hd))
            attn["wo"] = jnp.einsum("...dho,...oj->...dhj", wo,
                                    R2s).reshape(attn["wo"].shape)
    blk["attn"] = attn

    # cross attention (whisper): q/o live in decoder space, k/v in encoder space
    if "xattn" in blk:
        x = dict(blk["xattn"])
        ws, blk["ln_x"] = _absorb_scale_into([x["wq"]], blk["ln_x"])
        x["wq"] = ws[0]
        if enc_gamma is not None:   # absorb encoder final norm into k/v consumers
            x["wk"] = x["wk"] * enc_gamma[None, None, :].astype(x["wk"].dtype)
            x["wv"] = x["wv"] * enc_gamma[None, None, :].astype(x["wv"].dtype)
        if R1 is not None:
            x["wq"] = _rot_in(x["wq"], R1)
            x["wo"] = _rot_out(x["wo"], R1)
            if "bo" in x:
                x["bo"] = _rot_vec(x["bo"], R1)
        if R1_kv is not None:
            x["wk"] = _rot_in(x["wk"], R1_kv)
            x["wv"] = _rot_in(x["wv"], R1_kv)
        blk["xattn"] = x

    # FFN
    if "mlp" in blk:
        blk["mlp"] = _fuse_mlp(blk, "mlp", R1)
    if "moe" in blk:
        moe = dict(blk["moe"])
        gamma = blk["ln2"]["scale"]
        moe["router"] = moe["router"] * gamma[..., None, :].astype(jnp.float32)
        for wname in ("w_gate", "w_up"):
            moe[wname] = moe[wname] * gamma[..., None, None, :].astype(moe[wname].dtype)
        if "shared" in moe:
            sh = dict(moe["shared"])
            for wname in ("w_gate", "w_up"):
                sh[wname] = sh[wname] * gamma[..., None, :].astype(sh[wname].dtype)
            moe["shared"] = sh
        norm2 = dict(blk["ln2"]); norm2["scale"] = jnp.ones_like(gamma)
        blk["ln2"] = norm2
        if R1 is not None:
            moe["router"] = _rot_in(moe["router"], R1)
            moe["w_gate"] = _rot_in(moe["w_gate"], R1)
            moe["w_up"] = _rot_in(moe["w_up"], R1)
            moe["w_down"] = _rot_out(moe["w_down"], R1)
            if "shared" in moe:
                sh = dict(moe["shared"])
                sh["w_gate"] = _rot_in(sh["w_gate"], R1)
                sh["w_up"] = _rot_in(sh["w_up"], R1)
                sh["w_down"] = _rot_out(sh["w_down"], R1)
                moe["shared"] = sh
        blk["moe"] = moe
    return blk


def _fuse_mlp(blk: dict, key: str, R1) -> dict:
    mlp = dict(blk[key])
    gated = "w_gate" in mlp
    consumers = ["w_gate", "w_up"] if gated else ["fc1"]
    producer = "w_down" if gated else "fc2"
    ws, blk["ln2"] = _absorb_scale_into([mlp[c] for c in consumers], blk["ln2"])
    for c, w in zip(consumers, ws):
        mlp[c] = w
    if R1 is not None:
        for c in consumers:
            mlp[c] = _rot_in(mlp[c], R1)
        mlp[producer] = _rot_out(mlp[producer], R1)
        bkey = "b2"
        if bkey in mlp:
            mlp[bkey] = _rot_vec(mlp[bkey], R1)
    return mlp


def _fuse_mamba_block(cfg: ModelConfig, blk: dict, R1) -> dict:
    blk = dict(blk)
    mixer = dict(blk["mixer"])
    ws, blk["ln"] = _absorb_scale_into([mixer["in_proj"]], blk["ln"])
    mixer["in_proj"] = ws[0]
    if R1 is not None:
        mixer["in_proj"] = _rot_in(mixer["in_proj"], R1)
        mixer["out_proj"] = _rot_out(mixer["out_proj"], R1)
    blk["mixer"] = mixer
    return blk


# --------------------------------------------------------------------------- #
# LayerNorm -> RMS conversion (SliceGPT; whisper)
# --------------------------------------------------------------------------- #
def _fold_ln_bias(blk_norm: dict, consumers: list, biases: list):
    """beta folded into consumer biases: b' = b + beta @ W.T."""
    beta = blk_norm.get("bias")
    if beta is None:
        return consumers, biases, blk_norm
    new_b = []
    for w, b in zip(consumers, biases):
        shift = jnp.einsum("...oi,...i->...o", w, beta.astype(w.dtype))
        new_b.append((b if b is not None else 0.0) + shift)
    norm = dict(blk_norm)
    norm["bias"] = jnp.zeros_like(beta)
    return consumers, new_b, norm


def convert_ln_to_rms(cfg: ModelConfig, params: dict) -> dict:
    """Fold centering M = I - 11^T/d into every producer so LN == RMSNorm.

    Also folds LN biases into consumer biases.  Whisper-only layout.
    """
    d = cfg.d_model
    M = _centering(d)
    p = jax.tree.map(lambda x: x, params)  # shallow-ish copy

    def center_producers(blk, cross: bool):
        blk = dict(blk)
        for name in ("attn",) + (("xattn",) if cross else ()):
            a = dict(blk[name])
            a["wo"] = _rot_out(a["wo"], M)
            if "bo" in a:
                a["bo"] = _rot_vec(a["bo"], M)
            blk[name] = a
        mlp = dict(blk["mlp"])
        mlp["fc2"] = _rot_out(mlp["fc2"], M)
        mlp["b2"] = _rot_vec(mlp["b2"], M)
        blk["mlp"] = mlp
        return blk

    def fold_biases(blk, cross: bool):
        blk = dict(blk)
        a = dict(blk["attn"])
        (_, (a["bq"], a["bk"], a["bv"]), blk["ln1"]) = _fold_ln_bias(
            blk["ln1"], [a["wq"], a["wk"], a["wv"]],
            [a.get("bq"), a.get("bk"), a.get("bv")])
        blk["attn"] = a
        if cross:
            xa = dict(blk["xattn"])
            (_, (xa["bq"],), blk["ln_x"]) = _fold_ln_bias(
                blk["ln_x"], [xa["wq"]], [xa.get("bq")])
            blk["xattn"] = xa
        mlp = dict(blk["mlp"])
        (_, (mlp["b1"],), blk["ln2"]) = _fold_ln_bias(
            blk["ln2"], [mlp["fc1"]], [mlp.get("b1")])
        blk["mlp"] = mlp
        return blk

    p["embed"] = _rot_vec(p["embed"], M)
    p["pos_dec"] = _rot_vec(p["pos_dec"], M)
    p["pos_enc"] = _rot_vec(p["pos_enc"], M)
    p["enc_layers"] = fold_biases(center_producers(p["enc_layers"], False), False)
    p["dec_layers"] = fold_biases(center_producers(p["dec_layers"], True), True)
    # encoder final norm bias -> folded into cross wk/wv consumers of every layer
    beta = p["enc_norm"].get("bias")
    if beta is not None:
        dec = dict(p["dec_layers"])
        xa = dict(dec["xattn"])
        for wn, bn in (("wk", "bk"), ("wv", "bv")):
            shift = jnp.einsum("loi,i->lo", xa[wn], beta.astype(xa[wn].dtype))
            xa[bn] = xa.get(bn, 0.0) + shift
        dec["xattn"] = xa
        p["dec_layers"] = dec
        en = dict(p["enc_norm"]); en["bias"] = jnp.zeros_like(beta)
        p["enc_norm"] = en
    # final (decoder) norm bias -> logits bias via lm_head
    beta = p["final_norm"].get("bias")
    if beta is not None:
        head = p.get("lm_head", p["embed"])
        p["lm_head_bias"] = jnp.einsum("vi,i->v", head, beta.astype(head.dtype))
        fn = dict(p["final_norm"]); fn["bias"] = jnp.zeros_like(beta)
        p["final_norm"] = fn
    return p


# --------------------------------------------------------------------------- #
# Top-level fusion
# --------------------------------------------------------------------------- #
def fuse_rotations(cfg: ModelConfig, params: dict, pack: Dict):
    """Apply a rotation pack {'r1', 'r2', 'r1_enc', 'r4'} to params.

    Absorbs norm scales first, unties embeddings when needed, and returns
    ``(fused_cfg, fused_params)`` whose forward outputs are (float-exactly)
    unchanged — verified by tests/test_rotations.py.  LayerNorm models are
    converted to RMS-equivalent form (centering folded into producers), so the
    fused config has ``norm_type == "rmsnorm"``.
    """
    R1 = pack.get("r1")
    R2s = pack.get("r2")
    p = dict(params)
    out_cfg = cfg

    if cfg.norm_type == "layernorm":
        p = convert_ln_to_rms(cfg, p)
        out_cfg = cfg.replace(norm_type="rmsnorm")

    if cfg.tie_embeddings and "lm_head" not in p:
        p["lm_head"] = p["embed"]    # untie: head and embed diverge under fusion

    # final norm -> lm_head
    gamma = p["final_norm"]["scale"]
    p["lm_head"] = p["lm_head"] * gamma[None, :].astype(p["lm_head"].dtype)
    fn = dict(p["final_norm"]); fn["scale"] = jnp.ones_like(gamma)
    p["final_norm"] = fn
    if R1 is not None:
        p["embed"] = _rot_vec(p["embed"], R1)
        p["lm_head"] = _rot_in(p["lm_head"], R1)
        if "pos_dec" in p:
            p["pos_dec"] = _rot_vec(p["pos_dec"], R1)

    if cfg.family == "ssm":
        p["layers"] = _fuse_mamba_block(cfg, p["layers"], R1)
    elif cfg.family == "hybrid":
        p["mamba_groups"] = _fuse_mamba_block(cfg, p["mamba_groups"], R1)
        if "mamba_rest" in p:
            p["mamba_rest"] = _fuse_mamba_block(cfg, p["mamba_rest"], R1)
        shared_r2 = pack.get("r2_shared")
        p["shared"] = _fuse_dense_block(cfg, p["shared"], R1, shared_r2)
    elif cfg.is_encoder_decoder:
        R1e = pack.get("r1_enc")
        enc_gamma = p["enc_norm"]["scale"]
        p["dec_layers"] = _fuse_dense_block(cfg, p["dec_layers"], R1, R2s,
                                            R1_kv=R1e, enc_gamma=enc_gamma)
        en = dict(p["enc_norm"]); en["scale"] = jnp.ones_like(enc_gamma)
        p["enc_norm"] = en
        p["enc_layers"] = _fuse_dense_block(cfg, p["enc_layers"], R1e)
        if R1e is not None:
            p["pos_enc"] = _rot_vec(p["pos_enc"], R1e)
            # encoder stream starts at `frames` (stub embeddings): the frontend
            # stub output is defined in rotated space at serve time.
    elif "dense_layers" in p:
        if R2s is not None:
            nd = cfg.n_dense_layers
            r2_d, r2_m = R2s[:nd], R2s[nd:]
        else:
            r2_d = r2_m = None
        p["dense_layers"] = _fuse_dense_block(cfg, p["dense_layers"], R1, r2_d)
        p["moe_layers"] = _fuse_dense_block(cfg, p["moe_layers"], R1, r2_m)
    else:
        p["layers"] = _fuse_dense_block(cfg, p["layers"], R1, R2s)

    # R4: fold H into w_down so the online Hadamard on the hidden cancels
    if pack.get("r4") is not None:
        p = _fuse_r4(cfg, p)
    return out_cfg, p


def _fuse_r4(cfg: ModelConfig, p: dict) -> dict:
    def fold(blk):
        blk = dict(blk)
        if "mlp" in blk and "w_down" in blk["mlp"]:
            f = blk["mlp"]["w_down"].shape[-1]
            H = jnp.asarray(hadamard_matrix(f), jnp.float32) / np.sqrt(f)
            mlp = dict(blk["mlp"])
            mlp["w_down"] = _rot_in(mlp["w_down"], H)
            blk["mlp"] = mlp
        if "moe" in blk:
            moe = dict(blk["moe"])
            f = moe["w_down"].shape[-1]
            H = jnp.asarray(hadamard_matrix(f), jnp.float32) / np.sqrt(f)
            moe["w_down"] = _rot_in(moe["w_down"], H)
            if "shared" in moe and "w_down" in moe["shared"]:
                sh = dict(moe["shared"])
                fs = sh["w_down"].shape[-1]
                Hs = jnp.asarray(hadamard_matrix(fs), jnp.float32) / np.sqrt(fs)
                sh["w_down"] = _rot_in(sh["w_down"], Hs)
                moe["shared"] = sh
            blk["moe"] = moe
        return blk

    for key in ("layers", "dense_layers", "moe_layers", "dec_layers",
                "enc_layers", "shared"):
        if key in p and isinstance(p[key], dict) and (
                "mlp" in p[key] or "moe" in p[key]):
            p[key] = fold(p[key])
    return p
