"""DartQuant calibration driver (paper Algorithm 1, per rotation site).

``calibrate_model`` = capture -> token-sample -> per-site QR-Orth/Whip
optimization -> rotation pack ready for ``fuse_rotations``.

Also provides the QuaRot baseline (``random_pack``: random Hadamard R1/R2) and
identity pack, used by benchmarks to reproduce the paper's comparisons.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import whip as objectives
from repro.core.capture import capture_activations
from repro.core.qr_orth import calibrate_cayley, calibrate_qr, qr_rotation
from repro.core.rotations import random_hadamard


def calibrate_rotation(x: jax.Array, n: int, key, objective: str = "whip",
                       method: str = "qr", optimizer: str = "sgd",
                       steps: int = 100, lr: float = 5e-2,
                       callback: Optional[Callable] = None) -> jax.Array:
    """Optimize one rotation on captured activations x [N, n]."""
    obj = objectives.OBJECTIVES[objective]
    z0 = random_hadamard(n, key)           # paper App. K: Hadamard init
    if method == "cayley":
        return calibrate_cayley(x, z0, obj, steps=steps, lr=lr,
                                callback=callback)
    return calibrate_qr(x, z0, obj, steps=steps, lr=lr, optimizer=optimizer,
                        callback=callback)


def _r2_dim(cfg: ModelConfig) -> int:
    return cfg.v_head_dim if cfg.attn_type == "mla" else cfg.resolved_head_dim


def calibrate_model(cfg: ModelConfig, params: dict, tokens: jax.Array,
                    frames=None, key=None, objective: str = "whip",
                    method: str = "qr", optimizer: str = "sgd",
                    steps: int = 100, lr_r1: float = 2e-3,
                    lr_r2: float = 1e-3, sample_frac: float = 0.1,
                    use_r2: bool = True, verbose: bool = False) -> Dict:
    """Full DartQuant calibration: returns a rotation pack for fuse_rotations."""
    if key is None:
        key = jax.random.PRNGKey(0)
    t0 = time.time()
    acts = capture_activations(cfg, params, tokens, frames=frames,
                               sample_frac=sample_frac, key=key)
    ks = iter(jax.random.split(key, 64))
    pack: Dict = {}

    if not cfg.sandwich_norm:   # gemma2: R1 fusion blocked by post-norms
        pack["r1"] = calibrate_rotation(acts["r1"], cfg.d_model, next(ks),
                                        objective=objective, method=method,
                                        optimizer=optimizer, steps=steps,
                                        lr=lr_r1)
        if "r1_enc" in acts:
            pack["r1_enc"] = calibrate_rotation(acts["r1_enc"], cfg.d_model,
                                                next(ks), objective=objective,
                                                method=method,
                                                optimizer=optimizer,
                                                steps=steps, lr=lr_r1)
    if use_r2 and "r2" in acts:
        hd = _r2_dim(cfg)
        r2_list = []
        for i in range(acts["r2"].shape[0]):
            r2_list.append(calibrate_rotation(
                acts["r2"][i], hd, next(ks), objective=objective,
                method=method, optimizer=optimizer, steps=steps, lr=lr_r2))
        r2 = jnp.stack(r2_list, axis=0)
        if cfg.family == "hybrid":
            pack["r2_shared"] = jnp.mean(r2, axis=0) if r2.shape[0] == 1 else r2[0]
            # shared block: calibrate on pooled V activations of all applications
            pooled = acts["r2"].reshape(-1, hd)
            pack["r2_shared"] = calibrate_rotation(
                pooled, hd, next(ks), objective=objective, method=method,
                optimizer=optimizer, steps=steps, lr=lr_r2)
        else:
            pack["r2"] = r2
    pack["r4"] = True
    if verbose:
        print(f"calibration done in {time.time() - t0:.1f}s "
              f"(sites: {list(pack)})")
    return pack


def random_pack(cfg: ModelConfig, key, use_r2: bool = True) -> Dict:
    """QuaRot baseline: random Hadamard rotations, no calibration."""
    ks = jax.random.split(key, 4)
    pack: Dict = {"r4": True}
    if not cfg.sandwich_norm:
        pack["r1"] = random_hadamard(cfg.d_model, ks[0])
        if cfg.is_encoder_decoder:
            pack["r1_enc"] = random_hadamard(cfg.d_model, ks[1])
    if use_r2 and cfg.attn_type != "none":
        hd = _r2_dim(cfg)
        if cfg.family == "hybrid":
            pack["r2_shared"] = random_hadamard(hd, ks[2])
        else:
            n_r2 = cfg.n_layers
            r2keys = jax.random.split(ks[2], n_r2)
            pack["r2"] = jnp.stack([random_hadamard(hd, k) for k in r2keys])
    return pack


def identity_pack(cfg: ModelConfig) -> Dict:
    """No rotation at all (RTN baseline); still absorbs norms for parity."""
    return {"r4": None}
