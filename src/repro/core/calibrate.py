"""DartQuant calibration driver (paper Algorithm 1, per rotation site).

``calibrate_model`` = capture -> token-sample -> per-site QR-Orth/Whip
optimization -> rotation pack ready for ``fuse_rotations``.

Per-layer R2 sites are optimized by the scanned+vmapped engine
(``qr_orth.calibrate_rotations_batched``): all ``n_layers`` trajectories run
inside ONE compiled call instead of a serial Python loop — pass
``r2_batched=False`` to fall back to the serial path (same per-layer keys, so
batched and serial produce the same rotations up to float-noise
amplification).  Loss histories follow the contract documented in
``repro.core.qr_orth``: ``history[k]`` is the pre-update objective value of
step ``k``.

Also provides the QuaRot baseline (``random_pack``: random Hadamard R1/R2) and
identity pack, used by benchmarks to reproduce the paper's comparisons.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import qr_orth
from repro.core import whip as objectives
from repro.core.capture import capture_activations
from repro.core.qr_orth import calibrate_scan
from repro.core.rotations import random_hadamard


def calibrate_rotation(x: jax.Array, n: int, key, objective: str = "whip",
                       method: str = "qr", optimizer: str = "sgd",
                       steps: int = 100, lr: float = 5e-2,
                       callback: Optional[Callable] = None,
                       orth: str = "cholqr",
                       return_history: bool = False, mesh=None,
                       compressed_grads: bool = False,
                       obs=None, site: Optional[str] = None):
    """Optimize one rotation on captured activations x [N, n].

    Returns the rotation, or ``(rotation, loss_history)`` when
    ``return_history`` — the history never leaves the device until read.
    ``mesh`` runs the token-sharded engine (see ``repro.core.qr_orth``).
    """
    obj = objectives.OBJECTIVES[objective]
    z0 = random_hadamard(n, key)           # paper App. K: Hadamard init
    if method == "cayley":
        res = calibrate_scan(x, z0, obj, method="cayley", steps=steps, lr=lr,
                             mesh=mesh, compressed_grads=compressed_grads,
                             obs=obs, site=site)
    else:
        res = calibrate_scan(x, z0, obj, method="qr", optimizer=optimizer,
                             steps=steps, lr=lr, orth=orth, mesh=mesh,
                             compressed_grads=compressed_grads,
                             obs=obs, site=site)
    if callback is not None:
        qr_orth._replay(callback, res, res.rotation)
    if return_history:
        return res.rotation, res.loss_history
    return res.rotation


def calibrate_rotations(xs: jax.Array, n: int, key,
                        objective: str = "whip", method: str = "qr",
                        optimizer: str = "sgd", steps: int = 100,
                        lr: float = 5e-2, orth: str = "cholqr",
                        return_history: bool = False, mesh=None,
                        compressed_grads: bool = False,
                        obs=None, site: Optional[str] = None):
    """Optimize all L sites of xs [L, N, n] in one compiled vmapped scan.

    Per-site inits use ``jax.random.split(key, L)`` — identical to the serial
    path in ``calibrate_model(r2_batched=False)``, so the two are
    interchangeable.  Returns [L, n, n] rotations (plus [L, steps] histories
    when ``return_history``).  ``mesh`` shards the token axis over the mesh's
    data group (``repro.core.qr_orth`` mesh contract).
    """
    obj = objectives.OBJECTIVES[objective]
    layer_keys = jax.random.split(key, xs.shape[0])
    z0s = jnp.stack([random_hadamard(n, k) for k in layer_keys])
    res = qr_orth.calibrate_rotations_batched(
        xs, z0s, obj, method=method, optimizer=optimizer, steps=steps, lr=lr,
        orth=orth, mesh=mesh, compressed_grads=compressed_grads,
        obs=obs, site=site)
    if return_history:
        return res.rotation, res.loss_history
    return res.rotation


def _r2_dim(cfg: ModelConfig) -> int:
    return cfg.v_head_dim if cfg.attn_type == "mla" else cfg.resolved_head_dim


def calibrate_model(cfg: ModelConfig, params: dict, tokens: jax.Array,
                    frames=None, key=None, objective: str = "whip",
                    method: str = "qr", optimizer: str = "sgd",
                    steps: int = 100, lr_r1: float = 2e-3,
                    lr_r2: float = 1e-3, sample_frac: float = 0.1,
                    use_r2: bool = True, r2_batched: bool = True,
                    verbose: bool = False,
                    history_out: Optional[dict] = None, mesh=None,
                    compressed_grads: bool = False, obs=None) -> Dict:
    """Full DartQuant calibration: returns a rotation pack for fuse_rotations.

    All per-layer R2 sites are optimized in one compiled call (vmapped scan)
    unless ``r2_batched=False``; pass a dict as ``history_out`` to receive
    per-site loss histories keyed by site name.  With ``mesh=``, captured
    activations stay token-sharded over the mesh's data axes and every site
    runs on the token-sharded engine (``repro.core.qr_orth`` mesh contract).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    # independent streams: token sampling must not correlate with the
    # rotation inits (R1's Hadamard init used to share the raw key with
    # capture's sampler)
    k_cap, k_rot = jax.random.split(key)
    t0 = time.perf_counter()
    acts = capture_activations(cfg, params, tokens, frames=frames,
                               sample_frac=sample_frac, key=k_cap, mesh=mesh)
    if obs is not None:
        jax.block_until_ready(acts)
        obs.metrics.gauge(
            "calib_capture_seconds",
            help="activation capture + token sampling wall time").set(
                time.perf_counter() - t0)
    ks = iter(jax.random.split(k_rot, 64))
    pack: Dict = {}

    def record(name, history):
        if history_out is not None:
            history_out[name] = history

    if not cfg.sandwich_norm:   # gemma2: R1 fusion blocked by post-norms
        pack["r1"], h = calibrate_rotation(
            acts["r1"], cfg.d_model, next(ks), objective=objective,
            method=method, optimizer=optimizer, steps=steps, lr=lr_r1,
            return_history=True, mesh=mesh, compressed_grads=compressed_grads,
            obs=obs, site="r1")
        record("r1", h)
        if "r1_enc" in acts:
            pack["r1_enc"], h = calibrate_rotation(
                acts["r1_enc"], cfg.d_model, next(ks), objective=objective,
                method=method, optimizer=optimizer, steps=steps, lr=lr_r1,
                return_history=True, mesh=mesh,
                compressed_grads=compressed_grads, obs=obs, site="r1_enc")
            record("r1_enc", h)
    if use_r2 and "r2" in acts:
        hd = _r2_dim(cfg)
        if cfg.family == "hybrid":
            # shared block: calibrate on pooled V activations of all uses
            pooled = acts["r2"].reshape(-1, hd)
            pack["r2_shared"], h = calibrate_rotation(
                pooled, hd, next(ks), objective=objective, method=method,
                optimizer=optimizer, steps=steps, lr=lr_r2,
                return_history=True, mesh=mesh,
                compressed_grads=compressed_grads, obs=obs,
                site="r2_shared")
            record("r2_shared", h)
        else:
            k_r2 = next(ks)
            if r2_batched:
                pack["r2"], h = calibrate_rotations(
                    acts["r2"], hd, k_r2, objective=objective, method=method,
                    optimizer=optimizer, steps=steps, lr=lr_r2,
                    return_history=True, mesh=mesh,
                    compressed_grads=compressed_grads, obs=obs, site="r2")
                record("r2", h)
            else:
                layer_keys = jax.random.split(k_r2, acts["r2"].shape[0])
                r2_list, h_list = [], []
                for i in range(acts["r2"].shape[0]):
                    r, h = calibrate_rotation(
                        acts["r2"][i], hd, layer_keys[i], objective=objective,
                        method=method, optimizer=optimizer, steps=steps,
                        lr=lr_r2, return_history=True, mesh=mesh,
                        compressed_grads=compressed_grads, obs=obs,
                        site=f"r2[{i}]")
                    r2_list.append(r)
                    h_list.append(h)
                pack["r2"] = jnp.stack(r2_list, axis=0)
                record("r2", jnp.stack(h_list, axis=0))
    pack["r4"] = True
    dt = time.perf_counter() - t0
    if obs is not None:
        obs.metrics.gauge(
            "calib_total_seconds",
            help="capture + all rotation sites wall time").set(dt)
    if verbose:
        print(f"calibration done in {dt:.1f}s (sites: {list(pack)})")
    return pack


def random_pack(cfg: ModelConfig, key, use_r2: bool = True) -> Dict:
    """QuaRot baseline: random Hadamard rotations, no calibration."""
    ks = jax.random.split(key, 4)
    pack: Dict = {"r4": True}
    if not cfg.sandwich_norm:
        pack["r1"] = random_hadamard(cfg.d_model, ks[0])
        if cfg.is_encoder_decoder:
            pack["r1_enc"] = random_hadamard(cfg.d_model, ks[1])
    if use_r2 and cfg.attn_type != "none":
        hd = _r2_dim(cfg)
        if cfg.family == "hybrid":
            pack["r2_shared"] = random_hadamard(hd, ks[2])
        else:
            n_r2 = cfg.n_layers
            r2keys = jax.random.split(ks[2], n_r2)
            pack["r2"] = jnp.stack([random_hadamard(hd, k) for k in r2keys])
    return pack


def identity_pack(cfg: ModelConfig) -> Dict:
    """No rotation at all (RTN baseline); still absorbs norms for parity."""
    return {"r4": None}
