"""Activation capture at rotation sites (paper Alg. 1: ``X <- LLM(S)``).

Sites:
  r1      — post-norm residual-stream activations entering rotated consumers
            (every layer's ln1/ln2 outputs + the final-norm output)
  r2/<i>  — per-layer V-projection outputs, per head, [N, head_dim]
  r1_enc  — whisper: encoder-stream equivalent of r1

The capture pass runs layers *unrolled* (python loop over stacked-param
slices): calibration is offline, layer-at-a-time — this is exactly the
property that lets DartQuant calibrate a 70B on one 24GB GPU.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import apply_norm, linear
from repro.models.model import _embed


def token_sample(x: jax.Array, frac: float, key) -> jax.Array:
    """x [N, d] -> random fraction of rows (paper: 10%)."""
    n = x.shape[0]
    k = max(1, int(n * frac))
    idx = jax.random.choice(key, n, (k,), replace=False)
    return x[idx]


def _slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _v_out(cfg: ModelConfig, attn_p: dict, h: jax.Array) -> jax.Array:
    """V-projection outputs reshaped to [N_tokens*heads, head_dim] (R2 site)."""
    B, S, _ = h.shape
    if cfg.attn_type == "mla":
        kvlr = cfg.kv_lora_rank
        from repro.models.common import rmsnorm
        ckv = linear(h, attn_p["wkv_a"])[..., :kvlr]
        ckv = rmsnorm(ckv, attn_p["kv_norm"]["scale"], cfg.norm_eps)
        kv = linear(ckv, attn_p["wkv_b"]).reshape(
            B, S, cfg.n_heads, cfg.qk_nope_head_dim + cfg.v_head_dim)
        v = kv[..., cfg.qk_nope_head_dim:]
        return v.reshape(-1, cfg.v_head_dim)
    hd = cfg.resolved_head_dim
    v = linear(h, attn_p["wv"], attn_p.get("bv"))
    return v.reshape(-1, hd)


def capture_activations(cfg: ModelConfig, params: dict, tokens: jax.Array,
                        frames: Optional[jax.Array] = None,
                        sample_frac: float = 0.1,
                        key=None, mesh=None) -> Dict[str, jax.Array]:
    """Returns {'r1': [N,D], 'r2': [L,Nv,hd] (if attn), 'r1_enc': [N,D] (enc-dec)}.

    With ``mesh=``, the pooled activations are returned token-sharded over the
    mesh's data axes (``repro.dist.place_calib_acts``) instead of concentrated
    on one device, so the calibration engine consumes them in place — each
    pool is trimmed to the shard multiple (at most shards-1 sampled tokens
    dropped)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    B, S = tokens.shape
    D = cfg.d_model
    positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed(cfg, params, tokens)
    r1_pool, r2_pool, r1e_pool = [], [], []

    def collect_r1(h, k):
        r1_pool.append(token_sample(h.reshape(-1, D).astype(jnp.float32),
                                    sample_frac, k))

    keys = iter(jax.random.split(key, 4 * cfg.n_layers + 16))

    def run_dense_stack(layers, x, n, encoder_out=None, collect_r2=True,
                        pool=r1_pool, windows=None):
        for i in range(n):
            lp = _slice(layers, i)
            h = apply_norm(cfg, lp["ln1"], x)
            pool.append(token_sample(h.reshape(-1, D).astype(jnp.float32),
                                     sample_frac, next(keys)))
            if collect_r2:
                hd_v = cfg.v_head_dim if cfg.attn_type == "mla" else cfg.resolved_head_dim
                v = _v_out(cfg, lp["attn"], h)
                r2_pool.append(token_sample(v.astype(jnp.float32),
                                            sample_frac, next(keys)))
            win = int(tfm.layer_windows(cfg, n)[i]) if cfg.layer_pattern else 0
            x, _ = tfm.dense_block(cfg, lp, x, positions, window=win,
                                   encoder_out=encoder_out,
                                   causal=not (encoder_out is not None and False))
        return x

    if cfg.family == "ssm":
        for i in range(cfg.n_layers):
            lp = _slice(params["layers"], i)
            h = apply_norm(cfg, lp["ln"], x)
            collect_r1(h, next(keys))
            x = tfm.mamba_block(cfg, lp, x)
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        for g in range(n_groups):
            for i in range(every):
                lp = _slice(_slice(params["mamba_groups"], g), i)
                h = apply_norm(cfg, lp["ln"], x)
                collect_r1(h, next(keys))
                x = tfm.mamba_block(cfg, lp, x)
            sp = params["shared"]
            h = apply_norm(cfg, sp["ln1"], x)
            collect_r1(h, next(keys))
            r2_pool.append(token_sample(
                _v_out(cfg, sp["attn"], h).astype(jnp.float32),
                sample_frac, next(keys)))
            x, _ = tfm.dense_block(cfg, sp, x, positions)
        for i in range(cfg.n_layers % every):
            lp = _slice(params["mamba_rest"], i)
            h = apply_norm(cfg, lp["ln"], x)
            collect_r1(h, next(keys))
            x = tfm.mamba_block(cfg, lp, x)
    elif cfg.is_encoder_decoder:
        enc = frames.astype(x.dtype) + params["pos_enc"][None].astype(x.dtype)
        for i in range(cfg.n_encoder_layers):
            lp = _slice(params["enc_layers"], i)
            h = apply_norm(cfg, lp["ln1"], enc)
            r1e_pool.append(token_sample(h.reshape(-1, D).astype(jnp.float32),
                                         sample_frac, next(keys)))
            enc, _ = tfm.dense_block(cfg, lp, enc,
                                     jnp.arange(enc.shape[1], dtype=jnp.int32),
                                     causal=False)
        enc = apply_norm(cfg, params["enc_norm"], enc)
        x = x + params["pos_dec"][positions][None].astype(x.dtype)
        x = run_dense_stack(params["dec_layers"], x, cfg.n_layers,
                            encoder_out=enc)
    elif "dense_layers" in params:
        x = run_dense_stack(params["dense_layers"], x, cfg.n_dense_layers)
        x = run_dense_stack(params["moe_layers"], x,
                            cfg.n_layers - cfg.n_dense_layers)
    else:
        x = run_dense_stack(params["layers"], x, cfg.n_layers)

    # final-norm output (lm_head consumer)
    xf = apply_norm(cfg, params["final_norm"], x)
    r1_pool.append(token_sample(xf.reshape(-1, D).astype(jnp.float32),
                                sample_frac, next(keys)))

    out = {"r1": jnp.concatenate(r1_pool, axis=0)}
    if r2_pool:
        out["r2"] = jnp.stack(r2_pool, axis=0)
    if r1e_pool:
        out["r1_enc"] = jnp.concatenate(r1e_pool, axis=0)
    if mesh is not None:
        from repro.dist.sharding import place_calib_acts
        out = place_calib_acts(out, mesh)
    return out
