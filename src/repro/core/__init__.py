"""DartQuant core: rotational distribution calibration (the paper's contribution)."""
from repro.core.calibrate import (calibrate_model, calibrate_rotation,
                                  identity_pack, random_pack)
from repro.core.capture import capture_activations, token_sample
from repro.core.qr_orth import (calibrate_cayley, calibrate_qr,
                                cayley_sgd_step, orthogonality_error,
                                qr_rotation)
from repro.core.rotations import (fuse_rotations, hadamard_matrix,
                                  online_hadamard, random_hadamard)
from repro.core.whip import (OBJECTIVES, kurtosis, outlier_count, quant_error,
                             quant_loss, variance, whip)
