"""DartQuant core: rotational distribution calibration (the paper's contribution)."""
from repro.core.calibrate import (calibrate_model, calibrate_rotation,
                                  calibrate_rotations, identity_pack,
                                  random_pack)
from repro.core.capture import capture_activations, token_sample
from repro.core.qr_orth import (CalibResult, calibrate_cayley,
                                calibrate_cayley_legacy, calibrate_qr,
                                calibrate_qr_legacy, calibrate_scan,
                                cayley_sgd_step, cholqr_rotation,
                                orthogonality_error, qr_rotation)
from repro.core.rotations import (fuse_rotations, hadamard_matrix,
                                  online_hadamard, random_hadamard)
from repro.core.whip import (OBJECTIVES, kurtosis, outlier_count, quant_error,
                             quant_loss, variance, whip)
