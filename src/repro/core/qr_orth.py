"""QR-Orth (the paper's optimizer) and the Cayley-SGD baseline (Alg. 3).

QR-Orth: parametrize the rotation as ``R = qr(Z).Q`` of an unconstrained
latent ``Z`` and run any Euclidean optimizer on ``Z``.  One Householder QR is
~(4/3)n^3 vs Cayley's +6n^3 of extra matmuls per step (paper App. B).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- #
# QR-Orth parametrization
# --------------------------------------------------------------------------- #
def qr_rotation(z: jax.Array) -> jax.Array:
    """Orthogonal factor of Z with sign-fixed diagonal (unique, det-stable)."""
    q, r = jnp.linalg.qr(z)
    d = jnp.sign(jnp.diagonal(r))
    d = jnp.where(d == 0, 1.0, d)
    return q * d[None, :]


# --------------------------------------------------------------------------- #
# Euclidean optimizers on the latent Z (SGD-momentum / Adam)
# --------------------------------------------------------------------------- #
def sgd_update(z, m, g, lr, beta=0.9):
    m = beta * m + g
    return z - lr * m, m


def adam_update(z, state, g, lr, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return z - lr * mh / (jnp.sqrt(vh) + eps), (m, v, t)


def calibrate_qr(x: jax.Array, z0: jax.Array, objective: Callable,
                 steps: int = 100, lr: float = 2e-3, optimizer: str = "sgd",
                 callback: Optional[Callable] = None) -> jax.Array:
    """Algorithm 1: optimize latent Z so ``objective(x @ qr(Z).Q)`` drops.

    Returns the final rotation R (Z is discarded, per the paper).
    """
    def loss_fn(z):
        return objective(x @ qr_rotation(z).astype(x.dtype))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    z = z0
    if optimizer == "adam":
        state = (jnp.zeros_like(z), jnp.zeros_like(z), jnp.zeros((), jnp.int32))
        upd = adam_update
    else:
        state = jnp.zeros_like(z)
        upd = sgd_update
    for k in range(steps):
        loss, g = grad_fn(z)
        z, state = upd(z, state, g, lr)
        if callback is not None:
            callback(k, float(loss), z)
    return qr_rotation(z)


# --------------------------------------------------------------------------- #
# Cayley SGD with momentum (paper Alg. 3) — the expensive baseline
# --------------------------------------------------------------------------- #
def cayley_sgd_step(r, m, g, lr, beta=0.9, q=0.5, s=2, eps=1e-8):
    """One Riemannian step on the Stiefel manifold via iterative Cayley."""
    m = beta * m - g
    w_hat = m @ r.T - 0.5 * r @ (r.T @ m @ r.T)
    w = w_hat - w_hat.T
    m_new = w @ r
    alpha = jnp.minimum(lr, 2 * q / (jnp.linalg.norm(w) + eps))
    y = r + alpha * m_new
    for _ in range(s):
        y = r + (alpha / 2) * w @ (r + y)
    return y, m_new


def calibrate_cayley(x: jax.Array, r0: jax.Array, objective: Callable,
                     steps: int = 100, lr: float = 2e-3,
                     callback: Optional[Callable] = None) -> jax.Array:
    def loss_fn(r):
        return objective(x @ r.astype(x.dtype))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    step = jax.jit(partial(cayley_sgd_step))
    r = r0
    m = jnp.zeros_like(r)
    for k in range(steps):
        loss, g = grad_fn(r)
        r, m = step(r, m, g, lr)
        if callback is not None:
            callback(k, float(loss), r)
    return r


def orthogonality_error(r: jax.Array) -> jax.Array:
    n = r.shape[0]
    return jnp.max(jnp.abs(r @ r.T - jnp.eye(n, dtype=r.dtype)))
