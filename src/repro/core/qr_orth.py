"""QR-Orth (the paper's optimizer) and the Cayley-SGD baseline (Alg. 3).

QR-Orth: parametrize the rotation as ``R = qr(Z).Q`` of an unconstrained
latent ``Z`` and run any Euclidean optimizer on ``Z``.  One Householder QR is
~(4/3)n^3 vs Cayley's +6n^3 of extra matmuls per step (paper App. B).

Scan-based calibration engine
-----------------------------
The engine runs the whole optimization inside one ``jax.lax.scan`` so a
calibration is a single compiled XLA call instead of a host-driven Python loop
that re-enters jit every step:

    calibrate_scan(x, z0, objective, ...)            -> CalibResult
    calibrate_rotations_batched(xs, z0s, objective)  -> CalibResult (vmapped
                                                        over a leading L axis)

Loss-history contract: ``CalibResult.loss_history[k]`` is the objective value
at the *pre-update* parameters of step ``k`` — ``loss_history[0]`` is the loss
at the initialization, exactly the value the legacy host-loop callback
reported at step ``k``.  ``CalibResult.aux[name][k]`` follows the same
convention: each metric in ``metrics=(("name", fn), ...)`` is evaluated on the
pre-update rotated activations ``x @ R_k`` inside the compiled loop, so
recording a trace (e.g. quantization error per step, Fig. 7) costs no host
round-trips.  Histories live on device until the caller pulls them.

Orthogonalization backends (``orth=``):
  "cholqr"  (default) CholeskyQR — mathematically the same sign-fixed Q factor
            as Householder QR (Cholesky of Z^T Z has a positive diagonal, so
            the sign convention matches ``qr_rotation`` exactly in exact
            arithmetic) but built from matmul + cholesky + triangular-solve,
            which XLA batches and fuses far better than the LAPACK QR custom
            call; accuracy degrades as cond(Z)^2 * eps, and the latent stays
            near-orthogonal throughout calibration (cond < ~10 empirically).
            Gradients flow through a hand-derived custom VJP (one triangular
            solve + two matmuls) instead of JAX's generic QR pullback.
  "qr"      LAPACK QR + autodiff — bit-compatible with the legacy host loop's
            math; used by the compatibility shims and equivalence tests.

Token-sharded calibration (mesh contract)
-----------------------------------------
Pass ``mesh=`` to either entry point and the scan runs under ``shard_map``
over the mesh's data group — every axis except 'model' (so the production
mesh's 'pod' axis composes in, exactly like ``repro.dist.Sharding``):

  * activations shard their TOKEN axis N over the data group
    (``repro.dist.calib_specs``: ``x`` -> P(data, None), ``xs`` ->
    P(None, data, None)); calibration-set size scales with the mesh instead
    of one device's memory,
  * rotation latents, optimizer state, and ``lr`` REPLICATE (P()) — every
    shard steps the identical latent,
  * each step, the objective value and its latent gradient are psum'd over
    the data group (one collective per step; ``compressed_grads=True`` swaps
    the gradient psum for the int8+error-feedback reduction in
    ``repro.dist.collectives.psum_compressed``),
  * uneven N is padded to the shard multiple and masked out of the loss, so
    results are identical to the single-device path up to f32 reduction
    order; the ``CalibResult`` contract (rotation, loss history, aux
    metrics) is unchanged.

The sharded objective/metric contract: the objective must be a mean of
independent per-token scores (true of every entry in
``repro.core.whip.OBJECTIVES``) — per-shard partial means are combined with
a single psum.

The legacy host loops are preserved verbatim as ``calibrate_qr_legacy`` /
``calibrate_cayley_legacy`` for benchmarks (cost baseline) and equivalence
tests; ``calibrate_qr`` / ``calibrate_cayley`` keep their old signatures but
delegate to the scanned engine (a supplied ``callback`` is replayed from the
recorded loss history after the fact — it receives the *final* parameters, as
per-step latents are no longer materialized on the host).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.collectives import psum_compressed
from repro.dist.sharding import calib_data_axes, calib_group_size, calib_specs


# --------------------------------------------------------------------------- #
# QR-Orth parametrization
# --------------------------------------------------------------------------- #
def qr_rotation(z: jax.Array) -> jax.Array:
    """Orthogonal factor of Z with sign-fixed diagonal (unique, det-stable)."""
    q, r = jnp.linalg.qr(z)
    d = jnp.sign(jnp.diagonal(r))
    d = jnp.where(d == 0, 1.0, d)
    return q * d[None, :]


@jax.custom_vjp
def cholqr_rotation(z: jax.Array) -> jax.Array:
    """CholeskyQR: the same sign-fixed Q factor as ``qr_rotation`` for square
    full-rank Z, computed as Z L^{-T} with L = chol(Z^T Z).

    Error is O(cond(Z)^2 * eps); intended for the near-orthogonal latents the
    calibration engine maintains.  The custom VJP implements the Q-factor
    pullback (Townsend, "Differentiating the QR decomposition") directly:
    dZ = (dQ + Q copyltu(-dQ^T Q)) R^{-T} — two matmuls and one triangular
    solve, much cheaper on CPU/TPU than JAX's generic QR gradient.
    """
    l = jnp.linalg.cholesky(z.T @ z)
    return jsl.solve_triangular(l, z.T, lower=True).T


def _cholqr_fwd(z):
    l = jnp.linalg.cholesky(z.T @ z)
    q = jsl.solve_triangular(l, z.T, lower=True).T
    return q, (q, l)


def _cholqr_bwd(res, qbar):
    q, l = res                      # R = L^T (upper, positive diagonal)
    m = -qbar.T @ q                 # R-cotangent is zero: only Q is consumed
    c = jnp.tril(m, -1) + jnp.tril(m, -1).T + jnp.diag(jnp.diagonal(m))
    y = qbar + q @ c
    return (jsl.solve_triangular(l.T, y.T, lower=False).T,)


cholqr_rotation.defvjp(_cholqr_fwd, _cholqr_bwd)


ORTH_FNS = {"qr": qr_rotation, "cholqr": cholqr_rotation}


# --------------------------------------------------------------------------- #
# Euclidean optimizers on the latent Z (SGD-momentum / Adam)
# --------------------------------------------------------------------------- #
def sgd_update(z, m, g, lr, beta=0.9):
    m = beta * m + g
    return z - lr * m, m


def adam_update(z, state, g, lr, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    return z - lr * mh / (jnp.sqrt(vh) + eps), (m, v, t)


# --------------------------------------------------------------------------- #
# Cayley SGD with momentum (paper Alg. 3) — the expensive baseline
# --------------------------------------------------------------------------- #
def cayley_sgd_step(r, m, g, lr, beta=0.9, q=0.5, s=2, eps=1e-8):
    """One Riemannian step on the Stiefel manifold via iterative Cayley."""
    m = beta * m - g
    w_hat = m @ r.T - 0.5 * r @ (r.T @ m @ r.T)
    w = w_hat - w_hat.T
    m_new = w @ r
    alpha = jnp.minimum(lr, 2 * q / (jnp.linalg.norm(w) + eps))
    y = r + alpha * m_new
    for _ in range(s):
        y = r + (alpha / 2) * w @ (r + y)
    return y, m_new


# --------------------------------------------------------------------------- #
# Scan-based engine
# --------------------------------------------------------------------------- #
class CalibResult(NamedTuple):
    """Result of a scanned calibration.

    rotation:     [n, n] (or [L, n, n] for the batched entry point)
    loss_history: [steps] (or [L, steps]) pre-update objective values
    aux:          {metric_name: [steps] (or [L, steps])} pre-update metrics
    """
    rotation: jax.Array
    loss_history: jax.Array
    aux: dict


def _opt_init(method: str, optimizer: str, z0: jax.Array):
    if method != "cayley" and optimizer == "adam":
        return (jnp.zeros_like(z0), jnp.zeros_like(z0),
                jnp.zeros((), jnp.int32))
    return jnp.zeros_like(z0)       # SGD / Cayley momentum buffer


def _make_update(method, optimizer, lr):
    if method == "cayley":
        return lambda p, state, g: cayley_sgd_step(p, state, g, lr)
    if optimizer == "adam":
        return lambda p, state, g: adam_update(p, state, g, lr)
    return lambda p, state, g: sgd_update(p, state, g, lr)


def _scan_core(x, z0, lr, objective, method, optimizer, steps, orth, metrics):
    """One site: full optimization inside a single lax.scan."""
    orth_fn = (lambda r: r) if method == "cayley" else ORTH_FNS[orth]

    def fwd(p):
        o = x @ orth_fn(p).astype(x.dtype)
        return objective(o), o

    update = _make_update(method, optimizer, lr)

    def step(carry, _):
        p, state = carry
        (loss, o), g = jax.value_and_grad(fwd, has_aux=True)(p)
        outs = {"loss": loss}
        for name, fn in metrics:
            outs[name] = fn(o)
        p, state = update(p, state, g)
        return (p, state), outs

    carry0 = (z0, _opt_init(method, optimizer, z0))
    (p_final, _), hist = jax.lax.scan(step, carry0, None, length=steps)
    loss_history = hist.pop("loss")
    return CalibResult(orth_fn(p_final), loss_history, hist)


@partial(jax.jit, static_argnums=(3, 4, 5, 6, 7, 8))
def _scan_one(x, z0, lr, objective, method, optimizer, steps, orth, metrics):
    return _scan_core(x, z0, lr, objective, method, optimizer, steps, orth,
                      metrics)


@partial(jax.jit, static_argnums=(3, 4, 5, 6, 7, 8))
def _scan_batched(xs, z0s, lr, objective, method, optimizer, steps, orth,
                  metrics):
    f = partial(_scan_core, lr=lr, objective=objective, method=method,
                optimizer=optimizer, steps=steps, orth=orth, metrics=metrics)
    return jax.vmap(lambda x, z: f(x, z))(xs, z0s)


# --------------------------------------------------------------------------- #
# Token-sharded engine (see module docstring: "Token-sharded calibration")
# --------------------------------------------------------------------------- #
def _per_token(fn, o):
    """Per-row scores of a mean-of-per-token-scores objective/metric."""
    return jax.vmap(lambda row: fn(row[None, :]))(o)


def _scan_core_sharded(x, w, z0, lr, objective, method, optimizer, steps,
                       orth, metrics, axes, n_valid, compressed):
    """Per-shard scan body: ``x`` [N_local, n] local tokens, ``w`` [N_local]
    validity weights (0 on padding rows), ``z0``/``lr`` replicated.

    Each step computes the LOCAL partial loss sum(scores * w) / n_valid, then
    psums loss, metrics, and the latent gradient over ``axes`` — every shard
    applies the identical update, so latents stay replicated by construction.
    """
    orth_fn = (lambda r: r) if method == "cayley" else ORTH_FNS[orth]

    def fwd(p):
        o = x @ orth_fn(p).astype(x.dtype)
        local = jnp.sum(_per_token(objective, o) * w) / n_valid
        return local, o

    update = _make_update(method, optimizer, lr)

    def step(carry, _):
        if compressed:
            p, state, err = carry
        else:
            p, state = carry
        (local, o), g = jax.value_and_grad(fwd, has_aux=True)(p)
        outs = {"loss": jax.lax.psum(local, axes)}
        for name, fn in metrics:
            outs[name] = jax.lax.psum(
                jnp.sum(_per_token(fn, o) * w) / n_valid, axes)
        if compressed:
            g, err = psum_compressed(g, err, axes)
            g = g.astype(p.dtype)
        else:
            g = jax.lax.psum(g, axes)
        p, state = update(p, state, g)
        return ((p, state, err) if compressed else (p, state)), outs

    carry0 = (z0, _opt_init(method, optimizer, z0))
    if compressed:
        carry0 = carry0 + (jnp.zeros_like(z0, jnp.float32),)
    final, hist = jax.lax.scan(step, carry0, None, length=steps)
    loss_history = hist.pop("loss")
    return CalibResult(orth_fn(final[0]), loss_history, hist)


@partial(jax.jit, static_argnums=tuple(range(4, 14)))
def _scan_one_sharded(x, w, z0, lr, objective, method, optimizer, steps,
                      orth, metrics, mesh, axes, n_valid, compressed):
    s = calib_specs(mesh, axes)

    def body(x_l, w_l, z_l, lr_l):
        return _scan_core_sharded(x_l, w_l, z_l, lr_l, objective, method,
                                  optimizer, steps, orth, metrics, axes,
                                  n_valid, compressed)

    return shard_map(body, mesh=mesh,
                     in_specs=(s["x"], s["mask"], s["latent"], P()),
                     out_specs=P(), check_rep=False)(x, w, z0, lr)


@partial(jax.jit, static_argnums=tuple(range(4, 14)))
def _scan_batched_sharded(xs, w, z0s, lr, objective, method, optimizer,
                          steps, orth, metrics, mesh, axes, n_valid,
                          compressed):
    s = calib_specs(mesh, axes)

    def body(xs_l, w_l, z0s_l, lr_l):
        f = lambda x_l, z_l: _scan_core_sharded(
            x_l, w_l, z_l, lr_l, objective, method, optimizer, steps, orth,
            metrics, axes, n_valid, compressed)
        return jax.vmap(f)(xs_l, z0s_l)

    return shard_map(body, mesh=mesh,
                     in_specs=(s["xs"], s["mask"], s["latent"], P()),
                     out_specs=P(), check_rep=False)(xs, w, z0s, lr)


def _pad_tokens(x, k: int, axis: int):
    """Pad the token axis to a multiple of ``k``; returns (x, weights, N)."""
    n = x.shape[axis]
    if n == 0:
        raise ValueError("sharded calibration needs at least one token "
                         f"(got shape {x.shape})")
    pad = -n % k
    w = jnp.ones((n,), x.dtype)
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
        w = jnp.pad(w, ((0, pad),))
    return x, w, n


def _place_sharded(mesh, axes, x, w, z0, lr):
    """device_put engine inputs per the calib_specs rules (no-op reshards for
    activations that arrive pre-distributed from ``capture_activations``)."""
    specs = calib_specs(mesh, axes)
    ns = lambda s: NamedSharding(mesh, s)
    x = jax.device_put(x, ns(specs["xs" if x.ndim == 3 else "x"]))
    w = jax.device_put(w, ns(specs["mask"]))
    z0 = jax.device_put(z0, ns(specs["latent"]))
    lr = jax.device_put(lr, ns(P()))
    return x, w, z0, lr


def _norm_metrics(metrics) -> Tuple:
    if not metrics:
        return ()
    if isinstance(metrics, dict):
        return tuple(sorted(metrics.items()))
    return tuple(metrics)


def calibrate_scan(x: jax.Array, z0: jax.Array, objective: Callable, *,
                   method: str = "qr", optimizer: str = "sgd",
                   steps: int = 100, lr: float = 2e-3, orth: str = "cholqr",
                   metrics=(), mesh=None, data_axes=None,
                   compressed_grads: bool = False,
                   obs=None, site: Optional[str] = None) -> CalibResult:
    """Fully-jitted calibration of one rotation site.

    x [N, n] activations, z0 [n, n] latent init (rotation init for Cayley).
    Compiles once per (shapes, objective, method, optimizer, steps, orth,
    metrics) — ``lr`` is traced, so sweeping it does not retrigger
    compilation.  See the module docstring for the loss-history contract.

    ``lr`` and all latent/optimizer math live in ``z0``'s dtype (f32 even for
    bf16/fp16 activations); the rotation is cast to ``x.dtype`` only at the
    ``x @ R`` product.

    With ``obs=`` (a ``repro.obs.Obs``) the loss/metric histories stream
    into its registry under ``site=`` labels (plus one ``calib_site`` span
    when tracing); ``obs=None`` publishes nothing.

    With ``mesh=``, the token axis shards over the mesh's data group
    (``data_axes`` overrides which axes; default = every non-'model' axis)
    and loss/gradient psum per step — see "Token-sharded calibration" in the
    module docstring.  ``compressed_grads`` routes the gradient psum through
    the int8 error-feedback collective.
    """
    lr_a = jnp.asarray(lr, z0.dtype)
    if mesh is None:
        res = _scan_one(x, z0, lr_a, objective, method, optimizer, steps,
                        orth, _norm_metrics(metrics))
    else:
        axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
        x, w, n_valid = _pad_tokens(x, calib_group_size(mesh, axes), axis=0)
        x, w, z0, lr_a = _place_sharded(mesh, axes, x, w, z0, lr_a)
        res = _scan_one_sharded(x, w, z0, lr_a, objective, method, optimizer,
                                steps, orth, _norm_metrics(metrics), mesh,
                                axes, n_valid, bool(compressed_grads))
    if obs is not None:
        from repro.obs import record_calibration
        record_calibration(obs, site or "rotation", res.loss_history,
                           aux=res.aux)
    return res


def calibrate_rotations_batched(xs: jax.Array, z0s: jax.Array,
                                objective: Callable, *, method: str = "qr",
                                optimizer: str = "sgd", steps: int = 100,
                                lr: float = 2e-3, orth: str = "cholqr",
                                metrics=(), mesh=None, data_axes=None,
                                compressed_grads: bool = False,
                                obs=None,
                                site: Optional[str] = None) -> CalibResult:
    """Optimize all L sites of xs [L, N, n] in ONE compiled vmapped scan.

    Replaces ``calibrate_model``'s serial per-layer R2 loop: one jit entry,
    one compilation, batched matmuls across sites.  Results carry a leading
    L axis; per-site trajectories are independent (no cross-site coupling).

    With ``mesh=``, the token axis (axis 1) shards over the mesh's data group
    and the L site axis replicates — same contract as ``calibrate_scan``.
    """
    assert xs.ndim == 3 and z0s.ndim == 3 and xs.shape[0] == z0s.shape[0], \
        (xs.shape, z0s.shape)
    lr_a = jnp.asarray(lr, z0s.dtype)
    if mesh is None:
        res = _scan_batched(xs, z0s, lr_a, objective, method, optimizer,
                            steps, orth, _norm_metrics(metrics))
    else:
        axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
        xs, w, n_valid = _pad_tokens(xs, calib_group_size(mesh, axes), axis=1)
        xs, w, z0s, lr_a = _place_sharded(mesh, axes, xs, w, z0s, lr_a)
        res = _scan_batched_sharded(xs, w, z0s, lr_a, objective, method,
                                    optimizer, steps, orth,
                                    _norm_metrics(metrics), mesh, axes,
                                    n_valid, bool(compressed_grads))
    if obs is not None:
        from repro.obs import record_calibration
        record_calibration(obs, site or "rotation", res.loss_history,
                           aux=res.aux)
    return res


def sharded_scan_contract(mesh, objective: Callable, *, steps: int = 2,
                          n: int = 16, metrics=(), data_axes=None,
                          name: str = "calib/sharded-scan-collectives"):
    """The token-sharded calibration scan's collective contract, declared
    at the seam that owns the psums (``_scan_core_sharded``): every
    optimization step reduces exactly one loss partial, one partial per
    metric, and one latent gradient over the data axes — ``2 + len(metrics)``
    structural psum equations, all inside the scan body — and never gathers
    (latents stay replicated by construction; a gather would mean a shard
    stopped trusting that).

    The psum placement is structural, so the trace is valid on any mesh —
    including a single-device one, which is how the CI gate checks it
    without virtual devices.  The compressed-gradient path routes its psum
    through ``psum_compressed`` (different equation mix) and declares no
    census here.
    """
    from repro.analysis.rules import CollectiveCensus, Contract
    metrics = _norm_metrics(metrics)
    axes = tuple(data_axes) if data_axes else calib_data_axes(mesh)
    k = calib_group_size(mesh, axes)

    def trace():
        x = jnp.ones((4 * k, n), jnp.float32)
        z0 = jnp.eye(n, dtype=jnp.float32)
        x, w, n_valid = _pad_tokens(x, k, axis=0)
        x, w, z0, lr = _place_sharded(mesh, axes, x, w, z0,
                                      jnp.asarray(1e-2, jnp.float32))
        return jax.make_jaxpr(
            lambda x_, w_, z_, lr_: _scan_one_sharded(
                x_, w_, z_, lr_, objective, "qr", "sgd", steps, "cholqr",
                metrics, mesh, axes, n_valid, False))(x, w, z0, lr)

    return Contract(
        name=name, owner="repro.core.qr_orth",
        checks=(CollectiveCensus(
            expect={"psum": 2 + len(metrics)},
            forbid=("all_gather", "all_to_all"),
            require_in_scan=True),),
        trace=trace,
        description="loss + per-metric + gradient psums per calibration "
                    "step, inside the scan body; no gathers")


# --------------------------------------------------------------------------- #
# Compatibility shims (legacy signatures, scanned engine underneath)
# --------------------------------------------------------------------------- #
def _replay(callback, res: CalibResult, p_final):
    """Replay the recorded loss history through a legacy callback.

    The callback receives the FINAL parameters at every step — per-step
    latents are no longer materialized on the host.  Loss values match the
    legacy trace (pre-update loss of step k).
    """
    losses = jax.device_get(res.loss_history)
    for k in range(losses.shape[0]):
        callback(k, float(losses[k]), p_final)


def calibrate_qr(x: jax.Array, z0: jax.Array, objective: Callable,
                 steps: int = 100, lr: float = 2e-3, optimizer: str = "sgd",
                 callback: Optional[Callable] = None,
                 orth: str = "qr") -> jax.Array:
    """Algorithm 1 (legacy API): optimize Z so ``objective(x @ qr(Z).Q)`` drops.

    Returns the final rotation R (Z is discarded, per the paper).  Now a thin
    shim over ``calibrate_scan``; prefer that for loss histories and metrics.
    """
    res = calibrate_scan(x, z0, objective, method="qr", optimizer=optimizer,
                         steps=steps, lr=lr, orth=orth)
    if callback is not None:
        _replay(callback, res, res.rotation)
    return res.rotation


def calibrate_cayley(x: jax.Array, r0: jax.Array, objective: Callable,
                     steps: int = 100, lr: float = 2e-3,
                     callback: Optional[Callable] = None) -> jax.Array:
    """Cayley-SGD baseline (legacy API); scanned engine underneath."""
    res = calibrate_scan(x, r0, objective, method="cayley", steps=steps,
                         lr=lr)
    if callback is not None:
        _replay(callback, res, res.rotation)
    return res.rotation


# --------------------------------------------------------------------------- #
# Legacy host-driven loops — preserved for benchmarks + equivalence tests.
# These re-enter jit every step and recompile per call (fresh closures); that
# cost is exactly what table3_calib_cost measures against.
# --------------------------------------------------------------------------- #
def calibrate_qr_legacy(x: jax.Array, z0: jax.Array, objective: Callable,
                        steps: int = 100, lr: float = 2e-3,
                        optimizer: str = "sgd",
                        callback: Optional[Callable] = None) -> jax.Array:
    def loss_fn(z):
        return objective(x @ qr_rotation(z).astype(x.dtype))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    z = z0
    if optimizer == "adam":
        state = (jnp.zeros_like(z), jnp.zeros_like(z), jnp.zeros((), jnp.int32))
        upd = adam_update
    else:
        state = jnp.zeros_like(z)
        upd = sgd_update
    for k in range(steps):
        loss, g = grad_fn(z)
        z, state = upd(z, state, g, lr)
        if callback is not None:
            callback(k, float(loss), z)
    return qr_rotation(z)


def calibrate_cayley_legacy(x: jax.Array, r0: jax.Array, objective: Callable,
                            steps: int = 100, lr: float = 2e-3,
                            callback: Optional[Callable] = None) -> jax.Array:
    def loss_fn(r):
        return objective(x @ r.astype(x.dtype))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    step = jax.jit(partial(cayley_sgd_step))
    r = r0
    m = jnp.zeros_like(r)
    for k in range(steps):
        loss, g = grad_fn(r)
        r, m = step(r, m, g, lr)
        if callback is not None:
            callback(k, float(loss), r)
    return r


def orthogonality_error(r: jax.Array) -> jax.Array:
    n = r.shape[0]
    return jnp.max(jnp.abs(r @ r.T - jnp.eye(n, dtype=r.dtype)))
