"""Calibration objectives: Whip loss (the paper's) + ablation baselines.

All objectives take the *rotated* activation matrix ``o = x @ R`` of shape
[N_tokens, n] and return a scalar to minimize.  The Whip loss (Eq. 4)::

    Whip(o) = sum_i exp(-|o_i|)

is the CDF-derived Laplace->uniform transform surrogate: it pushes small values
away from zero; rotation norm-invariance then forces outliers inward, driving
each token's distribution toward uniform on [-tau, tau].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def whip(o: jax.Array) -> jax.Array:
    """Paper Eq. 4, averaged over tokens."""
    return jnp.mean(jnp.sum(jnp.exp(-jnp.abs(o)), axis=-1))


def variance(o: jax.Array) -> jax.Array:
    """Per-token variance (paper: ~constant under rotation -> flat objective)."""
    return jnp.mean(jnp.var(o, axis=-1))


def kurtosis(o: jax.Array) -> jax.Array:
    """Per-token kurtosis (tail heaviness; slow objective per paper Fig. 7a)."""
    mu = jnp.mean(o, axis=-1, keepdims=True)
    d = o - mu
    m2 = jnp.mean(d ** 2, axis=-1)
    m4 = jnp.mean(d ** 4, axis=-1)
    return jnp.mean(m4 / (m2 ** 2 + 1e-12))


def _fake_quant_ste(o: jax.Array, bits: int = 4) -> jax.Array:
    """Per-token asymmetric fake quant with straight-through gradients."""
    qmax = 2 ** bits - 1
    lo = jnp.min(o, axis=-1, keepdims=True)
    hi = jnp.max(o, axis=-1, keepdims=True)
    scale = (hi - lo) / qmax
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round((o - lo) / scale), 0, qmax)
    deq = q * scale + lo
    return o + jax.lax.stop_gradient(deq - o)   # STE


def quant_loss(o: jax.Array, bits: int = 4) -> jax.Array:
    """Direct quantization MSE (end-to-end-style objective; flat per Fig. 7a)."""
    deq = _fake_quant_ste(o, bits)
    return jnp.mean(jnp.sum((deq - o) ** 2, axis=-1))


def quant_error(o: jax.Array, bits: int = 4) -> jax.Array:
    """Measurement-only quantization MSE (no STE) — the paper's y-axis."""
    qmax = 2 ** bits - 1
    lo = jnp.min(o, axis=-1, keepdims=True)
    hi = jnp.max(o, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((o - lo) / scale), 0, qmax)
    deq = q * scale + lo
    return jnp.mean(jnp.sum((deq - o) ** 2, axis=-1))


def outlier_count(o: jax.Array, tau: float = None) -> jax.Array:
    """Paper Eq. 1 measurement: #|o_i| > tau (default: 4 sigma)."""
    if tau is None:
        tau = 4.0 * jnp.std(o)
    return jnp.mean(jnp.sum((jnp.abs(o) > tau).astype(jnp.float32), axis=-1))


OBJECTIVES = {
    "whip": whip,
    "variance": variance,
    "kurtosis": kurtosis,
    "quant": quant_loss,
}
