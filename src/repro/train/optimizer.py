"""AdamW + schedules, pure JAX (no optax in this environment).

Optimizer state inherits parameter sharding (ZeRO: m/v shard exactly like the
FSDP-sharded params).  ``opt_state_dtype`` from the config controls m/v
precision (bf16 for the 671B config to fit HBM).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init_opt_state(cfg: ModelConfig, params) -> OptState:
    dt = jnp.dtype(cfg.opt_state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(lambda z: z, zeros))


def cosine_schedule(step, base_lr=3e-4, warmup=100, total=10000,
                    min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(cfg: ModelConfig, params, grads, state: OptState,
                 base_lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
                 warmup=100, total=10000) -> Tuple[dict, OptState]:
    step = state.step + 1
    lr = cosine_schedule(step, base_lr, warmup, total)
    t = step.astype(jnp.float32)
    c1 = 1 - b1 ** t
    c2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        u = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        pf = p.astype(jnp.float32)
        p_new = pf - lr * (u + wd * pf)
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, new_v)
