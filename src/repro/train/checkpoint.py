"""Checkpointing: msgpack+npz save/restore, async writer, mesh resharding.

Format: <dir>/step_<N>/
    manifest.msgpack   — tree structure, shapes, dtypes, step metadata
    arrays.npz         — flat arrays keyed by index

Restore takes an optional (mesh, sharding-tree): arrays are device_put with
the *target* sharding, so a checkpoint written on one mesh restores onto any
other (elastic rescaling: 256 -> 512 chips needs no conversion step).
"""
from __future__ import annotations

import io
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra: Optional[dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {str(i): np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
        "extra": extra or {},
        "time": time.time(),
    }
    (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
    if out.exists():
        import shutil
        shutil.rmtree(out)
    tmp.rename(out)                      # atomic publish
    return out


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.iterdir()
                   if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optional target shardings tree
    (values are jax.sharding.Sharding) reshards onto the current mesh."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves, treedef = _flatten(like)
    new_leaves = []
    sh_leaves = (jax.tree.leaves(shardings,
                                 is_leaf=lambda s: hasattr(s, "device_set"))
                 if shardings is not None else [None] * len(leaves))
    for i, (l, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = data[str(i)]
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jnp.asarray(arr, dtype=l.dtype)
                              if hasattr(l, "dtype") else arr)
    return treedef.unflatten(new_leaves)


class AsyncCheckpointer:
    """Fire-and-forget background writer (training never blocks on disk)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def work():
            save(self.dir, step, host_tree, extra)
            self._gc()

        self.wait()
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.name.startswith("step_"))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
