"""Step builders: train_step / prefill_step / decode_step (+ input specs).

These are the functions the launcher jits; the dry-run lowers them for every
(arch x shape x mesh) cell with ShapeDtypeStruct stand-ins.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model as M
from repro.models.common import NO_SHARD
from repro.quant import context as qctx
from repro.train.optimizer import (OptState, adamw_update, clip_by_global_norm,
                                   init_opt_state)


def build_train_step(cfg: ModelConfig, mesh=None, shd=NO_SHARD, rot=None,
                     grad_accum: int = 1, max_grad_norm: float = 1.0,
                     lr: float = 3e-4, param_specs=None):
    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch, shd=shd, mesh=mesh, rot=rot)

    def constrain_like_params(tree):
        # CRITICAL at scale: without this the f32 grad-accumulation buffer is
        # replicated by SPMD, forcing a full all-reduce per microbatch
        # (§Perf: 1 TiB -> 65 GiB on yi-34b).  Pin it to the param sharding.
        if mesh is None or param_specs is None:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)), tree, param_specs)

    # grad-accumulation buffer dtype follows the optimizer-state dtype:
    # bf16 for the fully-sharded giants halves both the buffer and the
    # per-microbatch gradient-reduction payload (§Perf cell A).
    acc_dt = jnp.dtype(cfg.opt_state_dtype)

    def train_step(params, opt_state: OptState, batch):
        if grad_accum > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, mets), g = jax.value_and_grad(loss_of, has_aux=True)(
                    params, mb)
                # constrain raw grads FIRST: turns the per-micro gradient
                # all-reduce into reduce-scatter onto the param shards
                g = constrain_like_params(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + (b / grad_accum).astype(acc_dt),
                    g_acc, g)
                return (g_acc, l_acc + l / grad_accum), None
            zeros = constrain_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        grads, gn = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = adamw_update(cfg, params, grads, opt_state,
                                         base_lr=lr)
        metrics["grad_norm"] = gn
        return params, opt_state, metrics

    return train_step


def build_prefill(cfg: ModelConfig, mesh=None, shd=NO_SHARD, rot=None,
                  act_quant=None):
    """``act_quant``: per-linear activation hook, threaded explicitly so the
    quant context is active while jit *traces* the step (a global set/clear
    around ``jax.jit(...)`` construction never fires — tracing is lazy)."""
    def prefill_step(params, tokens, frames=None):
        with qctx.act_quant(act_quant):
            return M.prefill(cfg, params, tokens, frames=frames, shd=shd,
                             mesh=mesh, rot=rot)
    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh=None, shd=NO_SHARD, rot=None,
                      act_quant=None):
    def decode_step(params, token, cache, pos):
        with qctx.act_quant(act_quant):
            return M.decode_step(cfg, params, token, cache, pos, shd=shd,
                                 mesh=mesh, rot=rot)
    return decode_step


def build_paged_prefill_chunk(cfg: ModelConfig, mesh=None, shd=NO_SHARD,
                              rot=None, act_quant=None, kv_bits: int = 4,
                              state_bits: int = 8, tp_plan=None):
    def prefill_chunk(params, tokens, pool, block_table, start, carry,
                      chunk_len, n_pages):
        # n_pages is static (jit specializes per covered-page count): only the
        # page prefix holding [0, start+C) is gathered for chunk attention.
        # ``carry`` threads fp32 recurrent state (SSM/hybrid) across chunks;
        # ``chunk_len`` masks chunk padding out of the recurrence.
        with qctx.act_quant(act_quant):
            return M.paged_prefill_chunk(cfg, params, tokens, pool,
                                         block_table, start, carry=carry,
                                         chunk_len=chunk_len,
                                         shd=shd, mesh=mesh, rot=rot,
                                         kv_bits=kv_bits,
                                         state_bits=state_bits,
                                         n_pages=n_pages, tp_plan=tp_plan)
    return prefill_chunk


def build_paged_decode_step(cfg: ModelConfig, mesh=None, shd=NO_SHARD,
                            rot=None, act_quant=None, kv_bits: int = 4,
                            state_bits: int = 8, tp_plan=None):
    def decode_step(params, token, pool, block_tables, positions, lengths,
                    state_slots):
        with qctx.act_quant(act_quant):
            return M.paged_decode_step(cfg, params, token, pool, block_tables,
                                       positions, lengths,
                                       state_slots=state_slots, shd=shd,
                                       mesh=mesh, rot=rot, kv_bits=kv_bits,
                                       state_bits=state_bits, tp_plan=tp_plan)
    return decode_step


def build_paged_commit(cfg: ModelConfig, kv_bits: int = 4,
                       state_bits: int = 8):
    """Prefill->decode handoff: quantize the fp32 carry into its state slot."""
    def commit(pool, carry, phys_slot):
        return M.commit_prefill_state(cfg, pool, carry, phys_slot,
                                      kv_bits=kv_bits, state_bits=state_bits)
    return commit


def build_paged_init_slot(cfg: ModelConfig, kv_bits: int = 4,
                          state_bits: int = 8):
    """Zero a physical state slot at admission (pages need no reset)."""
    def init_slot(pool, phys_slot):
        return M.init_pool_slot(cfg, pool, phys_slot, kv_bits=kv_bits,
                                state_bits=state_bits)
    return init_slot


def build_paged_copy_page(cfg: ModelConfig, kv_bits: int = 4,
                          state_bits: int = 8):
    """Device copy-on-write: duplicate one physical page across every
    page-bearing adapter sub-state (src/dst are traced scalars, so one
    compiled program serves every CoW admission)."""
    def copy_page(pool, src, dst):
        return M.copy_pool_page(cfg, pool, src, dst, kv_bits=kv_bits,
                                state_bits=state_bits)
    return copy_page


# --------------------------------------------------------------------------- #
# ShapeDtypeStruct stand-ins (no allocation) per shape cell
# --------------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, cell: ShapeCell, cache_dtype=jnp.bfloat16):
    """Returns (kind, kwargs-of-ShapeDtypeStructs) for the step function."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return batch
    if cell.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encoder_decoder:
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
        return out
    # decode: one new token against a cache of length S
    cache = jax.eval_shape(partial(M.make_cache, cfg, B, S, cache_dtype))
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def opt_shape(cfg: ModelConfig, params_sds):
    return jax.eval_shape(partial(init_opt_state, cfg), params_sds)
