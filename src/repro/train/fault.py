"""Fault tolerance + straggler mitigation for the training loop.

``FaultTolerantLoop`` wraps the per-step call: on any exception (device loss,
preemption signal, injected fault) it restores the latest checkpoint and
resumes — the trainer's state is always reconstructible from (ckpt, data
seed, step).  ``StragglerMonitor`` keeps an EMA of step times and flags
outliers; at scale the hook triggers re-slicing / hot-spare swap — here it
records and (optionally) skips the slow step's non-critical work.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass
class StragglerMonitor:
    threshold: float = 3.0        # x EMA counts as straggler
    ema: float = 0.0
    beta: float = 0.9
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema > 0 and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        # slow steps don't poison the EMA
        self.ema = (self.beta * self.ema + (1 - self.beta) * dt
                    if self.ema > 0 else dt) if not is_straggler else self.ema
        return is_straggler


class FaultTolerantLoop:
    """Run steps with restore-on-failure semantics.

    fn(state, batch) -> state  may raise; restore_fn() -> state reloads the
    last durable checkpoint.  ``max_retries`` bounds consecutive failures
    (a real cluster would also re-admit replacement hosts here).
    """

    def __init__(self, step_fn: Callable, restore_fn: Callable,
                 max_retries: int = 3,
                 monitor: Optional[StragglerMonitor] = None,
                 on_fault: Optional[Callable] = None):
        self.step_fn = step_fn
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.monitor = monitor or StragglerMonitor()
        self.on_fault = on_fault
        self.faults: List[dict] = []

    def run(self, state, batches, n_steps: int, start_step: int = 0):
        step = start_step
        it = iter(batches)
        retries = 0
        while step < n_steps:
            batch = next(it)
            t0 = time.perf_counter()
            try:
                state = self.step_fn(state, batch)
                retries = 0
            except Exception as e:       # noqa: BLE001 — fault boundary
                self.faults.append({"step": step, "error": repr(e)})
                if self.on_fault is not None:
                    self.on_fault(step, e)
                retries += 1
                if retries > self.max_retries:
                    raise
                state = self.restore_fn()
                continue                 # retry the step from restored state
            self.monitor.observe(step, time.perf_counter() - t0)
            step += 1
        return state, step
