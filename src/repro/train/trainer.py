"""Trainer: jit'd step + data prefetch + async checkpoints + fault tolerance.

Runs anywhere from 1 CPU device (tests, examples) to the production mesh
(launch/train.py): the mesh/sharding objects are injected, the loop logic is
identical.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import Prefetcher, batches
from repro.models import model as M
from repro.models.common import NO_SHARD
from repro.train import steps as S
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.train.fault import FaultTolerantLoop, StragglerMonitor
from repro.train.optimizer import init_opt_state


class Trainer:
    def __init__(self, cfg: ModelConfig, batch_size: int = 8,
                 seq_len: int = 64, lr: float = 3e-3, mesh=None, shd=NO_SHARD,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 grad_accum: int = 1, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.mesh = mesh
        self.ckpt_dir = Path(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        key = jax.random.PRNGKey(seed)
        self.params = M.init_params(cfg, key)
        self.opt_state = init_opt_state(cfg, self.params)
        self.step_fn = jax.jit(S.build_train_step(
            cfg, mesh=mesh, shd=shd, grad_accum=grad_accum, lr=lr))
        self.data = batches(cfg, batch_size, seq_len, seed=seed)
        self.ckpt = (AsyncCheckpointer(self.ckpt_dir)
                     if self.ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.step = 0
        self.history: list = []

    # ------------------------------------------------------------------ core
    def _one_step(self, state, batch):
        params, opt_state = state
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, metrics = self.step_fn(params, opt_state, batch)
        self._last_metrics = jax.tree.map(float, metrics)
        return params, opt_state

    def _restore_latest(self):
        step = latest_step(self.ckpt_dir)
        assert step is not None, "fault before first checkpoint"
        params = restore(self.ckpt_dir, step, self.params)
        opt = restore(self.ckpt_dir / "opt", step, self.opt_state)
        self.step = step
        return params, opt

    def train(self, n_steps: int, log_every: int = 10,
              fault_hook=None, verbose: bool = True):
        state = (self.params, self.opt_state)
        loop = FaultTolerantLoop(
            step_fn=(fault_hook or (lambda s, b: self._one_step(s, b))),
            restore_fn=self._restore_latest, monitor=self.monitor)

        it = iter(self.data)
        t0 = time.perf_counter()
        while self.step < n_steps:
            n_chunk = min(self.ckpt_every if self.ckpt else log_every,
                          n_steps - self.step)
            state, self.step = loop.run(state, it, self.step + n_chunk,
                                        start_step=self.step)
            self.params, self.opt_state = state
            m = dict(self._last_metrics)
            m["step"] = self.step
            self.history.append(m)
            if verbose and (self.step % log_every == 0
                            or self.step >= n_steps):
                dt = time.perf_counter() - t0
                print(f"step {self.step:5d} loss {m['loss']:.4f} "
                      f"({dt:.1f}s)", flush=True)
            if self.ckpt:
                self.ckpt.save(self.step, self.params)     # async
                from repro.train.checkpoint import save as _save
                _save(self.ckpt_dir / "opt", self.step, self.opt_state)
                self.ckpt.wait()
        return self.history
