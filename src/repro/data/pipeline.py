"""Data pipeline: synthetic Markov LM corpus + shard-aware batching.

A fixed random Markov chain over the vocab gives a low-entropy "language" a
tiny model can visibly learn in a few hundred CPU steps (train-loss tests,
examples) while exercising the full pipeline: tokenize -> pack -> shard ->
prefetch.  The calibration sampler draws the paper's 128x2048-style batches.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class MarkovCorpus:
    """Order-1 Markov chain with temperature-controlled entropy."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 4):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        # each token transitions to `branching` likely successors
        self.next_tokens = rng.integers(0, vocab_size,
                                        size=(vocab_size, branching))
        self.rng = rng

    def sample(self, batch: int, seq_len: int, rng=None) -> np.ndarray:
        rng = rng or self.rng
        out = np.empty((batch, seq_len + 1), np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq_len + 1):
            choice = rng.integers(0, self.next_tokens.shape[1], size=batch)
            cur = self.next_tokens[cur, choice]
            # small amount of noise keeps the task non-trivial
            noise = rng.random(batch) < 0.05
            cur = np.where(noise, rng.integers(0, self.vocab, size=batch), cur)
            out[:, t] = cur
        return out


def batches(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0,
            frames: bool = False, corpus_seed: int = 0) -> Iterator[dict]:
    """Infinite iterator of {'tokens','labels'(,'frames')} numpy batches.

    ``seed`` varies the SAMPLING stream; ``corpus_seed`` fixes the language
    itself (train and eval must share it)."""
    corpus = MarkovCorpus(cfg.vocab_size, seed=corpus_seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        chunk = corpus.sample(batch, seq_len, rng)
        b = {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
        if frames or cfg.is_encoder_decoder:
            b["frames"] = rng.standard_normal(
                (batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        yield b


class Prefetcher:
    """Background-thread prefetch of the next N batches (host->device overlap)."""

    def __init__(self, it: Iterator[dict], depth: int = 2, sharding=None):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.sharding = sharding
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        for b in self.it:
            if self._stop.is_set():
                return
            if self.sharding is not None:
                b = jax.tree.map(
                    lambda x, s=self.sharding: jax.device_put(x, s), b)
            self.q.put(b)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass


def calibration_batch(cfg: ModelConfig, n_samples: int = 8,
                      seq_len: int = 256, seed: int = 0,
                      corpus_seed: int = 0) -> np.ndarray:
    """Paper-style calibration set (defaults scaled to CPU).  ``seed`` draws
    different samples from the same corpus (Tab. 16); ``corpus_seed`` swaps
    the corpus itself (Tab. 5)."""
    c = MarkovCorpus(cfg.vocab_size, seed=corpus_seed)
    c.rng = np.random.default_rng(seed + 1000)
    return c.sample(n_samples, seq_len)[:, :-1]
