"""Artifact I/O: one manifest.json + one flat weights.bin per artifact.

``save_artifact`` writes tensors back-to-back (64-byte aligned) into a single
blob; ``load_artifact`` memory-maps the blob and hands out zero-copy views —
no per-tensor file opens, no deserialization copies.  Manifest hashes are
verified on load by default (format invariant: a corrupted artifact never
serves).

Tensor-parallel cold boot rides the same views: the serve TP loader
(``repro.dist.sharding.place_serve_params``) feeds each mmap view through
``jax.make_array_from_callback``, so every device copies ONLY its own block
out of the blob — a big packed artifact boots onto an N-way mesh without any
host or device ever materializing a full projection weight.  The per-tensor
64-byte alignment (``ALIGN``) is what keeps those per-shard reads free:
every leaf starts on its own cache line / page-aligned stride, so a shard
slice never drags in another tensor's bytes.  ``leaf_alignment`` is the
introspection hook the TP tests assert this contract with.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.artifacts.format import (FORMAT_VERSION, QuantArtifact,
                                    config_from_dict, config_to_dict)
from repro.artifacts.manifest import (ALIGN, build_manifest, flatten_tree,
                                      unflatten_tree, verify_manifest)

MANIFEST = "manifest.json"
WEIGHTS = "weights.bin"


class ArtifactError(RuntimeError):
    pass


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                      # bfloat16 & friends
        return np.dtype(getattr(ml_dtypes, name))


def save_artifact(path: str, artifact: QuantArtifact) -> dict:
    """Serialize a QuantArtifact into directory ``path``; returns the
    manifest dict that was written."""
    os.makedirs(path, exist_ok=True)
    spec, tensors = flatten_tree(artifact.params)
    entries = build_manifest(tensors)
    with open(os.path.join(path, WEIGHTS), "wb") as f:
        for e, a in zip(entries, tensors):
            pad = e["offset"] - f.tell()
            if pad:
                f.write(b"\0" * pad)
            f.write(a.view(np.uint8).reshape(-1).data)
    manifest = {
        "format_version": FORMAT_VERSION,
        "model_config": config_to_dict(artifact.cfg),
        "rotations": dict(artifact.rotations),
        "meta": dict(artifact.meta),
        "tree": spec,
        "tensors": entries,
    }
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def leaf_alignment(manifest: dict) -> dict:
    """name -> (offset, nbytes, offset % ALIGN) for every stored tensor.

    The serve-TP contract requires every entry's third element to be 0:
    shard-wise artifact reads are only zero-waste when each tensor starts on
    its own ``ALIGN`` boundary."""
    return {e["name"]: (e["offset"], e["nbytes"], e["offset"] % ALIGN)
            for e in manifest["tensors"]}


def load_artifact(path: str, mmap: bool = True,
                  verify: bool = True) -> QuantArtifact:
    """Load an artifact directory; tensors are zero-copy views into the
    memory-mapped blob (``mmap=False`` reads it into RAM instead).

    ``verify`` asserts every tensor's sha256 against the manifest.
    """
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ArtifactError(f"unreadable artifact at {path}: {e}") from e
    if manifest.get("format_version") != FORMAT_VERSION:
        raise ArtifactError(
            f"artifact format {manifest.get('format_version')} != "
            f"{FORMAT_VERSION}")
    blob_path = os.path.join(path, WEIGHTS)
    if mmap:
        blob = np.memmap(blob_path, dtype=np.uint8, mode="r")
    else:
        blob = np.fromfile(blob_path, dtype=np.uint8)
    entries = manifest["tensors"]
    tensors = []
    for e in entries:
        end = e["offset"] + e["nbytes"]
        if end > blob.size:
            raise ArtifactError(f"{e['name']}: blob truncated "
                                f"({blob.size} < {end} bytes)")
        view = blob[e["offset"]:end].view(_np_dtype(e["dtype"]))
        tensors.append(view.reshape(e["shape"]))
    if verify:
        try:
            verify_manifest(entries, tensors)
        except ValueError as e:
            raise ArtifactError(str(e)) from e
    params = unflatten_tree(manifest["tree"], tensors)
    return QuantArtifact(cfg=config_from_dict(manifest["model_config"]),
                         params=params,
                         rotations=manifest.get("rotations", {}),
                         meta=manifest.get("meta", {}),
                         manifest=manifest)
