"""Artifact manifest: tree spec + tensor table with content hashes.

The param pytree is walked explicitly (dicts, ``QTensor`` nodes, arrays,
None) into a JSON tree spec referencing a flat tensor list; the tensor table
records shape/dtype/offset/sha256 per entry.  Hashes are asserted on every
load — a truncated or bit-flipped artifact can never serve.
"""
from __future__ import annotations

import hashlib
from typing import List, Tuple

import numpy as np

from repro.quant.quantizers import QTensor

ALIGN = 64          # tensor offsets in weights.bin are 64-byte aligned


def tensor_sha256(a) -> str:
    a = np.ascontiguousarray(np.asarray(a))
    return hashlib.sha256(a.view(np.uint8).reshape(-1).data).hexdigest()


def flatten_tree(tree) -> Tuple[dict, List[np.ndarray]]:
    """-> (json-able tree spec, tensor list in reference order)."""
    tensors: List[np.ndarray] = []

    def ref(a) -> int:
        tensors.append(np.ascontiguousarray(np.asarray(a)))
        return len(tensors) - 1

    def walk(node):
        if isinstance(node, QTensor):
            return {"kind": "qtensor", "bits": node.bits, "group": node.group,
                    "in_features": node.in_features, "packed": node.packed,
                    "q": ref(node.q), "scale": ref(node.scale),
                    "zero": None if node.zero is None else ref(node.zero)}
        if isinstance(node, dict):
            return {"kind": "dict",
                    "items": {k: walk(node[k]) for k in sorted(node)}}
        if node is None:
            return {"kind": "none"}
        return {"kind": "array", "tensor": ref(node)}

    return walk(tree), tensors


def unflatten_tree(spec: dict, tensors: List[np.ndarray]):
    kind = spec["kind"]
    if kind == "qtensor":
        zero = spec["zero"]
        return QTensor(tensors[spec["q"]], tensors[spec["scale"]],
                       None if zero is None else tensors[zero],
                       bits=spec["bits"], group=spec["group"],
                       in_features=spec["in_features"], packed=spec["packed"])
    if kind == "dict":
        return {k: unflatten_tree(v, tensors)
                for k, v in spec["items"].items()}
    if kind == "none":
        return None
    return tensors[spec["tensor"]]


def build_manifest(tensors: List[np.ndarray]) -> List[dict]:
    """Tensor table with aligned offsets into the flat weights blob."""
    entries, offset = [], 0
    for i, a in enumerate(tensors):
        offset = -(-offset // ALIGN) * ALIGN
        entries.append({
            "name": f"t{i}",
            "offset": offset,
            "nbytes": int(a.nbytes),
            "shape": list(a.shape),
            "dtype": a.dtype.name,
            "sha256": tensor_sha256(a),
        })
        offset += int(a.nbytes)
    return entries


def verify_manifest(entries: List[dict], tensors: List[np.ndarray]) -> None:
    """Assert shapes/dtypes/hashes of loaded tensors against the manifest."""
    if len(entries) != len(tensors):
        raise ValueError(f"manifest lists {len(entries)} tensors, "
                         f"blob decoded {len(tensors)}")
    for e, a in zip(entries, tensors):
        if list(a.shape) != e["shape"] or a.dtype.name != e["dtype"]:
            raise ValueError(f"{e['name']}: shape/dtype mismatch "
                             f"({a.shape}/{a.dtype.name} vs manifest)")
        got = tensor_sha256(a)
        if got != e["sha256"]:
            raise ValueError(f"{e['name']}: sha256 mismatch — artifact "
                             "corrupted or truncated")
