"""QuantArtifact: the serialized quantize-once deployment unit.

An artifact is everything serving needs and nothing calibration needs:

  * ``params``     — model pytree with projection weights as packed
                     ``QTensor``s (int4 nibbles / int8 codes + fp16 group or
                     per-channel scales); norms/embeddings stay dense.
  * ``rotations``  — fused-rotation metadata: R1/R2 are already folded into
                     the weights (recorded as ``"fused"``), R3/R4 are online
                     Hadamard specs resolved to the Pallas WHT kernel at boot.
  * ``cfg``        — the *fused* ModelConfig snapshot (norm conversion, quant
                     settings) so the engine needs no source-of-truth lookup.
  * manifest       — per-tensor shapes/dtypes/offsets/sha256, asserted on load.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import ModelConfig, QuantConfig

FORMAT_VERSION = 1


@dataclass
class QuantArtifact:
    cfg: ModelConfig
    params: dict
    rotations: Dict[str, Optional[str]] = field(default_factory=dict)
    meta: dict = field(default_factory=dict)
    manifest: Optional[dict] = None


def rotation_spec(pack: dict) -> Dict[str, Optional[str]]:
    """Fused-rotation metadata for a calibration pack that has just been
    folded into the weights: R1/R2 carry no runtime work, R3/R4 run as
    online Hadamards."""
    return {
        "r1": "fused" if pack.get("r1") is not None else None,
        "r2": "fused" if (pack.get("r2") is not None
                          or pack.get("r2_shared") is not None) else None,
        "r3": "hadamard",
        "r4": "hadamard" if pack.get("r4") is not None else None,
    }


def resolve_rotations(rotations: Dict[str, Optional[str]]) -> dict:
    """Build the serve-time rot-context hooks from artifact metadata.

    Only online sites materialize hooks; ``"fused"`` sites are already in the
    weights.  The Pallas WHT kernel is the Hadamard implementation (TPU fast
    path; interpret mode elsewhere).
    """
    from repro.kernels.hadamard.ops import online_hadamard
    rot = {}
    for site in ("r3", "r4"):
        kind = rotations.get(site)
        if kind is None or kind == "fused":
            continue
        if kind != "hadamard":
            raise ValueError(f"unknown online rotation {site}={kind!r}")
        rot[site] = online_hadamard
    return rot


def config_to_dict(cfg: ModelConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["skip_shapes"] = list(d["skip_shapes"])
    return d


def config_from_dict(d: dict) -> ModelConfig:
    d = dict(d)
    qc = QuantConfig(**d.pop("quant"))
    d["skip_shapes"] = tuple(d.get("skip_shapes", ()))
    return ModelConfig(quant=qc, **d)
