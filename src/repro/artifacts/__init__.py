"""Quantize-once artifact pipeline (deployment half of DartQuant).

Calibration runs once (``repro.launch.quantize``); serving cold-boots from a
serialized ``QuantArtifact`` — packed integer weights, fused-rotation
metadata, config snapshot, hash-verified manifest — without touching the
calibration stack.
"""
from repro.artifacts.format import (QuantArtifact, config_from_dict,
                                    config_to_dict, resolve_rotations,
                                    rotation_spec)
from repro.artifacts.io import ArtifactError, load_artifact, save_artifact
from repro.artifacts.manifest import (build_manifest, flatten_tree,
                                      tensor_sha256, unflatten_tree,
                                      verify_manifest)
