"""Pure-jnp oracle for the fused X@R + Whip loss (calibration hot loop)."""
from __future__ import annotations

import jax.numpy as jnp


def whip_rotate_ref(x, r):
    """Returns scalar: mean_t sum_i exp(-|x_t @ R|_i)."""
    o = x.astype(jnp.float32) @ r.astype(jnp.float32)
    return jnp.mean(jnp.sum(jnp.exp(-jnp.abs(o)), axis=-1))


def whip_rotate_grad_ref(x, r):
    """dWhip/dR = X^T (-sign(O) exp(-|O|)) / N  — closed form."""
    xf = x.astype(jnp.float32)
    o = xf @ r.astype(jnp.float32)
    g_o = -jnp.sign(o) * jnp.exp(-jnp.abs(o)) / x.shape[0]
    return xf.T @ g_o
