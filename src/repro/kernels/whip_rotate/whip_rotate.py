"""Pallas TPU kernel: fused X@R + Whip loss value and gradient.

The calibration hot loop evaluates ``Whip(X @ R)`` and its gradient wrt R for
X = [tokens, n] with tokens >> n.  Fusing the matmul with the elementwise
exp/abs reduce keeps O = X@R entirely in VMEM (never written to HBM), and the
backward kernel recomputes O per tile to form G_R = X^T (-sign(O) e^{-|O|}).

Forward grid tiles rows; each tile emits a partial loss sum (accumulated on
host side).  Backward accumulates G_R across the grid in the output ref
(sequential TPU grid => safe accumulation).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _whip_fwd_kernel(x_ref, r_ref, part_ref):
    x = x_ref[...].astype(jnp.float32)                     # [bm, n]
    o = jax.lax.dot_general(x, r_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    part_ref[0, 0] = jnp.sum(jnp.exp(-jnp.abs(o)))


def _whip_bwd_kernel(x_ref, r_ref, g_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)
    o = jax.lax.dot_general(x, r_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    g_o = -jnp.sign(o) * jnp.exp(-jnp.abs(o))
    g = jax.lax.dot_general(x, g_o, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = g

    @pl.when(i > 0)
    def _acc():
        g_ref[...] += g


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def whip_fwd_pallas(x, r, block_m: int = 512, interpret: bool = True):
    M, n = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    grid = (M // bm,)
    parts = pl.pallas_call(
        _whip_fwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x, r)
    return jnp.sum(parts) / M


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def whip_bwd_pallas(x, r, block_m: int = 512, interpret: bool = True):
    M, n = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    grid = (M // bm,)
    g = pl.pallas_call(
        _whip_bwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0)),
                  pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, r)
    return g / M
