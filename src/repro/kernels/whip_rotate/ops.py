"""Public wrapper with custom_vjp: drop-in Whip objective backed by Pallas."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.whip_rotate.whip_rotate import whip_bwd_pallas, whip_fwd_pallas


def _block(m: int) -> int:
    bm = 512
    while m % bm and bm > 1:
        bm //= 2
    return bm


@jax.custom_vjp
def whip_rotate(x: jax.Array, r: jax.Array) -> jax.Array:
    """Whip(X @ R), fused. Differentiable wrt r (x treated as data)."""
    return whip_fwd_pallas(x, r, block_m=_block(x.shape[0]),
                           interpret=use_interpret())


def _fwd(x, r):
    return whip_rotate(x, r), (x, r)


def _bwd(res, ct):
    x, r = res
    g_r = whip_bwd_pallas(x, r, block_m=_block(x.shape[0]),
                          interpret=use_interpret())
    return None, (g_r * ct).astype(r.dtype)


whip_rotate.defvjp(_fwd, _bwd)
