"""Shared kernel utilities."""
from __future__ import annotations

import jax

from repro.core.rotations import _is_constructible, hadamard_chain


def use_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def wht_factors(n: int) -> tuple[int, int]:
    """Split the canonical Kronecker chain so H_n == H_a (x) H_b exactly
    (matching hadamard_matrix's recursion) with b near the 128 lane width."""
    chain = hadamard_chain(n)
    if not chain:
        return 1, 1
    b = 1
    i = len(chain)
    while i > 0 and b * chain[i - 1] <= 128:
        i -= 1
        b *= chain[i]
    a = n // b
    if b == 1:          # single factor > 128 (e.g. n prime-ish): whole matrix
        return 1, n
    return a, b


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m
