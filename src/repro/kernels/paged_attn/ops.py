"""Public wrapper: paged decode attention over an int4 page-pool layer slice.

Dispatches to the Pallas kernel (interpret mode off-TPU, like the other
kernels); ``paged_attention_ref`` stays the parity oracle and is selectable
via ``impl="ref"`` for A/B testing.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.paged_attn.paged_attn import paged_attn_pallas
from repro.kernels.paged_attn.ref import paged_attention_ref


def paged_attention(q: jax.Array, pool_l: Dict[str, jax.Array],
                    block_tables: jax.Array, lengths: jax.Array, *,
                    bits: int = 4, window=0, logit_cap: float = 0.0,
                    scale: Optional[float] = None,
                    impl: str = "pallas") -> jax.Array:
    """q [B,Hq,hd]; pool_l {kq,ks,kz,vq,vs,vz} [P,T,H,...]; lengths [B].

    ``window`` may be a traced int32 scalar (per-layer local/global patterns);
    it is folded into a per-sequence start offset so the kernel only ever
    masks on [start, length).
    """
    B, Hq, hd = q.shape
    H = pool_l["ks"].shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    if impl == "ref":
        return paged_attention_ref(q, pool_l, block_tables, lengths,
                                   bits=bits, window=window,
                                   logit_cap=logit_cap, scale=scale)
    win = jnp.asarray(window, jnp.int32)
    starts = jnp.where(win > 0, jnp.maximum(lengths - win, 0), 0) \
        .astype(jnp.int32)
    return paged_attn_pallas(
        q, pool_l["kq"], pool_l["ks"], pool_l["kz"],
        pool_l["vq"], pool_l["vs"], pool_l["vz"],
        block_tables.astype(jnp.int32), starts, lengths.astype(jnp.int32),
        bits=bits, hd=hd, groups=Hq // H, scale=float(scale),
        logit_cap=float(logit_cap), interpret=use_interpret())
