"""Public wrappers: paged decode attention over a page-pool layer slice.

``paged_attention`` (GQA KV pages) and ``paged_mla_attention`` (MLA latent
pages) dispatch to the Pallas kernels (interpret mode off-TPU, like the other
kernels); the ``ref`` oracles stay the parity references and are selectable
via ``impl="ref"`` for A/B testing.  ``bits=16`` pools store raw fp16 pages
(the compat layout the demoted lockstep engine serves through) and always
take the dense-gather path — correctness over speed on the compat route.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.paged_attn.paged_attn import (paged_attn_pallas,
                                                 paged_mla_attn_pallas)
from repro.kernels.paged_attn.ref import (paged_attention_ref,
                                          paged_mla_attention_ref)


def paged_attention(q: jax.Array, pool_l: Dict[str, jax.Array],
                    block_tables: jax.Array, lengths: jax.Array, *,
                    bits: int = 4, window=0, logit_cap: float = 0.0,
                    scale: Optional[float] = None,
                    impl: str = "pallas") -> jax.Array:
    """q [B,Hq,hd]; pool_l {kq,ks,kz,vq,vs,vz} [P,T,H,...] (or {k,v} fp16 at
    bits=16); lengths [B].

    ``window`` may be a traced int32 scalar (per-layer local/global patterns);
    it is folded into a per-sequence start offset so the kernel only ever
    masks on [start, length).
    """
    B, Hq, hd = q.shape
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    if impl == "ref" or bits >= 16:
        return paged_attention_ref(q, pool_l, block_tables, lengths,
                                   bits=bits, window=window,
                                   logit_cap=logit_cap, scale=scale)
    H = pool_l["ks"].shape[2]
    win = jnp.asarray(window, jnp.int32)
    starts = jnp.where(win > 0, jnp.maximum(lengths - win, 0), 0) \
        .astype(jnp.int32)
    return paged_attn_pallas(
        q, pool_l["kq"], pool_l["ks"], pool_l["kz"],
        pool_l["vq"], pool_l["vs"], pool_l["vz"],
        block_tables.astype(jnp.int32), starts, lengths.astype(jnp.int32),
        bits=bits, hd=hd, groups=Hq // H, scale=float(scale),
        logit_cap=float(logit_cap), interpret=use_interpret())


def paged_mla_attention(q_lat: jax.Array, q_rope: jax.Array,
                        pool_l: Dict[str, jax.Array],
                        block_tables: jax.Array, lengths: jax.Array, *,
                        scale: float, bits: int = 4,
                        impl: str = "pallas") -> jax.Array:
    """Absorbed-MLA paged decode: q_lat [B,h,kvlr], q_rope [B,h,r];
    pool_l {cq,cs,cz,rq,rs,rz} [P,T,...] (or {ckv,krope} fp16 at bits=16);
    lengths [B] -> o_lat [B,h,kvlr].

    ``scale`` is required: the model's MLA softmax scale is
    1/sqrt(qk_nope_head_dim + rope), which cannot be derived from the
    absorbed q_lat shape (kvlr != nope) — a guessed default would silently
    diverge from ``mla_decode``.
    """
    B, h, kvlr = q_lat.shape
    rope = q_rope.shape[-1]
    if impl == "ref" or bits >= 16:
        return paged_mla_attention_ref(q_lat, q_rope, pool_l, block_tables,
                                       lengths, bits=bits, scale=scale)
    return paged_mla_attn_pallas(
        q_lat, q_rope, pool_l["cq"], pool_l["cs"], pool_l["cz"],
        pool_l["rq"], pool_l["rs"], pool_l["rz"],
        block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
        bits=bits, kvlr=kvlr, rope=rope, scale=float(scale),
        interpret=use_interpret())
