"""Pure-jnp oracle for paged decode attention over an int4 page pool.

Gathers every logical page of each sequence through its block table,
dequantizes to f32 and runs a masked single-query softmax — the dense
reference the Pallas kernel is tested against, and the fallback path on
backends without the kernel.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import softcap
from repro.quant.kv_cache import QuantKV, dequantize_kv


def dequant_codes(q: jax.Array, s: jax.Array, z: jax.Array, *, bits: int,
                  head_dim: int, dtype=jnp.float32) -> jax.Array:
    """Packed codes [..., pd] + scale/zero [...] -> values [..., head_dim]."""
    return dequantize_kv(QuantKV(q, s[..., None], z[..., None]), bits,
                         dtype, head_dim=head_dim)


def gather_pages(pool_l: Dict[str, jax.Array], block_tables: jax.Array, *,
                 bits: int, head_dim: int, dtype=jnp.float32):
    """pool_l [P,T,H,*]; block_tables [B,Pmax] -> k, v [B,Pmax*T,H,hd].

    ``bits=16`` pools hold raw fp16 under ``k``/``v`` (no codes to dequantize
    — the compat layout the demoted lockstep engine serves through).
    """
    B, Pmax = block_tables.shape
    if bits >= 16:
        T, H = pool_l["k"].shape[1], pool_l["k"].shape[2]
        return (pool_l["k"][block_tables].astype(dtype)
                .reshape(B, Pmax * T, H, head_dim),
                pool_l["v"][block_tables].astype(dtype)
                .reshape(B, Pmax * T, H, head_dim))
    T, H = pool_l["kq"].shape[1], pool_l["kq"].shape[2]

    def flat(codes, s, z):
        g = dequant_codes(codes[block_tables], s[block_tables],
                          z[block_tables], bits=bits, head_dim=head_dim,
                          dtype=dtype)
        return g.reshape(B, Pmax * T, H, head_dim)

    k = flat(pool_l["kq"], pool_l["ks"], pool_l["kz"])
    v = flat(pool_l["vq"], pool_l["vs"], pool_l["vz"])
    return k, v


def gather_latent_pages(pool_l: Dict[str, jax.Array], block_tables: jax.Array,
                        *, bits: int, kv_lora_rank: int, rope_dim: int,
                        dtype=jnp.float32):
    """MLA latent pool [P,T,*] -> c_kv [B,Pmax*T,kvlr], k_rope [B,Pmax*T,r]."""
    B, Pmax = block_tables.shape
    if bits >= 16:
        T = pool_l["ckv"].shape[1]
        return (pool_l["ckv"][block_tables].astype(dtype)
                .reshape(B, Pmax * T, kv_lora_rank),
                pool_l["krope"][block_tables].astype(dtype)
                .reshape(B, Pmax * T, rope_dim))
    T = pool_l["cs"].shape[1]

    def flat(codes, s, z, dim):
        g = dequant_codes(codes[block_tables], s[block_tables],
                          z[block_tables], bits=bits, head_dim=dim,
                          dtype=dtype)
        return g.reshape(B, Pmax * T, dim)

    return (flat(pool_l["cq"], pool_l["cs"], pool_l["cz"], kv_lora_rank),
            flat(pool_l["rq"], pool_l["rs"], pool_l["rz"], rope_dim))


def paged_attention_ref(q: jax.Array, pool_l: Dict[str, jax.Array],
                        block_tables: jax.Array, lengths: jax.Array, *,
                        bits: int = 4, window=0, logit_cap: float = 0.0,
                        scale: Optional[float] = None) -> jax.Array:
    """q [B,Hq,hd]; lengths [B] (valid tokens per seq) -> o [B,Hq,hd]."""
    B, Hq, hd = q.shape
    k, v = gather_pages(pool_l, block_tables, bits=bits, head_dim=hd)
    H = k.shape[2]
    G = Hq // H
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, H, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k)
    if logit_cap:
        s = softcap(s, logit_cap)
    idx = jnp.arange(k.shape[1], dtype=jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    starts = jnp.where(win > 0, jnp.maximum(lengths - win, 0), 0)
    valid = (idx[None, :] >= starts[:, None]) & (idx[None, :] < lengths[:, None])
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    # fully-masked rows (empty slots): uniform p over nothing -> zero output
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgk,bkhd->bhgd", p / denom, v)
    return o.reshape(B, Hq, hd).astype(q.dtype)


def paged_mla_attention_ref(q_lat: jax.Array, q_rope: jax.Array,
                            pool_l: Dict[str, jax.Array],
                            block_tables: jax.Array, lengths: jax.Array, *,
                            scale: float, bits: int = 4) -> jax.Array:
    """Absorbed-MLA decode oracle: q_lat [B,h,kvlr], q_rope [B,h,r];
    lengths [B] -> o_lat [B,h,kvlr] (the latent rows are the values).
    ``scale`` is required — see ``ops.paged_mla_attention``."""
    B, h, kvlr = q_lat.shape
    rope = q_rope.shape[-1]
    ckv, kr = gather_latent_pages(pool_l, block_tables, bits=bits,
                                  kv_lora_rank=kvlr, rope_dim=rope)
    s = (jnp.einsum("bhk,bsk->bhs", q_lat.astype(jnp.float32), ckv)
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), kr)) * scale
    idx = jnp.arange(ckv.shape[1], dtype=jnp.int32)
    valid = idx[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(valid[:, None, :], p, 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhs,bsk->bhk", p / denom, ckv)
    return o.astype(q_lat.dtype)
