"""Pallas TPU kernel: paged decode attention with fused int4 dequant.

One query token per sequence attends over its KV pages.  The grid is
(B, Pmax): the sequential minor dim walks a sequence's *logical* pages while
scalar-prefetched block tables steer each page's BlockSpec to the right
*physical* page of the pool — the pool itself never materializes densely.
Packed int4 codes are unpacked + dequantized in VMEM (vs HBM traffic at 4
bits/value, the decode bottleneck) and fed straight to the MXU; pages are
combined with an online-softmax accumulator in scratch, exactly the
flash-decode recurrence of ``models.attention.chunked_attention``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_block(q_codes, s, z, *, bits: int, hd: int):
    """[T,H,pd] uint8 + [T,H] scales -> [T,H,hd] f32."""
    if bits == 4:
        lo = (q_codes & 0xF).astype(jnp.float32)
        hi = ((q_codes >> 4) & 0xF).astype(jnp.float32)
        vals = jnp.stack([lo, hi], axis=-1)
        vals = vals.reshape(q_codes.shape[:-1] + (q_codes.shape[-1] * 2,))
        vals = vals[..., :hd]
    else:
        vals = q_codes.astype(jnp.float32)
    return vals * s[..., None].astype(jnp.float32) \
        + z[..., None].astype(jnp.float32)


def _paged_attn_kernel(bt_ref, starts_ref, lens_ref,        # scalar prefetch
                       q_ref, kq_ref, ks_ref, kz_ref,
                       vq_ref, vs_ref, vz_ref, o_ref,
                       m_s, l_s, acc_s, *,
                       bits: int, hd: int, groups: int,
                       scale: float, logit_cap: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    T, H = ks_ref.shape[1], ks_ref.shape[2]
    G = groups
    k = _dequant_block(kq_ref[0], ks_ref[0], kz_ref[0], bits=bits, hd=hd)
    v = _dequant_block(vq_ref[0], vs_ref[0], vz_ref[0], bits=bits, hd=hd)
    q = (q_ref[0].astype(jnp.float32) * scale).reshape(H, G, hd)

    # scores [H,G,T]: batch over the kv head, contract head_dim on the MXU
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    idx = j * T + jax.lax.broadcasted_iota(jnp.int32, (1, 1, T), 2)
    mask = (idx >= starts_ref[b]) & (idx < lens_ref[b])
    s = jnp.where(mask, s, -jnp.inf)

    m_prev, l_prev = m_s[...], l_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    m_s[...] = m_new
    l_s[...] = l_prev * corr + jnp.sum(p, axis=-1)
    # o update [H,G,hd]: contract the page dim, batch over the kv head
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr[..., None] + pv

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o = acc_s[...] / jnp.maximum(l_s[...], 1e-30)[..., None]
        o_ref[...] = o.reshape(1, H * G, hd).astype(o_ref.dtype)


# --------------------------------------------------------------------------- #
# MLA latent pages: absorbed decode over quantized c_kv + rope-key pages
# --------------------------------------------------------------------------- #
def _dequant_rows(codes, s, z, *, bits: int, dim: int):
    """[T,pd] uint8 + [T] fp16 scale/zero -> [T,dim] f32 (per-token rows)."""
    if bits == 4:
        lo = (codes & 0xF).astype(jnp.float32)
        hi = ((codes >> 4) & 0xF).astype(jnp.float32)
        vals = jnp.stack([lo, hi], axis=-1)
        vals = vals.reshape(codes.shape[:-1] + (codes.shape[-1] * 2,))
        vals = vals[..., :dim]
    else:
        vals = codes.astype(jnp.float32)
    return vals * s[..., None].astype(jnp.float32) \
        + z[..., None].astype(jnp.float32)


def _paged_mla_kernel(bt_ref, lens_ref,                       # scalar prefetch
                      ql_ref, qr_ref, cq_ref, cs_ref, cz_ref,
                      rq_ref, rs_ref, rz_ref, o_ref,
                      m_s, l_s, acc_s, *,
                      bits: int, kvlr: int, rope: int, scale: float):
    """One query token per sequence attends its latent pages (absorbed MLA):
    scores = q_lat . c_kv + q_rope . k_rope, values ARE the latents (o_lat =
    p . c_kv), so the page holds one quantized row pair per token and the
    kernel is single-"head" attention with n_heads query groups."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, -jnp.inf)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    T = cs_ref.shape[1]
    ckv = _dequant_rows(cq_ref[0], cs_ref[0], cz_ref[0], bits=bits, dim=kvlr)
    kr = _dequant_rows(rq_ref[0], rs_ref[0], rz_ref[0], bits=bits, dim=rope)
    ql = (ql_ref[0].astype(jnp.float32) * scale)          # [h, kvlr]
    qr = (qr_ref[0].astype(jnp.float32) * scale)          # [h, rope]

    # scores [h,T]: latent + rope-key contributions, contracted on the MXU
    s = jax.lax.dot_general(ql, ckv, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) \
        + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    idx = j * T + jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    mask = idx < lens_ref[b]
    s = jnp.where(mask, s, -jnp.inf)

    m_prev, l_prev = m_s[...], l_s[...]                   # [h,1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    m_s[...] = m_new
    l_s[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    # o_lat update [h,kvlr]: values are the latent rows themselves
    pv = jax.lax.dot_general(p, ckv, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_s[...] = acc_s[...] * corr + pv

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o = acc_s[...] / jnp.maximum(l_s[...], 1e-30)
        o_ref[...] = o[None].astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("bits", "kvlr", "rope", "scale",
                                   "interpret"))
def paged_mla_attn_pallas(q_lat: jax.Array, q_rope: jax.Array,
                          cq, cs, cz, rq, rs, rz,
                          block_tables: jax.Array, lengths: jax.Array, *,
                          bits: int, kvlr: int, rope: int, scale: float,
                          interpret: bool = True) -> jax.Array:
    """q_lat [B,h,kvlr], q_rope [B,h,rope]; latent pools [P,T,(pd)];
    block_tables [B,Pmax]; lengths [B] -> o_lat [B,h,kvlr]."""
    B, h, _ = q_lat.shape
    T = cs.shape[1]
    Pmax = block_tables.shape[1]

    def page(b, j, bt, ln):              # noqa: ARG001 — index map signature
        return (bt[b, j], 0, 0)

    def page2(b, j, bt, ln):             # noqa: ARG001
        return (bt[b, j], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Pmax),
        in_specs=[
            pl.BlockSpec((1, h, kvlr), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, h, rope), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, T, cq.shape[-1]), page),
            pl.BlockSpec((1, T), page2),
            pl.BlockSpec((1, T), page2),
            pl.BlockSpec((1, T, rq.shape[-1]), page),
            pl.BlockSpec((1, T), page2),
            pl.BlockSpec((1, T), page2),
        ],
        out_specs=pl.BlockSpec((1, h, kvlr), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, kvlr), jnp.float32),
        ],
    )
    return pl.pallas_call(
        partial(_paged_mla_kernel, bits=bits, kvlr=kvlr, rope=rope,
                scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, h, kvlr), q_lat.dtype),
        interpret=interpret,
    )(block_tables, lengths, q_lat, q_rope, cq, cs, cz, rq, rs, rz)


@partial(jax.jit, static_argnames=("bits", "hd", "groups", "scale",
                                   "logit_cap", "interpret"))
def paged_attn_pallas(q: jax.Array, kq, ks, kz, vq, vs, vz,
                      block_tables: jax.Array, starts: jax.Array,
                      lengths: jax.Array, *, bits: int, hd: int, groups: int,
                      scale: float, logit_cap: float = 0.0,
                      interpret: bool = True) -> jax.Array:
    """q [B,Hq,hd]; pools [P,T,H,(pd)]; block_tables [B,Pmax];
    starts/lengths [B] -> o [B,Hq,hd]."""
    B, Hq, _ = q.shape
    P, T, H = kq.shape[0], kq.shape[1], kq.shape[2]
    Pmax = block_tables.shape[1]
    G = groups

    def page(b, j, bt, st, ln):          # noqa: ARG001 — index map signature
        return (bt[b, j], 0, 0, 0)

    def page3(b, j, bt, st, ln):         # noqa: ARG001
        return (bt[b, j], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Pmax),
        in_specs=[
            pl.BlockSpec((1, Hq, hd), lambda b, j, bt, st, ln: (b, 0, 0)),
            pl.BlockSpec((1, T, H, kq.shape[-1]), page),
            pl.BlockSpec((1, T, H), page3),
            pl.BlockSpec((1, T, H), page3),
            pl.BlockSpec((1, T, H, vq.shape[-1]), page),
            pl.BlockSpec((1, T, H), page3),
            pl.BlockSpec((1, T, H), page3),
        ],
        out_specs=pl.BlockSpec((1, Hq, hd), lambda b, j, bt, st, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, G), jnp.float32),
            pltpu.VMEM((H, G), jnp.float32),
            pltpu.VMEM((H, G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        partial(_paged_attn_kernel, bits=bits, hd=hd, groups=G, scale=scale,
                logit_cap=logit_cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, hd), q.dtype),
        interpret=interpret,
    )(block_tables, starts, lengths, q, kq, ks, kz, vq, vs, vz)
