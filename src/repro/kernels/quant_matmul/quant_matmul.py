"""Pallas TPU kernel: int4-weight dequant matmul (the serving GEMM).

TPU adaptation of the paper's CUTLASS INT4 GEMM: v5e has no INT4 MXU path, so
the TPU-native form is weight-only int4 — packed nibbles are unpacked and
dequantized to bf16 *inside VMEM* (halving HBM weight traffic, the actual
bottleneck of decode) and fed to the MXU with f32 accumulation.

Grid tiles (M/bm, N/bn); the full K stripe of x and the packed K/2 stripe of w
live in VMEM per tile.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _w4_matmul_kernel(x_ref, qw_ref, s_ref, o_ref):
    x = x_ref[...]                                          # [bm, K]
    qw = qw_ref[...]                                        # [bn, K//2] uint8
    lo = (qw & 0xF).astype(jnp.int8)
    hi = ((qw >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    q = jnp.stack([lo, hi], axis=-1).reshape(qw.shape[0], qw.shape[1] * 2)
    w = q.astype(jnp.float32) * s_ref[...].astype(jnp.float32)   # [bn, K]
    acc = jax.lax.dot_general(x.astype(jnp.float32), w,
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def w4_matmul_pallas(x: jax.Array, qw: jax.Array, scale: jax.Array,
                     block_m: int = 128, block_n: int = 128,
                     interpret: bool = True) -> jax.Array:
    """x [M,K] bf16/f32; qw [N,K/2] uint8; scale [N,1] -> y [M,N]."""
    M, K = x.shape
    N = qw.shape[0]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _w4_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, K // 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, qw, scale)
