"""Pallas TPU kernel: int4/int8-weight dequant matmul (the serving GEMM).

TPU adaptation of the paper's CUTLASS INT4 GEMM: v5e has no INT4 MXU path, so
the TPU-native form is weight-only quantization — packed nibbles (or int8
bytes) are unpacked and dequantized to f32 *inside VMEM* (halving/quartering
HBM weight traffic, the actual bottleneck of decode) and fed to the MXU with
f32 accumulation.  Scales are per output channel ([N,1]) or grouped on the
in-feature dim ([N, K/group]).

Grid tiles (M/bm, N/bn); the full K stripe of x and the packed K/2 (int4) or
K (int8) stripe of w live in VMEM per tile.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_nibbles(qw: jax.Array) -> jax.Array:
    lo = (qw & 0xF).astype(jnp.int8)
    hi = ((qw >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(qw.shape[0], qw.shape[1] * 2)


def _quant_matmul_kernel(bits, group, x_ref, qw_ref, s_ref, o_ref):
    x = x_ref[...]                                          # [bm, K]
    qw = qw_ref[...]                                        # [bn, K/2] u8 | [bn, K] i8
    q = _unpack_nibbles(qw) if bits == 4 else qw
    qf = q.astype(jnp.float32)                              # [bn, K]
    s = s_ref[...].astype(jnp.float32)                      # [bn, 1] | [bn, K/group]
    if group > 0:
        bn, K = qf.shape
        w = (qf.reshape(bn, K // group, group) * s[:, :, None]).reshape(bn, K)
    else:
        w = qf * s
    acc = jax.lax.dot_general(x.astype(jnp.float32), w,
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@partial(jax.jit,
         static_argnames=("bits", "group", "block_m", "block_n", "interpret"))
def quant_matmul_pallas(x: jax.Array, qw: jax.Array, scale: jax.Array,
                        bits: int = 4, group: int = -1,
                        block_m: int = 128, block_n: int = 128,
                        interpret: bool = True) -> jax.Array:
    """x [M,K]; qw [N,K/2] uint8 (int4 nibbles) or [N,K] int8; scale [N,G]
    with G = 1 (per channel) or K/group -> y [M,N]."""
    M, K = x.shape
    N = qw.shape[0]
    G = scale.shape[1]
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        partial(_quant_matmul_kernel, bits, group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, qw.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, G), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, qw, scale)


def w4_matmul_pallas(x: jax.Array, qw: jax.Array, scale: jax.Array,
                     block_m: int = 128, block_n: int = 128,
                     interpret: bool = True) -> jax.Array:
    """Back-compat alias: packed-int4, per-channel scale."""
    return quant_matmul_pallas(x, qw, scale, bits=4, group=-1,
                               block_m=block_m, block_n=block_n,
                               interpret=interpret)
