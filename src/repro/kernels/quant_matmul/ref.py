"""Pure-jnp oracle for the W4 dequant matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.quantizers import unpack_int4


def w4_matmul_ref(x, qw_packed, scale):
    """x [M,K]; qw_packed [N,K/2] uint8 (two int4 nibbles); scale [N,1].

    y = x @ (unpack(qw) * scale).T  in f32 accumulation.
    """
    q = unpack_int4(qw_packed).astype(jnp.float32)          # [N, K]
    w = q * scale.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)
