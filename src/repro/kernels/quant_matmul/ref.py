"""Pure-jnp oracle for the quantized-weight dequant matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.quantizers import QTensor, unpack_int4


def quant_matmul_ref(x, qt: QTensor):
    """x [..., K logical]; qt as in ops.quant_matmul.

    y = x @ (unpack(q) * scale).T in f32 accumulation, padding x's last dim
    to the stored K (padded weight columns hold zero codes — exact).
    """
    q = unpack_int4(qt.q) if qt.packed else qt.q
    qf = q.astype(jnp.float32)                              # [N, Kp]
    s = qt.scale.astype(jnp.float32)
    if qt.group > 0:
        N, Kp = qf.shape
        w = (qf.reshape(N, Kp // qt.group, qt.group)
             * s[:, :, None]).reshape(N, Kp)
    else:
        w = qf * s.reshape(qf.shape[0], -1)
    Kp = w.shape[-1]
    if x.shape[-1] != Kp:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, Kp - x.shape[-1])])
    y = jnp.einsum("...i,oi->...o", x.astype(jnp.float32), w)
    return y.astype(x.dtype)


def w4_matmul_ref(x, qw_packed, scale):
    """Back-compat oracle: x [M,K]; qw_packed [N,K/2] uint8; scale [N,1]."""
    q = unpack_int4(qw_packed).astype(jnp.float32)          # [N, K]
    w = q * scale.astype(jnp.float32)
    return (x.astype(jnp.float32) @ w.T).astype(x.dtype)
