"""Public wrapper: W4 dequant matmul over QTensor weights."""
from __future__ import annotations

import numpy as np

import jax

from repro.kernels.common import use_interpret
from repro.kernels.quant_matmul.quant_matmul import w4_matmul_pallas
from repro.quant.quantizers import QTensor


def w4_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """y = x @ dequant(qt).T for any-rank x; qt.q packed uint8 [N, K/2]."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    m = int(np.prod(lead)) if lead else 1
    bm = 128
    while m % bm and bm > 1:
        bm //= 2
    N = qt.q.shape[0]
    bn = 128
    while N % bn and bn > 1:
        bn //= 2
    y = w4_matmul_pallas(x.reshape(m, K), qt.q, qt.scale,
                         block_m=bm, block_n=bn, interpret=use_interpret())
    return y.reshape(lead + (N,))
