"""Public wrapper: quantized-weight dequant matmul over QTensor weights."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.quant_matmul.quant_matmul import quant_matmul_pallas
from repro.quant.quantizers import QTensor


def _block(n: int, cap: int = 128) -> int:
    b = cap
    while n % b and b > 1:
        b //= 2
    return b


def quant_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """y = x @ dequant(qt).T for any-rank x.

    qt.q: packed uint8 [N, K/2] (int4) or int8 [N, K]; qt.scale [N, 1] or
    [N, K/group].  x's last dim is the *logical* in-feature count — it is
    zero-padded up to the stored (even/group-padded) K, which is exact since
    the padded weight columns hold zero codes.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    Kp = qt.stored_in_dim
    if Kp != K:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, Kp - K)])
    m = int(np.prod(lead)) if lead else 1
    N = qt.q.shape[0]
    scale = qt.scale if qt.scale.ndim == 2 else qt.scale.reshape(N, -1)
    y = quant_matmul_pallas(x.reshape(m, Kp), qt.q, scale,
                            bits=qt.bits, group=qt.group,
                            block_m=_block(m), block_n=_block(N),
                            interpret=use_interpret())
    return y.reshape(lead + (N,))


def w4_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """Back-compat alias: packed-int4 QTensor matmul."""
    return quant_matmul(x, qt)
