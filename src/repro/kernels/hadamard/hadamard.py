"""Pallas TPU kernel: online Walsh–Hadamard transform (R3/R4 fast path).

TPU adaptation (vs the CUDA warp-shuffle butterfly): an n-point WHT factors as
H_n = H_a (x) H_b, so for a row X viewed as an [a, b] matrix the transform is
``H_a @ X @ H_b`` — two dense matmuls with b chosen near the 128-lane width so
the MXU does the work.  Rows are tiled into VMEM blocks of ``block_m``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int):
    x = x_ref[...].astype(jnp.float32)                     # [bm, n]
    bm = x.shape[0]
    xr = x.reshape(bm, a, b)
    # X @ H_b  (contract the lane-sized factor first: MXU-aligned)
    t = jax.lax.dot_general(xr, hb_ref[...],
                            (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bm, a, b]
    # H_a applied on the a factor
    y = jax.lax.dot_general(t, ha_ref[...],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bm, b, a]
    y = jnp.swapaxes(y, 1, 2)
    o_ref[...] = y.reshape(bm, a * b).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def wht_pallas(x: jax.Array, ha: jax.Array, hb: jax.Array,
               block_m: int = 256, interpret: bool = True) -> jax.Array:
    """x [M, n] with n == a*b; ha [a,a], hb [b,b] pre-normalized factors."""
    M, n = x.shape
    a, b = ha.shape[0], hb.shape[0]
    assert a * b == n
    bm = min(block_m, M)
    assert M % bm == 0, f"rows {M} not divisible by block {bm}"
    grid = (M // bm,)
    return pl.pallas_call(
        partial(_wht_kernel, a=a, b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, n), x.dtype),
        interpret=interpret,
    )(x, ha, hb)
