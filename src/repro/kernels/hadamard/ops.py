"""jit'd public wrapper for the WHT kernel (auto shape handling, CPU interpret)."""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rotations import hadamard_matrix
from repro.kernels.common import use_interpret, wht_factors
from repro.kernels.hadamard.hadamard import wht_pallas


@lru_cache(maxsize=32)
def _factors(n: int):
    a, b = wht_factors(n)
    ha = np.asarray(hadamard_matrix(a), np.float32) / np.sqrt(a)
    hb = np.asarray(hadamard_matrix(b), np.float32) / np.sqrt(b)
    return ha, hb


def online_hadamard(x: jax.Array, block_m: int = 256) -> jax.Array:
    """Apply WHT/sqrt(n) over the last dim of any-rank x (R3/R4 online op)."""
    n = x.shape[-1]
    ha, hb = _factors(n)
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    xf = x.reshape(m, n)
    bm = block_m
    while m % bm and bm > 1:
        bm //= 2
    out = wht_pallas(xf, jnp.asarray(ha), jnp.asarray(hb), block_m=bm,
                     interpret=use_interpret())
    return out.reshape(x.shape)
