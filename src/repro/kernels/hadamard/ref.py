"""Pure-jnp oracle for the online Walsh–Hadamard transform."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.rotations import hadamard_matrix


def wht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """x [..., n] -> x @ H_n / sqrt(n)."""
    n = x.shape[-1]
    h = jnp.asarray(hadamard_matrix(n), jnp.float32) / np.sqrt(n)
    return (x.astype(jnp.float32) @ h).astype(x.dtype)
