"""Public wrapper: fused activation quantization."""
from __future__ import annotations

import numpy as np

import jax

from repro.kernels.act_quant.act_quant import act_quant_pallas
from repro.kernels.common import use_interpret


def act_quant(x: jax.Array, bits: int = 4, block_m: int = 256):
    """Any-rank x quantized per last-dim row. Returns (codes, scale, zero)."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    m = int(np.prod(lead)) if lead else 1
    bm = block_m
    while m % bm and bm > 1:
        bm //= 2
    q, s, z = act_quant_pallas(x.reshape(m, d), bits=bits, block_m=bm,
                               interpret=use_interpret())
    return (q.reshape(x.shape), s.reshape(lead + (1,)), z.reshape(lead + (1,)))
