"""Pure-jnp oracle for fused per-token asymmetric activation quantization."""
from __future__ import annotations

import jax.numpy as jnp


def act_quant_ref(x, bits: int = 4):
    """x [M, d] -> (codes uint8 [M,d], scale [M,1] f32, zero [M,1] f32)."""
    qmax = 2 ** bits - 1
    xf = x.astype(jnp.float32)
    lo = jnp.min(xf, axis=-1, keepdims=True)
    hi = jnp.max(xf, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((xf - lo) / scale), 0, qmax).astype(jnp.uint8)
    return q, scale, lo
