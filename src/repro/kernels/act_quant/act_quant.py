"""Pallas TPU kernel: fused per-token asymmetric activation quantization.

One VMEM pass per row block: min/max reduce across lanes, scale/zero-point,
round, emit uint8 codes + fp32 affine metadata.  This is the A4/A8 hot path in
front of every quantized matmul (paper Fig. 9: "all activations prior to the
weights are quantized to INT4").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _act_quant_kernel(x_ref, q_ref, s_ref, z_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # [bm, d]
    qmax = float(2 ** bits - 1)
    lo = jnp.min(x, axis=1, keepdims=True)
    hi = jnp.max(x, axis=1, keepdims=True)
    s = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((x - lo) / s), 0.0, qmax)
    q_ref[...] = q.astype(jnp.uint8)
    s_ref[...] = s
    z_ref[...] = lo


@partial(jax.jit, static_argnames=("bits", "block_m", "interpret"))
def act_quant_pallas(x: jax.Array, bits: int = 4, block_m: int = 256,
                     interpret: bool = True):
    M, d = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    grid = (M // bm,)
    return pl.pallas_call(
        partial(_act_quant_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, d), jnp.uint8),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
