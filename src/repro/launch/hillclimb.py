import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: re-lower the three chosen cells with optimization
variants and print before/after roofline terms.

  PYTHONPATH=src python -m repro.launch.hillclimb
"""
import json

from repro.launch.dryrun import OUT_DIR, cell_name, run_cell

# (arch, shape, variant) — hypotheses documented in EXPERIMENTS.md §Perf
RUNS = [
    # Cell A: deepseek-v3 train — kill per-microbatch expert-weight gathers
    ("deepseek-v3-671b", "train_4k", {"name": "ep_all",
                                      "cfg": {"ep_axes": "all"}}),
    # Cell B: yi-34b train — TP-shard seq-arch attention weights
    ("yi-34b", "train_4k", {"name": "attn_tp",
                            "cfg": {"attn_weight_tp": True}}),
    # Cell C: qwen decode — weight-stationary attention TP + fp8 KV cache
    ("qwen2.5-32b", "decode_32k", {"name": "attn_tp",
                                   "cfg": {"attn_weight_tp": True}}),
    ("qwen2.5-32b", "decode_32k", {"name": "attn_tp_kv8",
                                   "cfg": {"attn_weight_tp": True},
                                   "cache_dtype": "f8"}),
    # Round 2 — hypothesis: the replicated f32 grad-accum buffer forces a
    # full AR per microbatch; sharding it (param_specs constraint) turns it
    # into reduce-scatter.  With memory freed, fewer microbatches cut the
    # per-micro FSDP param regathers.
    ("yi-34b", "train_4k", {"name": "attn_tp_gshard",
                            "cfg": {"attn_weight_tp": True}, "accum": 16}),
    ("yi-34b", "train_4k", {"name": "attn_tp_gshard_acc4",
                            "cfg": {"attn_weight_tp": True}, "accum": 4}),
    ("deepseek-v3-671b", "train_4k", {"name": "ep_all_gshard",
                                      "cfg": {"ep_axes": "all"}, "accum": 16}),
    ("deepseek-v3-671b", "train_4k", {"name": "ep_all_gshard_acc4",
                                      "cfg": {"ep_axes": "all"}, "accum": 4}),
]


def show(rec):
    r = rec["roofline"]
    return (f"mem={rec['memory']['peak_estimate_bytes']/2**30:6.2f}GiB "
            f"t_c={r['t_compute']:8.3f} t_m={r['t_memory']:8.3f} "
            f"t_x={r['t_collective']:8.3f} dom={r['bottleneck']}")


def main():
    for arch, shape, variant in RUNS:
        base = json.loads(
            (OUT_DIR / f"{cell_name(arch, shape, False)}.json").read_text())
        print(f"--- {arch} {shape}")
        print(f"    baseline          {show(base)}", flush=True)
        rec = run_cell(arch, shape, variant=variant)
        print(f"    {variant['name']:<17s} {show(rec)}", flush=True)


if __name__ == "__main__":
    main()
