"""DartQuant calibration + quantization driver (the paper's pipeline).

  PYTHONPATH=src python -m repro.launch.calibrate --arch llama2-7b \
      --objective whip --method qr --steps 100

Loads/initializes a model (reduced config on CPU), captures activations on a
calibration batch, optimizes R1/R2 with QR-Orth+Whip, fuses rotations, applies
RTN/GPTQ weight quant, and reports before/after quant quality.

Observability: ``--metrics-out metrics.prom`` snapshots per-site loss
gauges and quantization-health histograms (clip rate, scale dynamic range —
sampled at the QDQ hooks while quantizing); ``--trace-out span.jsonl``
writes one ``calib_site`` span per rotation site with the full loss history;
``--profile-dir d/`` captures a ``jax.profiler`` device trace of the
calibration scans.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import calibrate_model, fuse_rotations, random_pack
from repro.data.pipeline import calibration_batch, batches
from repro.models import model as M
from repro.obs import JsonlSink, Obs, Tracer
from repro.obs import quant_health
from repro.quant import act_quant as act_quant_ctx, fake_quant_act, \
    quantize_params


def eval_ppl(cfg, params, tokens, labels, a_bits=16, rot=None):
    def run():
        logits, _ = M.forward(cfg, params, tokens, rot=rot)
        from repro.models.common import cross_entropy
        return cross_entropy(logits, labels)
    if a_bits < 16:
        with act_quant_ctx(lambda x: fake_quant_act(x, a_bits)):
            ce = jax.jit(run)()
    else:
        ce = jax.jit(run)()
    return float(jnp.exp(ce))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--objective", default="whip")
    ap.add_argument("--method", default="qr", choices=["qr", "cayley"])
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    ap.add_argument("--serial-r2", action="store_true",
                    help="legacy serial per-layer R2 loop (debug/compare)")
    ap.add_argument("--mesh", default=None, metavar="N|auto",
                    help="token-sharded calibration on a data mesh: 'auto' "
                         "puts every local device on the 'data' axis, an "
                         "integer N builds an (N, 1) ('data','model') mesh. "
                         "Mesh contract: captured activations shard their "
                         "token axis over the data axes ('pod' x 'data' on "
                         "the production mesh); rotation latents and "
                         "optimizer state replicate; the whip loss and its "
                         "gradient are psum'd once per step. Eval/serving "
                         "stays single-device.")
    ap.add_argument("--compressed-grads", action="store_true",
                    help="int8+error-feedback payload for the sharded "
                         "gradient psum (needs --mesh)")
    ap.add_argument("--ckpt", default=None, help="params checkpoint to load")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write calib_site spans (JSONL) here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus metrics snapshot here (also "
                         "arms the QDQ quant-health taps)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace")
    args = ap.parse_args(argv)

    tracer = Tracer(JsonlSink(args.trace_out)) if args.trace_out else None
    obs = Obs(tracer=tracer, profile_dir=args.profile_dir)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_calib_mesh
        mesh = make_calib_mesh(None if args.mesh == "auto" else int(args.mesh))
        print(f"calibrating token-sharded on mesh {dict(mesh.shape)}")

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    # independent streams for the calibration capture sampling and the
    # Hadamard baseline (repro.analysis prng-reuse: one key, one consumer)
    k_calib = jax.random.fold_in(key, 1)
    k_had = jax.random.fold_in(key, 2)
    if args.ckpt:
        from repro.train.checkpoint import latest_step, restore
        s = latest_step(args.ckpt)
        params = restore(args.ckpt, s, params)
        print(f"loaded checkpoint step {s}")

    calib = jnp.asarray(calibration_batch(cfg, n_samples=8, seq_len=128))
    test = next(batches(cfg, 8, 128, seed=123))
    toks, labels = jnp.asarray(test["tokens"]), jnp.asarray(test["labels"])

    ppl_fp = eval_ppl(cfg, params, toks, labels)
    ppl_rtn = eval_ppl(cfg, quantize_params(cfg, params), toks, labels,
                       a_bits=args.a_bits)

    t0 = time.perf_counter()
    histories = {}
    obs.start_profile()
    try:
        pack = calibrate_model(cfg, params, calib, key=k_calib,
                               objective=args.objective, method=args.method,
                               optimizer=args.optimizer, steps=args.steps,
                               r2_batched=not args.serial_r2,
                               history_out=histories, verbose=True, mesh=mesh,
                               compressed_grads=args.compressed_grads,
                               obs=obs)
    finally:
        obs.stop_profile()
    for site, h in histories.items():
        h = jnp.asarray(h)
        first, last = h[..., 0], h[..., -1]
        print(f"  site {site:10s}: loss {float(first.mean()):.4f} -> "
              f"{float(last.mean()):.4f} over {h.shape[-1]} steps"
              + (f" (x{h.shape[0]} layers)" if h.ndim == 2 else ""))
    fcfg, fused = fuse_rotations(cfg, params, pack)
    from repro.core.rotations import online_hadamard
    rot = {"r4": online_hadamard}
    if args.metrics_out:
        # arm the QDQ taps so the calibrated quantization pass reports
        # clip-rate / scale-dynamic-range health into the same registry
        with quant_health.sampling(obs.metrics):
            qparams = quantize_params(fcfg, fused)
            jax.block_until_ready(qparams)
    else:
        qparams = quantize_params(fcfg, fused)
    ppl_dart = eval_ppl(fcfg, qparams, toks, labels,
                        a_bits=args.a_bits, rot=rot)

    hcfg, hfused = fuse_rotations(cfg, params, random_pack(cfg, k_had))
    ppl_had = eval_ppl(hcfg, quantize_params(hcfg, hfused), toks, labels,
                       a_bits=args.a_bits, rot=rot)

    print(f"\narch={args.arch} W{args.w_bits}A{args.a_bits}")
    print(f"  fp32 ppl       : {ppl_fp:.3f}")
    print(f"  RTN  ppl       : {ppl_rtn:.3f}")
    print(f"  QuaRot(Hadamard): {ppl_had:.3f}")
    print(f"  DartQuant      : {ppl_dart:.3f}  "
          f"(calibrated in {time.perf_counter()-t0:.1f}s)")

    if args.metrics_out:
        obs.metrics.write_prom(args.metrics_out)
        print(f"[calibrate] metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        print(f"[calibrate] span log -> {args.trace_out}")
    obs.close()


if __name__ == "__main__":
    main()
