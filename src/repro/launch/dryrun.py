import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step function (train_step / prefill / decode_step) with
     ShapeDtypeStruct stand-ins and full sharding specs,
  3. compiles, records memory_analysis + scan-aware HLO roofline stats,
  4. appends the result JSON to experiments/dryrun/<cell>.json (resumable).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCH_IDS, ARCH_IDS, SHAPES, get_config
from repro.dist.sharding import Sharding
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.roofline.hlo_analysis import analyze_hlo, roofline_terms
from repro.train.optimizer import OptState
from repro.train import steps as S

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def cell_name(arch: str, shape: str, multi_pod: bool,
              variant: str = "") -> str:
    base = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
    return f"{base}__{variant}" if variant else base


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             skip_existing: bool = True, variant: dict = None) -> dict:
    """variant: {"name": str, "cfg": {field: value}, "cache_dtype": "f8"}
    — §Perf hillclimb runs baseline vs variants on the same cell."""
    vname = variant["name"] if variant else ""
    out_path = OUT_DIR / f"{cell_name(arch, shape, multi_pod, vname)}.json"
    if skip_existing and out_path.exists():
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    if variant and variant.get("cfg"):
        cfg = cfg.replace(**variant["cfg"])
    cell = SHAPES[shape]
    if shape in cfg.skip_shapes:
        rec = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
               "status": "skipped", "reason": "skip_shapes (see DESIGN.md)"}
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shd = Sharding(cfg, mesh)
    params_sds = S.params_shape(cfg)
    pspecs = shd.param_specs(params_sds)
    psh = shd.named(pspecs)

    with mesh:
        if cell.kind == "train":
            opt_sds = S.opt_shape(cfg, params_sds)
            osh = OptState(NamedSharding(mesh, P()), psh, psh)
            batch_sds = S.input_specs(cfg, cell)
            bsh = shd.named(shd.batch_specs(batch_sds))
            # microbatch so activation memory fits HBM: remat saves one
            # [tokens, d_model] residual per layer -> budget ~6 GiB
            n_dp = int(np.prod([mesh.shape[a] for a in mesh.shape
                                if a != "model"]))
            tokens_per_dev = cell.global_batch * cell.seq_len // n_dp
            budget_tokens = max(2048, int(6e9 / (cfg.n_layers * cfg.d_model * 2)))
            target = min(16384, budget_tokens)
            accum = max(1, -(-tokens_per_dev // target))
            accum = 1 << (accum - 1).bit_length()          # round up to pow2
            while (cell.global_batch % (accum * n_dp) or
                   cell.global_batch // accum < n_dp) and accum > 1:
                accum //= 2
            if variant and variant.get("accum"):
                accum = variant["accum"]
            step = S.build_train_step(cfg, mesh=mesh, shd=shd,
                                      grad_accum=accum, param_specs=pspecs)
            jf = jax.jit(step, in_shardings=(psh, osh, bsh),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_sds, opt_sds, batch_sds)
        elif cell.kind == "prefill":
            ins = S.input_specs(cfg, cell)
            ish = shd.named(shd.batch_specs(ins))
            step = S.build_prefill(cfg, mesh=mesh, shd=shd)
            if "frames" in ins:
                jf = jax.jit(lambda p, t, f: step(p, t, frames=f),
                             in_shardings=(psh, ish["tokens"], ish["frames"]))
                lowered = jf.lower(params_sds, ins["tokens"], ins["frames"])
            else:
                jf = jax.jit(step, in_shardings=(psh, ish["tokens"]))
                lowered = jf.lower(params_sds, ins["tokens"])
        else:  # decode
            cache_dtype = jnp.bfloat16
            if variant and variant.get("cache_dtype") == "f8":
                cache_dtype = jnp.float8_e4m3fn
            ins = S.input_specs(cfg, cell, cache_dtype=cache_dtype)
            csh = shd.named(shd.cache_specs(ins["cache"]))
            tsh = shd.named(shd.batch_specs({"token": ins["token"]}))["token"]
            step = S.build_decode_step(cfg, mesh=mesh, shd=shd)
            jf = jax.jit(step,
                         in_shardings=(psh, tsh, csh, NamedSharding(mesh, P())),
                         donate_argnums=(2,))
            lowered = jf.lower(params_sds, ins["token"], ins["cache"],
                               jax.ShapeDtypeStruct((), jnp.int32))

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)
    terms = roofline_terms(stats)
    ca = compiled.cost_analysis() or {}

    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod, "status": "ok",
        "variant": vname or "baseline",
        "n_devices": int(len(mesh.devices.flat)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        },
        "hlo_stats": {k: (v if not isinstance(v, dict) else v)
                      for k, v in stats.items()},
        "xla_cost_analysis_flops": float(ca.get("flops", -1)),
        "roofline": terms,
        "model": {
            "n_params": get_config(arch).n_params(),
            "n_active_params": get_config(arch).n_active_params(),
        },
    }
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    for a, s in cells:
        name = cell_name(a, s, args.multi_pod)
        try:
            rec = run_cell(a, s, multi_pod=args.multi_pod,
                           skip_existing=not args.force)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[ok] {name}: compile={rec['compile_s']}s "
                      f"mem={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB "
                      f"t_c={r['t_compute']:.4f} t_m={r['t_memory']:.4f} "
                      f"t_x={r['t_collective']:.4f} dom={r['bottleneck']}",
                      flush=True)
            else:
                print(f"[skip] {name}: {rec.get('reason','')}", flush=True)
        except Exception as e:
            print(f"[FAIL] {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
            OUT_DIR.mkdir(parents=True, exist_ok=True)
            (OUT_DIR / f"{name}.FAILED").write_text(
                f"{type(e).__name__}: {e}\n{traceback.format_exc()}")


if __name__ == "__main__":
    main()
