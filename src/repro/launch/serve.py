"""Serving driver: batched generation over a DartQuant-quantized model.

  # quantize-once → serve-from-artifact (production flow; no calibration here)
  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --out art/
  PYTHONPATH=src python -m repro.launch.serve --artifact art/ --requests 8

  # in-process calibrate-then-serve (dev flow)
  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --requests 8

With ``--artifact`` the engine cold-boots from the saved QuantArtifact —
packed int4/int8 weights straight onto the device, online R3/R4 resolved from
the fused-rotation metadata — and the calibration stack
(``core.calibrate``/``core.qr_orth``) is never invoked.

Every decoder-only family serves through the paged runtime (the default):
dense/MoE/mixed GQA stacks on int4/int8 KV pages, MLA (deepseek-v3) on
quantized latent pages, SSM (mamba2) and hybrid (zamba2) on int8 state
slots — all under the same token-level continuous-batching scheduler.
``--engine legacy`` selects the lockstep dense-cache loop, which survives
only for encoder-decoder models (whisper); for everything else the legacy
``ServeEngine`` is a thin wrapper over the paged engine.

Sampling is per request: greedy by default; ``--temperature``/``--top-k``
(with ``--seed``) enable stochastic decoding with a per-request PRNG key.

``--loadgen`` replaces the pre-enqueued batch with the open-loop Poisson
load generator (``repro.serve.loadgen``): requests arrive through real
scheduler admission at ``--loadgen-rate`` with mixed lengths and a
``--loadgen-shared-frac`` shared-prefix traffic mix, and the run reports
goodput — the fraction of requests meeting ``--slo-ttft`` and
``--slo-itl-p99`` — alongside the usual stats (and into ``--metrics-out``).

Observability (``repro.obs``): ``--trace-out span.jsonl`` writes the
per-request lifecycle span log, ``--metrics-out metrics.prom`` a Prometheus
textfile snapshot (TTFT/ITL histograms, page occupancy, prefix-cache and
preemption counters), ``--profile-dir d/`` a ``jax.profiler`` device trace
viewable in TensorBoard/Perfetto.  All three default off; the disabled path
serves bit-identical tokens.  Validate the artifacts with
``python -m repro.obs.validate --trace span.jsonl --metrics metrics.prom``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.obs import JsonlSink, Obs, Tracer
from repro.serve import PagedServeEngine, Request, ServeEngine


def _use_paged(args, cfg) -> bool:
    if args.engine == "paged":
        return True
    return args.engine == "auto" and M.supports_paged(cfg)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a quantized model. Every decoder-only family "
                    "(dense/MoE/mixed GQA, MLA, SSM, hybrid) runs on the "
                    "paged continuous-batching engine; the legacy lockstep "
                    "engine remains only for encoder-decoder models.")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--artifact", default=None,
                    help="serve from a saved QuantArtifact directory "
                         "(skips the calibration stack entirely)")
    ap.add_argument("--engine", choices=["paged", "legacy", "auto"],
                    default="auto",
                    help="auto = paged for every decoder-only family "
                         "(legacy only for enc-dec)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=None,
                    help="4/8 = quantized KV or MLA-latent pages; 16 = raw "
                         "fp16 pages (compat)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = full)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = no truncation)")
    ap.add_argument("--rep-penalty", type=float, default=1.0,
                    help="CTRL repetition penalty over the last 64 "
                         "prompt+output tokens (1.0 = off)")
    ap.add_argument("--seed", type=int, default=None,
                    help="base PRNG seed for sampled decoding")
    ap.add_argument("--mesh", default=None, metavar="N|auto",
                    help="tensor-parallel serving over N devices on the "
                         "mesh 'model' axis ('auto' = all local devices; "
                         "default: single device)")
    ap.add_argument("--dump-tokens", default=None, metavar="PATH",
                    help="write every request's output token ids (one "
                         "space-separated line per request) — the TP parity "
                         "smoke diffs this across --mesh values")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a shared system prompt of this many tokens "
                         "to every request (exercises the prefix cache)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the prefix index (every prompt prefills "
                         "from scratch)")
    ap.add_argument("--assert-prefix-parity", action="store_true",
                    help="re-serve the same requests with the prefix cache "
                         "off and assert token-for-token parity, a nonzero "
                         "hit rate and fewer prefilled tokens (CI smoke)")
    ap.add_argument("--assert-program-cache", action="store_true",
                    help="after serving, check every jitted program's cache "
                         "size against the engine's declared compile budget "
                         "(the repro.analysis recompile contract: above "
                         "budget = a leaked cache-key dependency recompiling "
                         "per step; CI smoke)")
    ap.add_argument("--loadgen", action="store_true",
                    help="drive the engine with the open-loop Poisson load "
                         "generator (real scheduler admission) instead of a "
                         "pre-enqueued batch, and report goodput against the "
                         "--slo-* objectives")
    ap.add_argument("--loadgen-rate", type=float, default=8.0, metavar="RPS",
                    help="offered (open-loop) arrival rate for --loadgen")
    ap.add_argument("--loadgen-shared-frac", type=float, default=0.5,
                    metavar="FRAC",
                    help="fraction of --loadgen requests carrying the "
                         "--shared-prefix system prompt")
    ap.add_argument("--slo-ttft", type=float, default=2.0, metavar="S",
                    help="TTFT SLO (seconds) for the goodput report")
    ap.add_argument("--slo-itl-p99", type=float, default=0.5, metavar="S",
                    help="per-request p99 inter-token-latency SLO (seconds)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the request-lifecycle span log (JSONL) here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus textfile metrics snapshot here")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace into this "
                         "directory (TensorBoard/Perfetto)")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--qdq", action="store_true",
                    help="serve fake-quant (QDQ) fp weights instead of "
                         "packed int4 QTensors (in-process flow only)")
    args = ap.parse_args(argv)

    if args.artifact:
        # the artifact snapshot IS the serving config — reject conflicting
        # flags instead of silently ignoring them
        bad = [n for n, v in (("--arch", args.arch),
                              ("--a-bits", args.a_bits),
                              ("--kv-bits", args.kv_bits)) if v is not None]
        bad += [n for n, v in (("--qdq", args.qdq),
                               ("--no-quant", args.no_quant)) if v]
        if bad:
            ap.error(f"{', '.join(bad)} conflict(s) with --artifact: the "
                     "serving config comes from the artifact snapshot "
                     "(re-run repro.launch.quantize to change it)")
    else:
        args.arch = args.arch or "llama2-7b"
        args.a_bits = 8 if args.a_bits is None else args.a_bits
        args.kv_bits = 4 if args.kv_bits is None else args.kv_bits

    max_seq = args.prompt_len + args.shared_prefix + args.max_new * 4
    mesh = None
    if args.mesh:
        n = len(jax.devices()) if args.mesh == "auto" else int(args.mesh)
        if n > 1:
            from repro.launch.mesh import make_serve_mesh
            mesh = make_serve_mesh(n)
            print(f"[serve] tensor-parallel over {n} devices "
                  f"(mesh 'model' axis)")
    eng_kw = dict(batch_slots=args.slots, max_seq=max_seq, mesh=mesh)
    base_seed = 0 if args.seed is None else args.seed

    # one Obs for the primary engine; the parity baseline below gets its own
    # default Obs so its runs never pollute the traced artifacts
    tracer = Tracer(JsonlSink(args.trace_out)) if args.trace_out else None
    obs = Obs(tracer=tracer, profile_dir=args.profile_dir)

    if args.artifact:
        # cold boot: packed weights + rotation metadata from disk; zero calls
        # into core.calibrate/core.qr_orth
        from repro.artifacts import load_artifact
        art = load_artifact(args.artifact)
        cfg = art.cfg

        def build(prefix_cache: bool, obs=None):
            if _use_paged(args, cfg):
                return PagedServeEngine.from_artifact(
                    art, page_size=args.page_size, base_seed=base_seed,
                    prefix_cache=prefix_cache, obs=obs, **eng_kw)
            # the wrapper forwards decoder-only families to the paged engine,
            # so sampling/paging flags must flow through it too
            return ServeEngine.from_artifact(
                art, page_size=args.page_size, obs=obs,
                **(dict(base_seed=base_seed, prefix_cache=prefix_cache,
                        **eng_kw)
                   if M.supports_paged(cfg) else eng_kw))
        eng = build(not args.no_prefix_cache, obs=obs)
        print(f"[serve] cold boot from {args.artifact} "
              f"(rotations: {art.rotations}, meta: {art.meta})")
    else:
        cfg = get_config(args.arch).reduced()
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        rot = None
        if not args.no_quant:
            from repro.core import calibrate_model, fuse_rotations
            from repro.data.pipeline import calibration_batch
            from repro.quant import pack_params, quantize_params
            calib = jnp.asarray(calibration_batch(cfg, 4, 64))
            pack = calibrate_model(cfg, params, calib,
                                   key=jax.random.fold_in(key, 1), steps=30)
            cfg, params = fuse_rotations(cfg, params, pack)
            if args.qdq:
                params = quantize_params(cfg, params)
            else:
                # true packed int4: QTensor weights through the Pallas
                # quant_matmul kernel
                params = pack_params(cfg, params)
            # online R3/R4 Hadamards via the Pallas WHT kernel (TPU fast
            # path), not the dense-matmul reference in core.rotations
            from repro.kernels.hadamard.ops import online_hadamard
            rot = {"r3": online_hadamard, "r4": online_hadamard}
            print(f"calibrated + quantized (W4 "
                  f"{'QDQ' if args.qdq else 'packed'}, rotations fused)")

        def build(prefix_cache: bool, obs=None):
            if _use_paged(args, cfg):
                return PagedServeEngine(cfg, params, rot=rot,
                                        page_size=args.page_size,
                                        a_bits=args.a_bits,
                                        kv_bits=args.kv_bits,
                                        base_seed=base_seed,
                                        prefix_cache=prefix_cache, obs=obs,
                                        **eng_kw)
            return ServeEngine(cfg, params, rot=rot, a_bits=args.a_bits,
                               kv_bits=args.kv_bits,
                               page_size=args.page_size, obs=obs,
                               **(dict(base_seed=base_seed,
                                       prefix_cache=prefix_cache, **eng_kw)
                                  if M.supports_paged(cfg) else eng_kw))
        eng = build(not args.no_prefix_cache, obs=obs)

    def make_requests():
        rng = np.random.default_rng(0)
        # one shared system prompt + per-request divergent suffix: the
        # production traffic shape the prefix cache is for
        sys_prompt = rng.integers(0, cfg.vocab_size, args.shared_prefix)
        # per-request keys derive from the engine base seed + sequence id, so
        # requests sample independently yet replay deterministically
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             rng.integers(0, cfg.vocab_size,
                                          args.prompt_len)]).astype(np.int64),
                        max_new=args.max_new, temperature=args.temperature,
                        top_k=args.top_k, top_p=args.top_p,
                        rep_penalty=args.rep_penalty)
                for _ in range(args.requests)]

    if args.loadgen:
        if args.assert_prefix_parity:
            ap.error("--loadgen and --assert-prefix-parity are separate "
                     "smokes; run them in separate invocations")
        if not hasattr(eng, "serve_open_loop"):
            ap.error("--loadgen needs the paged engine (open-loop admission "
                     "goes through the token scheduler)")
        from repro.serve import LoadSpec, SLO
        from repro.serve.loadgen import run_workload
        spec = LoadSpec(n_requests=args.requests,
                        rate_rps=args.loadgen_rate,
                        prompt_len=(max(1, args.prompt_len // 2),
                                    args.prompt_len),
                        max_new=(max(1, args.max_new // 2), args.max_new),
                        shared_prefix_len=args.shared_prefix,
                        shared_frac=args.loadgen_shared_frac,
                        temperature=args.temperature, top_k=args.top_k,
                        seed=base_seed)
        slo = SLO(ttft_s=args.slo_ttft, itl_p99_s=args.slo_itl_p99)
        obs.start_profile()
        try:
            reqs, stats = run_workload(eng, spec, slo=slo, verbose=True)
        finally:
            obs.stop_profile()
        print(f"[loadgen] offered {spec.rate_rps:.1f} rps, achieved "
              f"{stats['achieved_rps']:.2f} rps; goodput "
              f"{stats['goodput']:.2f} ({stats['n_good']}/"
              f"{stats['n_requests']} within TTFT<={slo.ttft_s}s, "
              f"p99-ITL<={slo.itl_p99_s}s; {stats['ttft_misses']} TTFT / "
              f"{stats['itl_misses']} ITL misses)")
    else:
        obs.start_profile()
        try:
            reqs, stats = eng.generate(make_requests(), verbose=True)
        finally:
            obs.stop_profile()
    done = sum(r.done for r in reqs)
    print(f"[{type(eng).__name__}] served {done}/{len(reqs)} requests; "
          f"{stats['decode_tok_per_s']:.1f} tok/s decode; "
          f"kv cache {stats['kv_cache_bytes']} B; "
          f"weights {stats['weight_bytes']} B")
    if stats.get("tp_devices", 1) > 1:
        print(f"[serve] tp={stats['tp_devices']}: "
              f"{stats['kv_cache_bytes_per_device']} B cache/device, "
              f"{stats['psum_bytes_per_token']} B psum/token")
    if args.dump_tokens:
        with open(args.dump_tokens, "w") as f:
            for r in reqs:
                f.write(" ".join(str(t) for t in r.out) + "\n")
        print(f"[serve] output tokens -> {args.dump_tokens}")
    if "prefix_hit_rate" in stats:
        print(f"[serve] prefix hit rate {stats['prefix_hit_rate']:.2f} "
              f"({stats['prefix_hit_tokens']}/{stats['prompt_tokens']} prompt "
              f"tokens), {stats['cow_copies']} CoW copies, "
              f"{stats['preemptions']} preemptions")

    if args.assert_prefix_parity:
        if "prefix_hit_rate" not in stats or args.no_prefix_cache:
            ap.error("--assert-prefix-parity needs the paged engine with the "
                     "prefix cache enabled")
        base = build(prefix_cache=False)
        base_reqs, base_stats = base.generate(make_requests())
        assert [r.out for r in reqs] == [r.out for r in base_reqs], \
            "prefix-cached outputs diverged from the uncached path"
        assert stats["prefix_hit_rate"] > 0, "no prefix hits recorded"
        assert stats["prefill_tokens"] < base_stats["prefill_tokens"], \
            "prefix cache did not reduce prefilled tokens"
        print(f"[serve] prefix parity OK: {len(reqs)} requests identical "
              f"with the cache off; prefill tokens "
              f"{stats['prefill_tokens']} vs {base_stats['prefill_tokens']}")

    if args.assert_program_cache:
        if not hasattr(eng, "recompile_contract"):
            ap.error("--assert-program-cache needs the paged engine (the "
                     "compile budget is declared per paged program)")
        from repro.analysis import run_contract
        findings = run_contract(eng.recompile_contract())
        for f in findings:
            print(f"[serve] {f}")
        if findings:
            raise SystemExit(1)
        sizes = eng.program_cache_sizes()
        print("[serve] program cache within budget: "
              + ", ".join(f"{k}={v}" for k, v in sorted(sizes.items())))

    if args.metrics_out:
        obs.metrics.write_prom(args.metrics_out)
        print(f"[serve] metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        print(f"[serve] span log -> {args.trace_out}")
    obs.close()
    return reqs, stats


if __name__ == "__main__":
    main()
