"""Serving driver: batched generation over a DartQuant-quantized model.

  # quantize-once → serve-from-artifact (production flow; no calibration here)
  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --out art/
  PYTHONPATH=src python -m repro.launch.serve --artifact art/ --requests 8

  # in-process calibrate-then-serve (dev flow)
  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --requests 8

With ``--artifact`` the engine cold-boots from the saved QuantArtifact —
packed int4/int8 weights straight onto the device, online R3/R4 resolved from
the fused-rotation metadata — and the calibration stack
(``core.calibrate``/``core.qr_orth``) is never invoked.  Default engine is
the paged int4-KV runtime; ``--engine legacy`` selects the lockstep
dense-cache engine (required for MLA/SSM/hybrid/enc-dec families).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve import PagedServeEngine, Request, ServeEngine


def _engine_kind(args, cfg, kv_bits: int) -> bool:
    return args.engine == "paged" or (
        args.engine == "auto" and M.supports_paged(cfg)
        and kv_bits in (4, 8))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--artifact", default=None,
                    help="serve from a saved QuantArtifact directory "
                         "(skips the calibration stack entirely)")
    ap.add_argument("--engine", choices=["paged", "legacy", "auto"],
                    default="auto")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--a-bits", type=int, default=None)
    ap.add_argument("--kv-bits", type=int, default=None)
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--qdq", action="store_true",
                    help="serve fake-quant (QDQ) fp weights instead of "
                         "packed int4 QTensors (in-process flow only)")
    args = ap.parse_args(argv)

    if args.artifact:
        # the artifact snapshot IS the serving config — reject conflicting
        # flags instead of silently ignoring them
        bad = [n for n, v in (("--arch", args.arch),
                              ("--a-bits", args.a_bits),
                              ("--kv-bits", args.kv_bits)) if v is not None]
        bad += [n for n, v in (("--qdq", args.qdq),
                               ("--no-quant", args.no_quant)) if v]
        if bad:
            ap.error(f"{', '.join(bad)} conflict(s) with --artifact: the "
                     "serving config comes from the artifact snapshot "
                     "(re-run repro.launch.quantize to change it)")
    else:
        args.arch = args.arch or "llama2-7b"
        args.a_bits = 8 if args.a_bits is None else args.a_bits
        args.kv_bits = 4 if args.kv_bits is None else args.kv_bits

    max_seq = args.prompt_len + args.max_new * 4
    eng_kw = dict(batch_slots=args.slots, max_seq=max_seq)

    if args.artifact:
        # cold boot: packed weights + rotation metadata from disk; zero calls
        # into core.calibrate/core.qr_orth
        from repro.artifacts import load_artifact
        art = load_artifact(args.artifact)
        cfg = art.cfg
        use_paged = _engine_kind(args, cfg, cfg.quant.kv_bits)
        if use_paged:
            eng = PagedServeEngine.from_artifact(
                art, page_size=args.page_size, **eng_kw)
        else:
            eng = ServeEngine.from_artifact(art, **eng_kw)
        print(f"[serve] cold boot from {args.artifact} "
              f"(rotations: {art.rotations}, meta: {art.meta})")
    else:
        cfg = get_config(args.arch).reduced()
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        rot = None
        if not args.no_quant:
            from repro.core import calibrate_model, fuse_rotations
            from repro.data.pipeline import calibration_batch
            from repro.quant import pack_params, quantize_params
            calib = jnp.asarray(calibration_batch(cfg, 4, 64))
            pack = calibrate_model(cfg, params, calib, key=key, steps=30)
            cfg, params = fuse_rotations(cfg, params, pack)
            if args.qdq:
                params = quantize_params(cfg, params)
            else:
                # true packed int4: QTensor weights through the Pallas
                # quant_matmul kernel
                params = pack_params(cfg, params)
            # online R3/R4 Hadamards via the Pallas WHT kernel (TPU fast
            # path), not the dense-matmul reference in core.rotations
            from repro.kernels.hadamard.ops import online_hadamard
            rot = {"r3": online_hadamard, "r4": online_hadamard}
            print(f"calibrated + quantized (W4 "
                  f"{'QDQ' if args.qdq else 'packed'}, rotations fused)")
        use_paged = _engine_kind(args, cfg, args.kv_bits)
        if use_paged:
            eng = PagedServeEngine(cfg, params, rot=rot,
                                   page_size=args.page_size,
                                   a_bits=args.a_bits, kv_bits=args.kv_bits,
                                   **eng_kw)
        else:
            eng = ServeEngine(cfg, params, rot=rot, a_bits=args.a_bits,
                              kv_bits=args.kv_bits, **eng_kw)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new) for _ in range(args.requests)]
    reqs, stats = eng.generate(reqs, verbose=True)
    done = sum(r.done for r in reqs)
    print(f"[{type(eng).__name__}] served {done}/{len(reqs)} requests; "
          f"{stats['decode_tok_per_s']:.1f} tok/s decode; "
          f"kv cache {stats['kv_cache_bytes']} B; "
          f"weights {stats['weight_bytes']} B")
    return reqs, stats


if __name__ == "__main__":
    main()
