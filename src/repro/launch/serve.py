"""Serving driver: batched generation over a DartQuant-quantized model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --requests 8

Default engine is the paged int4-KV runtime (page-pool cache + token-level
continuous batching + Pallas paged attention); ``--engine legacy`` selects the
lockstep dense-cache engine (required for MLA/SSM/hybrid/enc-dec families).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import calibrate_model, fuse_rotations
from repro.data.pipeline import calibration_batch
from repro.models import model as M
from repro.quant import quantize_params
from repro.serve import PagedServeEngine, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--engine", choices=["paged", "legacy", "auto"],
                    default="auto")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=4)
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)

    rot = None
    if not args.no_quant:
        calib = jnp.asarray(calibration_batch(cfg, 4, 64))
        pack = calibrate_model(cfg, params, calib, key=key, steps=30)
        cfg, params = fuse_rotations(cfg, params, pack)
        params = quantize_params(cfg, params)
        # online R3/R4 Hadamards via the Pallas WHT kernel (TPU fast path),
        # not the dense-matmul reference in core.rotations
        from repro.kernels.hadamard.ops import online_hadamard
        rot = {"r3": online_hadamard, "r4": online_hadamard}
        print("calibrated + quantized (W4, rotations fused)")

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new=args.max_new) for _ in range(args.requests)]
    max_seq = args.prompt_len + args.max_new * 4
    use_paged = args.engine == "paged" or (
        args.engine == "auto" and M.supports_paged(cfg)
        and args.kv_bits in (4, 8))
    if use_paged:
        eng = PagedServeEngine(cfg, params, rot=rot, batch_slots=args.slots,
                               max_seq=max_seq, page_size=args.page_size,
                               a_bits=args.a_bits, kv_bits=args.kv_bits)
    else:
        eng = ServeEngine(cfg, params, rot=rot, batch_slots=args.slots,
                          max_seq=max_seq, a_bits=args.a_bits,
                          kv_bits=args.kv_bits)
    reqs, stats = eng.generate(reqs, verbose=True)
    done = sum(r.done for r in reqs)
    print(f"[{type(eng).__name__}] served {done}/{len(reqs)} requests; "
          f"{stats['decode_tok_per_s']:.1f} tok/s decode; "
          f"kv cache {stats['kv_cache_bytes']} B")


if __name__ == "__main__":
    main()
