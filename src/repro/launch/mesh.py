"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips (v5e pod).  Multi-pod: 2x16x16 = 512
chips; the 'pod' axis composes with 'data' into the FSDP axis, so pods scale
parameter/optimizer sharding without any resharding-logic changes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """All available devices as a 1D data mesh (tests / tiny runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serve_mesh(model: int | None = None):
    """Mesh for tensor-parallel serving: ``model`` devices on the 'model'
    axis (default: every local device), trivial 'data' axis.  The paged
    engine shards weights, pages and SSM state over 'model' and keeps the
    scheduler / prefix index host-side and mesh-oblivious."""
    n = len(jax.devices()) if model is None else model
    return jax.make_mesh((1, n), ("data", "model"))


def make_calib_mesh(data: int | None = None):
    """Mesh for token-sharded calibration: ``data`` devices on the 'data'
    axis (default: every local device = the host mesh), trivial 'model'
    axis.  Calibration only shards tokens, so the production mesh works too
    — the engine uses its data group ('pod' x 'data') and ignores 'model'."""
    if data is None:
        return make_host_mesh()
    return jax.make_mesh((data, 1), ("data", "model"))
