"""End-to-end training driver.

Small-scale (CPU, default): trains a reduced config on the synthetic corpus
with checkpointing + fault tolerance.  Production: pass --production to build
the 16x16 mesh (requires real devices or the dry-run env var).

  PYTHONPATH=src python -m repro.launch.train --arch llama2-7b --steps 200
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models.common import NO_SHARD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--production", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = None
    shd = NO_SHARD
    if args.production:
        from repro.launch.mesh import make_production_mesh
        from repro.dist.sharding import Sharding
        mesh = make_production_mesh()
        shd = Sharding(cfg, mesh)
    else:
        cfg = cfg.reduced()

    from repro.train.trainer import Trainer
    tr = Trainer(cfg, batch_size=args.batch, seq_len=args.seq, lr=args.lr,
                 mesh=mesh, shd=shd, ckpt_dir=args.ckpt_dir,
                 grad_accum=args.grad_accum)
    hist = tr.train(args.steps)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
