"""One-shot quantization driver: calibrate → fuse → pack → save artifact.

  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --out art/

This is the only place the calibration stack runs in the deployment flow —
DartQuant's calibrate-cheap-once story.  The resulting artifact directory
(packed int4/int8 weights + fused-rotation metadata + hash-verified manifest)
cold-boots ``repro.launch.serve --artifact <dir>`` with zero calibration work.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.artifacts import QuantArtifact, rotation_spec, save_artifact
from repro.configs import get_config
from repro.core import calibrate_model, fuse_rotations, random_pack
from repro.data.pipeline import calibration_batch
from repro.models import model as M
from repro.obs import JsonlSink, Obs, Tracer
from repro.obs import quant_health
from repro.quant import memory_bytes, pack_params, projection_weight_bytes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--out", required=True, help="artifact output directory")
    ap.add_argument("--steps", type=int, default=30,
                    help="QR-Orth calibration steps per site")
    ap.add_argument("--calib-seqs", type=int, default=4)
    ap.add_argument("--calib-len", type=int, default=64)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--w-group", type=int, default=-1)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--kv-bits", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rotation", choices=["dart", "hadamard"], default="dart",
                    help="dart = calibrated QR-Orth; hadamard = QuaRot baseline")
    ap.add_argument("--mesh", default=None, metavar="N|auto",
                    help="token-sharded calibration over a data mesh "
                         "('auto' = all local devices); tokens shard, "
                         "latents replicate — see repro.launch.calibrate")
    ap.add_argument("--full", action="store_true",
                    help="use the full config instead of the reduced smoke one")
    ap.add_argument("--override", default=None, metavar="K=V[,K=V...]",
                    help="override int ModelConfig fields after --full/"
                         "reduced resolution (e.g. n_heads=8,n_kv_heads=8,"
                         "head_dim=8 — the TP serve smoke needs head counts "
                         "the mesh divides)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write calib_site spans (JSONL) here")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus metrics snapshot here (also "
                         "arms the QDQ quant-health taps during packing)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace")
    args = ap.parse_args(argv)

    tracer = Tracer(JsonlSink(args.trace_out)) if args.trace_out else None
    obs = Obs(tracer=tracer, profile_dir=args.profile_dir)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_calib_mesh
        mesh = make_calib_mesh(None if args.mesh == "auto" else int(args.mesh))

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if args.override:
        cfg = cfg.replace(**{k: int(v) for k, v in
                             (kv.split("=", 1)
                              for kv in args.override.split(","))})
    qcfg = cfg.quant.replace(w_bits=args.w_bits, w_group_size=args.w_group,
                             a_bits=args.a_bits, kv_bits=args.kv_bits)
    cfg = cfg.replace(quant=qcfg)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    # rotation-pack stream independent of the init stream (repro.analysis
    # prng-reuse: one key, one consumer)
    k_rot = jax.random.fold_in(key, 1)

    t0 = time.perf_counter()
    obs.start_profile()
    try:
        if args.rotation == "dart":
            calib = jnp.asarray(calibration_batch(cfg, args.calib_seqs,
                                                  args.calib_len))
            pack = calibrate_model(cfg, params, calib, key=k_rot,
                                   steps=args.steps, mesh=mesh, obs=obs)
        else:
            pack = random_pack(cfg, k_rot)
        cfg, params = fuse_rotations(cfg, params, pack)
        calib_s = time.perf_counter() - t0

        if args.metrics_out:
            # arm the QDQ taps: packing quantizes every projection weight,
            # so the snapshot carries clip-rate / dynamic-range health
            with quant_health.sampling(obs.metrics):
                packed = pack_params(cfg, params)
                jax.block_until_ready(packed)
        else:
            packed = pack_params(cfg, params)
    finally:
        obs.stop_profile()
    art = QuantArtifact(
        cfg=cfg, params=packed, rotations=rotation_spec(pack),
        meta={"arch": args.arch, "rotation": args.rotation,
              "steps": args.steps, "calib_s": round(calib_s, 3),
              "calib_mesh": args.mesh})
    save_artifact(args.out, art)

    proj, proj_fp16 = projection_weight_bytes(packed)
    print(f"[quantize] {args.arch}: calibrated ({args.rotation}, "
          f"{args.steps} steps) in {calib_s:.1f}s")
    print(f"[quantize] artifact -> {args.out}  "
          f"total {memory_bytes(packed)} B; projection weights {proj} B "
          f"({proj / max(proj_fp16, 1):.2f}x of fp16)")
    if args.metrics_out:
        obs.metrics.write_prom(args.metrics_out)
        print(f"[quantize] metrics snapshot -> {args.metrics_out}")
    if args.trace_out:
        print(f"[quantize] span log -> {args.trace_out}")
    obs.close()
    return art


if __name__ == "__main__":
    main()
