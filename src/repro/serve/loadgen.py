"""Open-loop load generator + goodput/SLO reporting for the paged runtime.

Serving benchmarks that pre-enqueue a request batch measure the engine at
full occupancy from step zero — they never exercise admission under load,
queueing delay, or the latency a user actually sees.  This module generates
the production traffic shape instead:

  * **open-loop Poisson arrivals**: inter-arrival gaps are exponential at a
    configured offered rate, independent of service completions (an
    overloaded server keeps receiving requests — closed-loop generators
    hide overload by self-throttling);
  * **configurable prompt/output length distributions** (inclusive uniform
    ranges), matching the heterogeneous lengths real traffic has;
  * a **shared-prefix traffic mix**: a configurable fraction of requests
    carry the same system prompt (the prefix-cache production shape), the
    rest are fully divergent.

The workload is deterministic under a fixed seed — identical arrival times,
prompts and budgets on every build — so goodput numbers are comparable
across runs and the regression gate (``repro.obs.bench``) can track them.

``run_workload`` drives ``PagedServeEngine.serve_open_loop`` (real admission
through the ``TokenScheduler``, not a pre-enqueued batch) and reports
**goodput**: the fraction of requests that met BOTH the TTFT SLO and the
p99 inter-token-latency SLO.  Throughput without an SLO rewards batching
everything forever; goodput is the number a capacity planner can use.  The
report also publishes into the engine's ``repro.obs`` metrics registry
(``serve_goodput_ratio``, ``serve_slo_*_misses_total``, ``loadgen_*``) so a
``--metrics-out`` snapshot carries it.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Request

__all__ = ["LoadSpec", "SLO", "build_workload", "goodput_report",
           "run_workload", "publish_goodput"]


@dataclass(frozen=True)
class LoadSpec:
    """One load-generation scenario (deterministic given ``seed``)."""
    n_requests: int = 16
    rate_rps: float = 8.0                   # offered (open-loop) arrival rate
    prompt_len: Tuple[int, int] = (8, 24)   # inclusive uniform range
    max_new: Tuple[int, int] = (4, 12)      # inclusive uniform range
    shared_prefix_len: int = 0              # 0 = no shared-prefix traffic
    shared_frac: float = 0.5                # fraction carrying the prefix
    temperature: float = 0.0                # 0 = greedy (parity oracle)
    top_k: int = 0
    seed: int = 0

    def replace(self, **kw) -> "LoadSpec":
        return replace(self, **kw)


@dataclass(frozen=True)
class SLO:
    """Per-request service-level objectives (seconds)."""
    ttft_s: float = 2.0                     # enqueue -> first token
    itl_p99_s: float = 0.5                  # p99 inter-token latency


def _rng_range(rng, lohi: Tuple[int, int]) -> int:
    lo, hi = lohi
    if not 1 <= lo <= hi:
        raise ValueError(f"length range must satisfy 1 <= lo <= hi: {lohi}")
    return int(rng.integers(lo, hi + 1))


def build_workload(spec: LoadSpec, vocab_size: int
                   ) -> List[Tuple[float, Request]]:
    """Materialize ``[(arrival_offset_s, Request)]``, sorted by offset.

    Arrivals are an open-loop Poisson process: exponential inter-arrival
    gaps at ``rate_rps`` (the first request arrives after one gap).  All
    randomness flows from one ``default_rng(seed)`` in a fixed draw order,
    so the workload — times, prompts, budgets, traffic mix — is
    bit-reproducible.
    """
    if spec.n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {spec.n_requests}")
    if spec.rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {spec.rate_rps}")
    if not 0.0 <= spec.shared_frac <= 1.0:
        raise ValueError(f"shared_frac must be in [0, 1], "
                         f"got {spec.shared_frac}")
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.rate_rps, spec.n_requests)
    offsets = np.cumsum(gaps)
    shared = rng.integers(0, vocab_size, spec.shared_prefix_len)
    out: List[Tuple[float, Request]] = []
    for i in range(spec.n_requests):
        plen = _rng_range(rng, spec.prompt_len)
        max_new = _rng_range(rng, spec.max_new)
        use_shared = (spec.shared_prefix_len > 0
                      and float(rng.random()) < spec.shared_frac)
        suffix = rng.integers(0, vocab_size, plen)
        prompt = np.concatenate([shared, suffix]) if use_shared else suffix
        out.append((float(offsets[i]),
                    Request(prompt=prompt.astype(np.int64),
                            max_new=max_new,
                            temperature=spec.temperature,
                            top_k=spec.top_k)))
    return out


def goodput_report(requests: Sequence[Request],
                   latencies: Dict[int, Dict[str, float]],
                   itl_by_rid: Dict[int, List[float]],
                   slo: SLO) -> Dict[str, float]:
    """Score served requests against the SLOs.

    A request is *good* iff it finished, its TTFT met ``slo.ttft_s``, and
    the p99 of its inter-token-latency samples met ``slo.itl_p99_s`` (a
    request with no decode steps beyond the prefill token trivially meets
    the ITL SLO).  ``goodput`` = good / submitted — an unfinished request
    counts against goodput, exactly like a user who never got an answer.
    """
    n = len(requests)
    n_finished = n_good = ttft_misses = itl_misses = 0
    ttfts, itl_p99s = [], []
    for req in requests:
        if not req.done:
            continue
        n_finished += 1
        lat = latencies.get(req.rid)
        ttft = lat["ttft_s"] if lat else float("inf")
        itls = itl_by_rid.get(req.rid, [])
        itl_p99 = float(np.percentile(itls, 99)) if itls else 0.0
        ttfts.append(ttft)
        itl_p99s.append(itl_p99)
        ttft_ok = ttft <= slo.ttft_s
        itl_ok = itl_p99 <= slo.itl_p99_s
        ttft_misses += not ttft_ok
        itl_misses += not itl_ok
        n_good += ttft_ok and itl_ok
    return {
        "n_requests": n,
        "n_finished": n_finished,
        "n_good": n_good,
        "goodput": n_good / max(1, n),
        "slo_ttft_s": slo.ttft_s,
        "slo_itl_p99_s": slo.itl_p99_s,
        "ttft_misses": ttft_misses,
        "itl_misses": itl_misses,
        "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
        "itl_p99_worst_s": max(itl_p99s) if itl_p99s else 0.0,
    }


def publish_goodput(metrics, spec: LoadSpec, slo: SLO,
                    report: Dict[str, float], duration_s: float) -> None:
    """Mirror a goodput report into a ``repro.obs`` metrics registry —
    the loadgen's metric families ride the same Prometheus snapshot as the
    serve stack's."""
    metrics.gauge("serve_goodput_ratio",
                  help="fraction of requests meeting the TTFT and p99-ITL "
                       "SLOs").set(report["goodput"])
    metrics.gauge("serve_slo_ttft_seconds",
                  help="TTFT SLO threshold").set(slo.ttft_s)
    metrics.gauge("serve_slo_itl_p99_seconds",
                  help="p99 inter-token-latency SLO threshold"
                  ).set(slo.itl_p99_s)
    metrics.counter("serve_slo_ttft_misses_total",
                    help="finished requests that missed the TTFT SLO"
                    ).inc(report["ttft_misses"])
    metrics.counter("serve_slo_itl_misses_total",
                    help="finished requests that missed the p99-ITL SLO"
                    ).inc(report["itl_misses"])
    metrics.counter("loadgen_requests_total",
                    help="requests submitted by the load generator"
                    ).inc(report["n_requests"])
    metrics.gauge("loadgen_offered_rps",
                  help="configured open-loop arrival rate"
                  ).set(spec.rate_rps)
    metrics.gauge("loadgen_achieved_rps",
                  help="finished requests / serve duration").set(
                      report["n_finished"] / max(duration_s, 1e-9))


def run_workload(engine, spec: LoadSpec, slo: Optional[SLO] = None,
                 verbose: bool = False):
    """Generate a workload, serve it open-loop, and return
    ``(requests, stats)`` where ``stats`` is the engine's serve stats plus
    the goodput report (``goodput``, SLO miss counts, offered/achieved
    rates).  Metrics are published into ``engine.obs.metrics``."""
    slo = slo if slo is not None else SLO()
    workload = build_workload(spec, engine.cfg.vocab_size)
    reqs, stats = engine.serve_open_loop(workload, verbose=verbose)
    report = goodput_report(reqs, stats["request_latencies"],
                            stats["itl_by_rid"], slo)
    duration = stats["serve_duration_s"]
    publish_goodput(engine.obs.metrics, spec, slo, report, duration)
    stats.update(report)
    stats["offered_rps"] = spec.rate_rps
    stats["achieved_rps"] = report["n_finished"] / max(duration, 1e-9)
    return reqs, stats
