"""Paged serving cache: a refcounted pool of token pages + per-slot state.

Memory for *attention* caches is allocated in fixed-size pages of
``page_size`` tokens (vLLM-style): packed int4/int8 GQA KV codes or MLA
latent rows + fp16 scale/zero in the ``QuantKV`` convention (raw fp16 pages
at ``kv_bits=16``, the compat layout).  *Recurrent* caches (SSM/conv state)
are fixed-size per slot, int8-quantized with fp16 scales.  The device state
is a nested dict — one sub-state per cache adapter
(``repro.serve.cache_adapters``) — whose arrays carry a leading layer dim so
the model's layer scan consumes them as scan xs:

    state["attn"]   GQA:  kq,vq [L,P,T,Hkv,pd]; ks,kz,vs,vz [L,P,T,Hkv]
                    MLA:  cq [L,P,T,pd(kvlr)], rq [L,P,T,pd(r)], cs/cz/rs/rz
    state["ssm"]    cvq [L,S+1,K-1,C], hq [L,S+1,H,P,N] + fp16 scales/zeros

Physical page 0 and physical state slot 0 are reserved *null* targets:
inactive decode slots and out-of-range block-table entries point at them, so
their writes can never clobber a live sequence.  The host-side allocator
hands out pages 1..P-1 and keeps per-sequence block tables; state slots map
1:1 to scheduler slots (slot i -> physical i+1).

Pages are *refcounted* so shared-prompt traffic can map one physical page
into many sequences (``prefix_cache=True`` plus a ``PrefixIndex`` over page
contents).  Every page 1..P-1 is in exactly one of three states:

    free         in ``_free``: unreferenced, not indexed — allocatable
    cached-free  in ``_cached_free``: unreferenced but still in the prefix
                 index — matchable, reclaimed LRU-last when ``_free`` runs dry
    referenced   ``_ref[p] >= 1``: mapped by that many live sequences;
                 refcount 1 with a single mapper = privately owned,
                 refcount >= 2 = shared read-only

The conservation invariant (property-tested) is

    len(_free) + len(_cached_free) + len(_ref) == num_pages - 1

``admit_seq`` maps a new sequence onto the pool: the longest indexed prompt
prefix rides existing pages (refcount bump), the last partially-filled
prefix page is copied-on-write (the sequence must append into it), and only
the divergent suffix gets fresh pages.  Admission reserves *prompt* pages
only; decode-time pages come from ``grow_seq`` on demand (the scheduler
preempts a victim when growth fails).  Prefix caching is enabled only when
every adapter is page-backed — recurrent-state families (SSM/hybrid) must
recompute their prefix to rebuild slot state, so skipping prefill would be
wrong, not just slow.

``nbytes`` is the bytes actually held on device — the serve engine reports it
instead of a dense-cache estimate.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.obs import Obs
from repro.serve.cache_adapters import adapters_for
from repro.serve.prefix_index import PrefixIndex


class PagePool:
    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_seq: int, kv_bits: int = 4, state_bits: int = 8,
                 n_slots: int = 1, prefix_cache: bool = False,
                 obs: Optional[Obs] = None):
        self.adapters = adapters_for(cfg, kv_bits=kv_bits,
                                     state_bits=state_bits)
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_bits = kv_bits
        self.state_bits = state_bits
        self.n_slots = n_slots
        self.has_pages = any(a.needs_pages for a in self.adapters.values())
        self.max_pages_per_seq = -(-max_seq // page_size) if self.has_pages \
            else 1
        # prefix caching needs every cache page-backed: a matched prefix skips
        # prefill, and recurrent families need that prefill to rebuild slot
        # state — for them the index must stay off, not just miss.
        pageable = self.has_pages and all(
            a.needs_pages for a in self.adapters.values())
        self.prefix: Optional[PrefixIndex] = \
            PrefixIndex(page_size) if (prefix_cache and pageable) else None
        self.state: Dict[str, dict] = {
            name: (ad.init_state(num_pages, page_size) if ad.needs_pages
                   else ad.init_state(n_slots))
            for name, ad in self.adapters.items()}
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._cached_free: Dict[int, None] = {}     # refcount-0, still indexed
        self._ref: Dict[int, int] = {}              # page -> live refcount
        self._owned: Dict[int, List[int]] = {}      # seq_id -> physical pages
        # one metrics surface (repro.obs): CoW/eviction counters live in the
        # registry; occupancy/refcount states publish as collect-time gauges
        self.obs = obs if obs is not None else Obs()
        m = self.obs.metrics
        self._c_cow = m.counter(
            "serve_cow_copies_total",
            help="shared pages copied-on-write at admission")
        self._c_evict = m.counter(
            "serve_prefix_evictions_total",
            help="cached-free pages reclaimed from the prefix index")
        m.gauge("serve_pages_total",
                help="allocatable pages (null page excluded)").set(
                    num_pages - 1)
        m.gauge("serve_pages_free",
                help="allocatable: truly free + cached-free").set_fn(
                    lambda: self.free_pages)
        m.gauge("serve_pages_cached_free",
                help="refcount-0 pages parked in the prefix index").set_fn(
                    lambda: len(self._cached_free))
        m.gauge("serve_pages_owned",
                help="pages mapped by exactly one sequence").set_fn(
                    lambda: self.owned_pages)
        m.gauge("serve_pages_shared",
                help="read-only pages mapped by >= 2 sequences").set_fn(
                    lambda: self.shared_pages)

    # counters kept as attribute views for compat with pre-obs callers
    @property
    def cow_copies(self) -> int:
        return int(self._c_cow.value)

    @property
    def evictions(self) -> int:
        return int(self._c_evict.value)

    # ---------------------------------------------------------------- alloc
    @property
    def free_pages(self) -> int:
        """Allocatable pages: truly free + cached-but-unreferenced (the
        latter are reclaimed by evicting their prefix-index entry)."""
        return len(self._free) + len(self._cached_free)

    @property
    def owned_pages(self) -> int:
        return sum(1 for c in self._ref.values() if c == 1)

    @property
    def shared_pages(self) -> int:
        return sum(1 for c in self._ref.values() if c >= 2)

    def pages_for(self, n_tokens: int) -> int:
        """Pages a sequence of ``n_tokens`` needs; 0 for pure-recurrent
        models (their state is fixed-size per slot, not per token)."""
        if not self.has_pages:
            return 0
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        n = self.pages_for(n_tokens)
        return n <= self.free_pages and n <= self.max_pages_per_seq

    def _take_page(self) -> int:
        """Pop a free page, evicting from the prefix index if necessary.
        Eviction prefers leaf nodes (keeps ancestor chains matchable) and
        then least-recently-matched; the evicted node's whole subtree leaves
        the index — its refcount-0 pages become plain free."""
        if self._free:
            return self._free.pop()
        if self._cached_free:
            page = min(self._cached_free,
                       key=lambda p: (self.prefix.node_for(p).has_children,
                                      self.prefix.node_for(p).last_use))
            for dropped in self.prefix.remove(page):
                if dropped in self._cached_free:
                    del self._cached_free[dropped]
                    if dropped != page:
                        self._free.append(dropped)
            self._c_evict.inc()
            return page
        raise MemoryError(f"pool exhausted: 0 of {self.num_pages - 1} free")

    def _ref_page(self, page: int) -> None:
        self._ref[page] = self._ref.get(page, 0) + 1
        if self._ref[page] == 1:
            # a cached-free page coming back live is no longer reclaimable
            self._cached_free.pop(page, None)

    def _unref_page(self, page: int) -> None:
        count = self._ref.get(page, 0)
        if count <= 0:
            raise ValueError(f"page {page} freed with refcount 0")
        if count == 1:
            del self._ref[page]
            if self.prefix is not None and page in self.prefix:
                self._cached_free[page] = None      # retained for future hits
            else:
                self._free.append(page)
        else:
            self._ref[page] = count - 1

    def alloc_seq(self, seq_id: int, n_tokens: int) -> List[int]:
        """Reserve pages covering ``n_tokens`` for a new sequence (no prefix
        mapping — ``admit_seq`` is the sharing-aware entry point)."""
        if seq_id in self._owned:
            raise ValueError(f"seq {seq_id} already holds pages")
        n = self.pages_for(n_tokens)
        if n > self.max_pages_per_seq:
            raise ValueError(f"seq of {n_tokens} tokens exceeds max_seq")
        if n > self.free_pages:
            raise MemoryError(f"pool exhausted: want {n}, free {self.free_pages}")
        pages = []
        for _ in range(n):
            p = self._take_page()
            self._ref_page(p)
            pages.append(p)
        self._owned[seq_id] = pages
        return pages

    def admit_seq(self, seq_id: int, prompt: Sequence[int]) \
            -> Optional[Tuple[int, List[Tuple[int, int]]]]:
        """Map a new sequence onto the pool with prefix sharing.

        Matches the prompt against the prefix index; fully matched pages are
        mapped read-only (refcount bump), a partially matched boundary page
        is scheduled for copy-on-write (the sequence appends into it), and
        the remaining prompt pages are allocated fresh.  Only *prompt* pages
        are reserved — decode growth is on-demand via ``grow_seq``.

        Returns ``(cached_len, copy_ops)`` — the engine prefills only
        ``prompt[cached_len:]`` after applying each ``(src, dst)`` device
        page copy — or ``None`` when the fresh pages don't fit right now.
        Copy ops must be applied before the next pool mutation (the source
        page is only pinned for the duration of this call).
        """
        if seq_id in self._owned:
            raise ValueError(f"seq {seq_id} already holds pages")
        n_total = self.pages_for(len(prompt))
        if n_total > self.max_pages_per_seq:
            raise ValueError(f"seq of {len(prompt)} tokens exceeds max_seq")
        T = self.page_size
        matched_pages: List[int] = []
        matched = 0
        if self.prefix is not None:
            matched_pages, matched = self.prefix.match(prompt)
        # the prompt tail must be prefilled even on a full match: sampling the
        # first output token needs the tail logits
        usable = min(matched, len(prompt) - 1) if len(prompt) else 0
        w = usable // T                 # logical index of the first written page
        shared = matched_pages[:w]      # fully used, stay read-only
        cow_src = matched_pages[w] if usable % T != 0 else None
        # pin matched pages *before* taking fresh ones — _take_page eviction
        # must never reclaim the pages this admission is about to map
        for p in shared:
            self._ref_page(p)
        if cow_src is not None:
            self._ref_page(cow_src)
        if n_total - w > self.free_pages:
            for p in shared:            # roll back: admission doesn't fit yet
                self._unref_page(p)
            if cow_src is not None:
                self._unref_page(cow_src)
            return None
        pages = list(shared)
        copy_ops: List[Tuple[int, int]] = []
        try:
            if cow_src is not None:
                dst = self._take_page()
                self._ref_page(dst)
                pages.append(dst)
                copy_ops.append((cow_src, dst))
                self._c_cow.inc()
            for _ in range(n_total - len(pages)):
                p = self._take_page()
                self._ref_page(p)
                pages.append(p)
        finally:
            if cow_src is not None:
                self._unref_page(cow_src)   # pinned only across allocation
        self._owned[seq_id] = pages
        return usable, copy_ops

    def grow_seq(self, seq_id: int) -> bool:
        """Append one on-demand page to a running sequence.  Returns False
        when the pool is exhausted (the scheduler then preempts a victim)."""
        pages = self._owned[seq_id]
        if len(pages) >= self.max_pages_per_seq:
            raise ValueError(f"seq {seq_id} already at the max_seq page cap")
        if self.free_pages == 0:
            return False
        p = self._take_page()
        self._ref_page(p)
        pages.append(p)
        return True

    def seq_page_count(self, seq_id: int) -> int:
        return len(self._owned[seq_id])

    def register_prefix(self, seq_id: int, prompt: Sequence[int]) -> int:
        """Index this sequence's prompt pages (post-prefill, content valid).
        The partially filled tail page is registered too — its registered
        offsets are never rewritten (decode appends land past them)."""
        if self.prefix is None:
            return 0
        return self.prefix.register(prompt, self._owned[seq_id], len(prompt))

    def free_seq(self, seq_id: int) -> None:
        # strict pop: a double free / unknown id is a scheduler bug that must
        # surface here, not later as cross-request page reuse (admission
        # records every sequence, pageless families included).  Unref'd pages
        # still in the prefix index park in _cached_free for future hits.
        for page in self._owned.pop(seq_id):
            self._unref_page(page)

    # ---------------------------------------------------------- block tables
    def block_table_row(self, seq_id: int) -> np.ndarray:
        """[max_pages_per_seq] int32; unallocated logical pages -> null page 0."""
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        pages = self._owned.get(seq_id, [])
        row[:len(pages)] = pages
        return row

    # ---------------------------------------------------------------- bytes
    @property
    def nbytes(self) -> int:
        return sum(ad.nbytes(self.state[name])
                   for name, ad in self.adapters.items())

    @property
    def nbytes_by_kind(self) -> Dict[str, int]:
        return {name: ad.nbytes(self.state[name])
                for name, ad in self.adapters.items()}

    @property
    def predicted_nbytes(self) -> int:
        return sum(
            (ad.predicted_nbytes(self.num_pages, self.page_size)
             if ad.needs_pages else ad.predicted_nbytes(self.n_slots))
            for ad in self.adapters.values())

    # ------------------------------------------------------------- tensor TP
    def partition_specs(self, tp: int = 1) -> Dict[str, dict]:
        """Per-adapter PartitionSpec trees for the serve shard_map (GQA KV
        pages split heads over 'model'; MLA latent and SSM state replicate)."""
        return {name: ad.partition_specs(tp)
                for name, ad in self.adapters.items()}

    def nbytes_per_device(self, tp: int = 1) -> int:
        """Bytes ONE device holds under tp-way model-axis sharding."""
        return sum(ad.nbytes_per_device(self.state[name], tp)
                   for name, ad in self.adapters.items())
