"""Paged int4 KV cache: a fixed pool of token pages + per-sequence block tables.

Memory is allocated in fixed-size pages of ``page_size`` tokens (vLLM-style),
stored in the ``QuantKV`` integer format (packed int4/int8 codes + fp16
scale/zero per (token, head)).  The device state is a flat dict of arrays with
a leading layer dim so the model's layer scan consumes it as scan xs:

    kq, vq:  [L, num_pages, page_size, Hkv, packed_dim(hd, bits)]  uint8
    ks, kz,
    vs, vz:  [L, num_pages, page_size, Hkv]                        fp16

Physical page 0 is a reserved *null page*: inactive decode slots and
out-of-range block-table entries point at it, so their writes can never
clobber a live sequence.  The host-side allocator hands out pages 1..P-1 and
keeps per-sequence block tables (logical page order -> physical page id).

``nbytes`` is the bytes actually held on device — the serve engine reports it
instead of a dense-cache estimate.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.quant.kv_cache import packed_dim, paged_kv_bytes


class PagePool:
    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_seq: int, kv_bits: int = 4):
        if cfg.attn_type != "gqa" or cfg.family not in ("dense", "moe") \
                or cfg.is_encoder_decoder:
            raise NotImplementedError(
                f"paged KV cache supports dense GQA models, not {cfg.arch_id}")
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_bits = kv_bits
        self.max_pages_per_seq = -(-max_seq // page_size)
        L, H, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        pd = packed_dim(hd, kv_bits)
        codes = (L, num_pages, page_size, H, pd)
        meta = (L, num_pages, page_size, H)
        self.state: Dict[str, jnp.ndarray] = {
            "kq": jnp.zeros(codes, jnp.uint8),
            "ks": jnp.zeros(meta, jnp.float16),
            "kz": jnp.zeros(meta, jnp.float16),
            "vq": jnp.zeros(codes, jnp.uint8),
            "vs": jnp.zeros(meta, jnp.float16),
            "vz": jnp.zeros(meta, jnp.float16),
        }
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}      # seq_id -> physical pages

    # ---------------------------------------------------------------- alloc
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        n = self.pages_for(n_tokens)
        return n <= len(self._free) and n <= self.max_pages_per_seq

    def alloc_seq(self, seq_id: int, n_tokens: int) -> List[int]:
        """Reserve pages covering ``n_tokens`` for a new sequence."""
        if seq_id in self._owned:
            raise ValueError(f"seq {seq_id} already holds pages")
        n = self.pages_for(n_tokens)
        if n > self.max_pages_per_seq:
            raise ValueError(f"seq of {n_tokens} tokens exceeds max_seq")
        if n > len(self._free):
            raise MemoryError(f"pool exhausted: want {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._owned[seq_id] = pages
        return pages

    def free_seq(self, seq_id: int) -> None:
        self._free.extend(self._owned.pop(seq_id))

    # ---------------------------------------------------------- block tables
    def block_table_row(self, seq_id: int) -> np.ndarray:
        """[max_pages_per_seq] int32; unallocated logical pages -> null page 0."""
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        pages = self._owned.get(seq_id, [])
        row[:len(pages)] = pages
        return row

    # ---------------------------------------------------------------- bytes
    @property
    def nbytes(self) -> int:
        return sum(int(x.size) * x.dtype.itemsize for x in self.state.values())

    @property
    def predicted_nbytes(self) -> int:
        cfg = self.cfg
        return paged_kv_bytes(self.num_pages, self.page_size, cfg.n_layers,
                              cfg.n_kv_heads, cfg.resolved_head_dim,
                              self.kv_bits)
