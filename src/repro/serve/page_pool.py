"""Paged serving cache: a fixed pool of token pages + per-slot state slots.

Memory for *attention* caches is allocated in fixed-size pages of
``page_size`` tokens (vLLM-style): packed int4/int8 GQA KV codes or MLA
latent rows + fp16 scale/zero in the ``QuantKV`` convention (raw fp16 pages
at ``kv_bits=16``, the compat layout).  *Recurrent* caches (SSM/conv state)
are fixed-size per slot, int8-quantized with fp16 scales.  The device state
is a nested dict — one sub-state per cache adapter
(``repro.serve.cache_adapters``) — whose arrays carry a leading layer dim so
the model's layer scan consumes them as scan xs:

    state["attn"]   GQA:  kq,vq [L,P,T,Hkv,pd]; ks,kz,vs,vz [L,P,T,Hkv]
                    MLA:  cq [L,P,T,pd(kvlr)], rq [L,P,T,pd(r)], cs/cz/rs/rz
    state["ssm"]    cvq [L,S+1,K-1,C], hq [L,S+1,H,P,N] + fp16 scales/zeros

Physical page 0 and physical state slot 0 are reserved *null* targets:
inactive decode slots and out-of-range block-table entries point at them, so
their writes can never clobber a live sequence.  The host-side allocator
hands out pages 1..P-1 and keeps per-sequence block tables; state slots map
1:1 to scheduler slots (slot i -> physical i+1).

``nbytes`` is the bytes actually held on device — the serve engine reports it
instead of a dense-cache estimate.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.cache_adapters import adapters_for


class PagePool:
    def __init__(self, cfg: ModelConfig, *, num_pages: int, page_size: int,
                 max_seq: int, kv_bits: int = 4, state_bits: int = 8,
                 n_slots: int = 1):
        self.adapters = adapters_for(cfg, kv_bits=kv_bits,
                                     state_bits=state_bits)
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the null page)")
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_bits = kv_bits
        self.state_bits = state_bits
        self.n_slots = n_slots
        self.has_pages = any(a.needs_pages for a in self.adapters.values())
        self.max_pages_per_seq = -(-max_seq // page_size) if self.has_pages \
            else 1
        self.state: Dict[str, dict] = {
            name: (ad.init_state(num_pages, page_size) if ad.needs_pages
                   else ad.init_state(n_slots))
            for name, ad in self.adapters.items()}
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}      # seq_id -> physical pages

    # ---------------------------------------------------------------- alloc
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        """Pages a sequence of ``n_tokens`` needs; 0 for pure-recurrent
        models (their state is fixed-size per slot, not per token)."""
        if not self.has_pages:
            return 0
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        n = self.pages_for(n_tokens)
        return n <= len(self._free) and n <= self.max_pages_per_seq

    def alloc_seq(self, seq_id: int, n_tokens: int) -> List[int]:
        """Reserve pages covering ``n_tokens`` for a new sequence."""
        if seq_id in self._owned:
            raise ValueError(f"seq {seq_id} already holds pages")
        n = self.pages_for(n_tokens)
        if n > self.max_pages_per_seq:
            raise ValueError(f"seq of {n_tokens} tokens exceeds max_seq")
        if n > len(self._free):
            raise MemoryError(f"pool exhausted: want {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._owned[seq_id] = pages
        return pages

    def free_seq(self, seq_id: int) -> None:
        # strict pop: a double free / unknown id is a scheduler bug that must
        # surface here, not later as cross-request page reuse (alloc_seq
        # records every admitted sequence, pageless families included)
        self._free.extend(self._owned.pop(seq_id))

    # ---------------------------------------------------------- block tables
    def block_table_row(self, seq_id: int) -> np.ndarray:
        """[max_pages_per_seq] int32; unallocated logical pages -> null page 0."""
        row = np.zeros((self.max_pages_per_seq,), np.int32)
        pages = self._owned.get(seq_id, [])
        row[:len(pages)] = pages
        return row

    # ---------------------------------------------------------------- bytes
    @property
    def nbytes(self) -> int:
        return sum(ad.nbytes(self.state[name])
                   for name, ad in self.adapters.items())

    @property
    def nbytes_by_kind(self) -> Dict[str, int]:
        return {name: ad.nbytes(self.state[name])
                for name, ad in self.adapters.items()}

    @property
    def predicted_nbytes(self) -> int:
        return sum(
            (ad.predicted_nbytes(self.num_pages, self.page_size)
             if ad.needs_pages else ad.predicted_nbytes(self.n_slots))
            for ad in self.adapters.values())
