"""Per-layer cache adapters: one paged runtime for every decoder family.

An adapter owns one *kind* of per-layer serving cache — its device layout,
its byte accounting, and the traced per-layer step that reads/writes it:

  * ``GQAPages``       — the paged int4/int8 KV cache (fp16 pages at bits=16),
                         attended by the Pallas paged-attention kernel.
  * ``MLALatentPages`` — paged MLA latent cache: pages hold one quantized
                         ``c_kv`` row + one rope-key row per token (the
                         absorbed-decode form), attended by the Pallas
                         ``paged_mla_attention`` kernel path.
  * ``SSMStatePool``   — per-slot fixed-size recurrent state (conv window +
                         SSD state), int8 codes + fp16 scale/zero in the
                         QuantKV convention (raw f32 at bits>=16).

Protocol (all state-changing methods are pure and trace-safe):

    init_state(geometry)                  -> dict of arrays, leading layer dim
    init_slot(state, phys_slot)           -> state with that slot zeroed
    init_carry()                          -> fp32 prefill carry (or None)
    attend_or_mix(p, x, state_l, carry_l, ctx, ...) -> (out, state_l, carry_l)
    commit(state, carry, phys_slot)       -> state (prefill carry -> pool)
    nbytes(state) / predicted_nbytes(...) -> bytes the arrays actually hold

``attend_or_mix`` dispatches on the ctx type: a ``DecodeCtx`` steps one token
per slot against the pool; a ``PrefillCtx`` processes one prompt chunk of a
single sequence.  Chunked prefill carries recurrent state in fp32 through the
carry (no per-chunk requantization); ``commit`` quantizes it into the slot
exactly once at the prefill->decode handoff, so paged serving matches a
one-shot legacy reference to f32 reduction order.

The byte-accounting contract is uniform: ``nbytes`` equals the bytes the
arrays actually hold, physical page 0 / state slot 0 are reserved null
targets for idle-slot writes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import NO_SHARD
from repro.quant.kv_cache import (latent_bytes, packed_dim, paged_kv_bytes,
                                  quantize_kv, ssm_state_bytes)


class DecodeCtx(NamedTuple):
    """Per-step routing for a [slots]-batched decode: idle slots carry
    length 0 and point at null page 0 / null state slot 0."""
    block_tables: jax.Array        # [B, Pmax] int32
    positions: jax.Array           # [B] int32 per-slot write position
    lengths: jax.Array             # [B] int32 valid tokens after the write
    state_slots: jax.Array         # [B] int32 physical state slot (0 = null)


class PrefillCtx(NamedTuple):
    """One chunk of one admitted prompt (chunked prefill into owned pages).

    ``chunk_len`` is the number of *real* tokens in the chunk: positions past
    it are padding — attention caches may write them (decode overwrites
    before any read), recurrent state must not advance through them.
    """
    block_table: jax.Array         # [1, Pmax] int32
    start: jax.Array               # scalar int32 chunk offset
    chunk_len: jax.Array           # scalar int32 valid tokens in the chunk
    n_pages: Optional[int] = None  # static page prefix covering the chunk


def _state_nbytes(state: dict) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(state))


def _sharded_nbytes(state: dict, specs: Dict[str, P], tp: int) -> int:
    """Bytes ONE device holds: leaves whose spec carries the 'model' axis
    count 1/tp of their global size, replicated leaves count in full."""
    total = 0
    for name, x in state.items():
        div = tp if any(ax == "model" for ax in specs[name] if ax) else 1
        total += int(x.size) * x.dtype.itemsize // div
    return total


def _quant_rows(x: jax.Array, bits: int):
    """Per-row (last-axis) QuantKV codes; scale/zero squeezed to row shape."""
    q = quantize_kv(x, bits)
    return q.q, q.scale[..., 0], q.zero[..., 0]


def _dequant_rows(codes, scale, zero, bits: int, dim: int,
                  dtype=jnp.float32):
    from repro.kernels.paged_attn.ref import dequant_codes
    return dequant_codes(codes, scale, zero, bits=bits, head_dim=dim,
                         dtype=dtype)


# --------------------------------------------------------------------------- #
# (a) paged GQA KV pages
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class GQAPages:
    cfg: ModelConfig
    kv_bits: int = 4
    n_layers: int = 0              # 0 -> cfg.n_layers

    kind = "gqa-pages"
    needs_pages = True

    @property
    def layers(self) -> int:
        return self.n_layers or self.cfg.n_layers

    def init_state(self, num_pages: int, page_size: int) -> dict:
        cfg = self.cfg
        L, H, hd = self.layers, cfg.n_kv_heads, cfg.resolved_head_dim
        if self.kv_bits >= 16:
            shape = (L, num_pages, page_size, H, hd)
            return {"k": jnp.zeros(shape, jnp.float16),
                    "v": jnp.zeros(shape, jnp.float16)}
        pd = packed_dim(hd, self.kv_bits)
        codes = (L, num_pages, page_size, H, pd)
        meta = (L, num_pages, page_size, H)
        return {"kq": jnp.zeros(codes, jnp.uint8),
                "ks": jnp.zeros(meta, jnp.float16),
                "kz": jnp.zeros(meta, jnp.float16),
                "vq": jnp.zeros(codes, jnp.uint8),
                "vs": jnp.zeros(meta, jnp.float16),
                "vz": jnp.zeros(meta, jnp.float16)}

    def nbytes(self, state: dict) -> int:
        return _state_nbytes(state)

    def predicted_nbytes(self, num_pages: int, page_size: int) -> int:
        cfg = self.cfg
        return paged_kv_bytes(num_pages, page_size, self.layers,
                              cfg.n_kv_heads, cfg.resolved_head_dim,
                              self.kv_bits)

    def partition_specs(self, tp: int = 1) -> Dict[str, P]:
        """Pool specs over the mesh 'model' axis: KV pages split their head
        axis (each shard attends its own kv heads — the psum at the output
        projection reassembles), scale/zero meta splits alongside."""
        if tp <= 1:
            return ({"k": P(), "v": P()} if self.kv_bits >= 16 else
                    {k: P() for k in ("kq", "ks", "kz", "vq", "vs", "vz")})
        if self.cfg.n_kv_heads % tp:
            raise ValueError(
                f"serve TP: {self.cfg.arch_id}: n_kv_heads = "
                f"{self.cfg.n_kv_heads} is not divisible by the model-axis "
                f"size {tp}")
        codes = P(None, None, None, "model", None)   # [L,P,T,H,·]
        meta = P(None, None, None, "model")          # [L,P,T,H]
        if self.kv_bits >= 16:
            return {"k": codes, "v": codes}
        return {"kq": codes, "ks": meta, "kz": meta,
                "vq": codes, "vs": meta, "vz": meta}

    def nbytes_per_device(self, state: dict, tp: int = 1) -> int:
        return _sharded_nbytes(state, self.partition_specs(tp), tp)

    def init_slot(self, state: dict, phys_slot) -> dict:
        return state               # pages are write-before-read; length-masked

    def init_carry(self):
        return None                # KV pages are written as chunks arrive

    def commit(self, state: dict, carry, phys_slot) -> dict:
        return state

    def copy_page(self, state: dict, src, dst) -> dict:
        """Copy-on-write: duplicate one physical page (all layers, codes and
        scale/zero meta alike — the copy is bit-exact by construction)."""
        return {k: v.at[:, dst].set(v[:, src]) for k, v in state.items()}

    def write_decode(self, state_l: dict, k: jax.Array, v: jax.Array,
                     pages: jax.Array, offs: jax.Array) -> dict:
        """Quantize one token's k,v [N,H,hd] rows into pages[N]/offs[N]."""
        from repro.models.attention import _write_kv_pages
        return _write_kv_pages(state_l, k, v, pages, offs, self.kv_bits)

    write_prefill_chunk = write_decode   # same scatter, [C] rows at once

    def attend_or_mix(self, p: dict, x: jax.Array, state_l: dict, carry_l,
                      ctx, *, window=0, shd=NO_SHARD, rot=None):
        from repro.models import attention as attn_mod
        if isinstance(ctx, PrefillCtx):
            out, new_state = attn_mod.paged_gqa_prefill_chunk(
                self.cfg, p, x, state_l, ctx.block_table, ctx.start,
                window=window, shd=shd, rot=rot, kv_bits=self.kv_bits,
                n_pages=ctx.n_pages)
        else:
            out, new_state = attn_mod.paged_gqa_decode(
                self.cfg, p, x, state_l, ctx.block_tables, ctx.positions,
                ctx.lengths, window=window, shd=shd, rot=rot,
                kv_bits=self.kv_bits)
        return out, new_state, carry_l


# --------------------------------------------------------------------------- #
# (b) paged MLA latent pages
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MLALatentPages:
    cfg: ModelConfig
    kv_bits: int = 4
    n_layers: int = 0

    kind = "mla-latent-pages"
    needs_pages = True

    @property
    def layers(self) -> int:
        return self.n_layers or self.cfg.n_layers

    def init_state(self, num_pages: int, page_size: int) -> dict:
        cfg = self.cfg
        L, kvlr, rope = self.layers, cfg.kv_lora_rank, cfg.qk_rope_head_dim
        if self.kv_bits >= 16:
            return {"ckv": jnp.zeros((L, num_pages, page_size, kvlr),
                                     jnp.float16),
                    "krope": jnp.zeros((L, num_pages, page_size, rope),
                                       jnp.float16)}
        meta = (L, num_pages, page_size)
        return {"cq": jnp.zeros(meta + (packed_dim(kvlr, self.kv_bits),),
                                jnp.uint8),
                "cs": jnp.zeros(meta, jnp.float16),
                "cz": jnp.zeros(meta, jnp.float16),
                "rq": jnp.zeros(meta + (packed_dim(rope, self.kv_bits),),
                                jnp.uint8),
                "rs": jnp.zeros(meta, jnp.float16),
                "rz": jnp.zeros(meta, jnp.float16)}

    def nbytes(self, state: dict) -> int:
        return _state_nbytes(state)

    def predicted_nbytes(self, num_pages: int, page_size: int) -> int:
        cfg = self.cfg
        return latent_bytes(num_pages * page_size, self.layers,
                            cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                            self.kv_bits)

    def partition_specs(self, tp: int = 1) -> Dict[str, P]:
        """Latent pages REPLICATE: c_kv comes off the replicated ``wkv_a``
        projection, so every shard computes the identical row and writes the
        identical page — queries shard over heads instead (``wq_b``) and
        attend the full latent locally.  Replication is what keeps the
        absorbed-decode write deterministic across shards."""
        keys = (("ckv", "krope") if self.kv_bits >= 16 else
                ("cq", "cs", "cz", "rq", "rs", "rz"))
        return {k: P() for k in keys}

    def nbytes_per_device(self, state: dict, tp: int = 1) -> int:
        return _state_nbytes(state)

    def init_slot(self, state: dict, phys_slot) -> dict:
        return state

    def init_carry(self):
        return None

    def commit(self, state: dict, carry, phys_slot) -> dict:
        return state

    def copy_page(self, state: dict, src, dst) -> dict:
        """Copy-on-write: duplicate one physical latent page (all layers)."""
        return {k: v.at[:, dst].set(v[:, src]) for k, v in state.items()}

    def write_decode(self, state_l: dict, c_kv: jax.Array, k_rope: jax.Array,
                     pages: jax.Array, offs: jax.Array) -> dict:
        """Quantize latent rows c_kv [N,kvlr] + k_rope [N,r] into pages."""
        from repro.models.attention import _write_latent_pages
        return _write_latent_pages(state_l, c_kv, k_rope, pages, offs,
                                   self.kv_bits)

    write_prefill_chunk = write_decode

    def attend_or_mix(self, p: dict, x: jax.Array, state_l: dict, carry_l,
                      ctx, *, window=0, shd=NO_SHARD, rot=None):
        from repro.models import attention as attn_mod
        if isinstance(ctx, PrefillCtx):
            out, new_state = attn_mod.paged_mla_prefill_chunk(
                self.cfg, p, x, state_l, ctx.block_table, ctx.start,
                window=window, shd=shd, rot=rot, kv_bits=self.kv_bits,
                n_pages=ctx.n_pages)
        else:
            out, new_state = attn_mod.paged_mla_decode(
                self.cfg, p, x, state_l, ctx.block_tables, ctx.positions,
                ctx.lengths, window=window, shd=shd, rot=rot,
                kv_bits=self.kv_bits)
        return out, new_state, carry_l


# --------------------------------------------------------------------------- #
# (c) SSM / conv recurrent-state pool (per slot, fixed size)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class SSMStatePool:
    cfg: ModelConfig
    state_bits: int = 8
    n_layers: int = 0

    kind = "ssm-state-pool"
    needs_pages = False

    @property
    def layers(self) -> int:
        return self.n_layers or self.cfg.n_layers

    def _dims(self):
        cfg = self.cfg
        return (cfg.ssm_conv - 1, cfg.conv_dim, cfg.ssm_nheads,
                cfg.ssm_head_dim, cfg.ssm_state)

    def init_state(self, n_slots: int) -> dict:
        """Slot-indexed state arrays; physical slot 0 is the null slot idle
        decode lanes write to (mirrors the pool's null page 0)."""
        L, S1 = self.layers, n_slots + 1
        K1, C, H, P, N = self._dims()
        if self.state_bits >= 16:
            return {"conv": jnp.zeros((L, S1, K1, C), jnp.float32),
                    "h": jnp.zeros((L, S1, H, P, N), jnp.float32)}
        return {"cvq": jnp.zeros((L, S1, K1, packed_dim(C, self.state_bits)),
                                 jnp.uint8),
                "cvs": jnp.zeros((L, S1, K1), jnp.float16),
                "cvz": jnp.zeros((L, S1, K1), jnp.float16),
                "hq": jnp.zeros((L, S1, H, P, packed_dim(N, self.state_bits)),
                                jnp.uint8),
                "hs": jnp.zeros((L, S1, H, P), jnp.float16),
                "hz": jnp.zeros((L, S1, H, P), jnp.float16)}

    def nbytes(self, state: dict) -> int:
        return _state_nbytes(state)

    def predicted_nbytes(self, n_slots: int) -> int:
        K1, C, H, P, N = self._dims()
        return ssm_state_bytes(n_slots + 1, self.layers, K1, C, H, P, N,
                               self.state_bits)

    def partition_specs(self, tp: int = 1) -> Dict[str, P]:
        """SSM state REPLICATES under TP: the Mamba2 gated output norm spans
        the full d_inner (``rmsnorm(y * silu(z))``), so sharding the heads
        would force a second per-layer psum before it — against the
        one-psum-per-layer contract — and the in_proj segment layout is not
        contiguously shardable anyway.  Mamba blocks run whole per shard;
        only attention (and FFN/MoE when eligible) shard."""
        keys = (("conv", "h") if self.state_bits >= 16 else
                ("cvq", "cvs", "cvz", "hq", "hs", "hz"))
        return {k: P() for k in keys}

    def nbytes_per_device(self, state: dict, tp: int = 1) -> int:
        return _state_nbytes(state)

    def init_slot(self, state: dict, phys_slot) -> dict:
        return {k: v.at[:, phys_slot].set(jnp.zeros_like(v[:, 0]))
                for k, v in state.items()}

    def init_carry(self) -> dict:
        """fp32 single-sequence prefill state, stacked over layers."""
        L = self.layers
        K1, C, H, P, N = self._dims()
        return {"conv": jnp.zeros((L, 1, K1, C), jnp.float32),
                "h": jnp.zeros((L, 1, H, P, N), jnp.float32)}

    # ---- slot read/write (the QuantKV round trip) ----------------------- #
    def read_slots(self, state_l: dict, slots: jax.Array) -> dict:
        """state_l (one layer) + slots [B] -> {'conv' [B,K1,C], 'h' [B,H,P,N]}."""
        K1, C, H, P, N = self._dims()
        if self.state_bits >= 16:
            return {"conv": state_l["conv"][slots], "h": state_l["h"][slots]}
        conv = _dequant_rows(state_l["cvq"][slots], state_l["cvs"][slots],
                             state_l["cvz"][slots], self.state_bits, C)
        h = _dequant_rows(state_l["hq"][slots], state_l["hs"][slots],
                          state_l["hz"][slots], self.state_bits, N)
        return {"conv": conv, "h": h}

    def write_slots(self, state_l: dict, slots: jax.Array,
                    new: dict) -> dict:
        """Quantize {'conv','h'} (leading slot batch) and scatter at slots."""
        if self.state_bits >= 16:
            return {"conv": state_l["conv"].at[slots].set(
                        new["conv"].astype(jnp.float32)),
                    "h": state_l["h"].at[slots].set(
                        new["h"].astype(jnp.float32))}
        cq, cs, cz = _quant_rows(new["conv"].astype(jnp.float32),
                                 self.state_bits)
        hq, hs, hz = _quant_rows(new["h"].astype(jnp.float32),
                                 self.state_bits)
        return {"cvq": state_l["cvq"].at[slots].set(cq),
                "cvs": state_l["cvs"].at[slots].set(cs),
                "cvz": state_l["cvz"].at[slots].set(cz),
                "hq": state_l["hq"].at[slots].set(hq),
                "hs": state_l["hs"].at[slots].set(hs),
                "hz": state_l["hz"].at[slots].set(hz)}

    write_decode = write_slots          # protocol alias: per-step state write

    def commit(self, state: dict, carry: dict, phys_slot) -> dict:
        """Quantize the fp32 prefill carry into the slot — the single
        quantization event at the prefill->decode handoff."""
        conv = carry["conv"][:, 0]                     # [L,K1,C]
        h = carry["h"][:, 0]                           # [L,H,P,N]
        if self.state_bits >= 16:
            return {"conv": state["conv"].at[:, phys_slot].set(conv),
                    "h": state["h"].at[:, phys_slot].set(h)}
        cq, cs, cz = _quant_rows(conv, self.state_bits)
        hq, hs, hz = _quant_rows(h, self.state_bits)
        return {"cvq": state["cvq"].at[:, phys_slot].set(cq),
                "cvs": state["cvs"].at[:, phys_slot].set(cs),
                "cvz": state["cvz"].at[:, phys_slot].set(cz),
                "hq": state["hq"].at[:, phys_slot].set(hq),
                "hs": state["hs"].at[:, phys_slot].set(hs),
                "hz": state["hz"].at[:, phys_slot].set(hz)}

    def copy_page(self, state: dict, src, dst) -> dict:
        """Recurrent state is per-slot, not per-page: CoW doesn't apply."""
        return state

    def attend_or_mix(self, p: dict, x: jax.Array, state_l: dict, carry_l,
                      ctx, *, window=0, shd=NO_SHARD, rot=None):
        from repro.models import ssm as ssm_mod
        if isinstance(ctx, PrefillCtx):
            # prefill state flows through the fp32 carry; the pool slot is
            # written once by commit() after the last chunk.  chunk padding
            # must not advance the recurrence (valid_len mask).
            out, new_carry = ssm_mod.mamba2_prefill_chunk(
                self.cfg, p, x, carry_l, shd=shd, valid_len=ctx.chunk_len)
            return out, state_l, new_carry
        cache = self.read_slots(state_l, ctx.state_slots)
        out, new = ssm_mod.mamba2_decode(self.cfg, p, x, cache, shd=shd)
        return out, self.write_slots(state_l, ctx.state_slots, new), carry_l


# --------------------------------------------------------------------------- #
# Factory: which adapters a config's layer stack needs
# --------------------------------------------------------------------------- #
def adapters_for(cfg: ModelConfig, *, kv_bits: int = 4,
                 state_bits: int = 8) -> dict:
    """Sub-state name -> adapter for every decoder family the paged runtime
    serves.  Keys match the nested layout of ``PagePool.state``:

        single attention stacks (dense/moe/vlm):
            {'attn': GQAPages | MLALatentPages}          [n_layers]
        mixed dense+MoE (deepseek/grok1-style):
            {'attn_dense': ..., 'attn_moe': ...}         [prefix] / [rest]
            (two sub-states so the layer scans consume them without
            slice/concat copies — pool donation keeps aliasing)
        ssm:    {'ssm': SSMStatePool}                    [n_layers]
        hybrid: {'ssm': SSMStatePool,                    [all mamba layers]
                 'attn': GQAPages}                       [one per group]
    """
    if cfg.is_encoder_decoder:
        raise NotImplementedError(
            f"{cfg.arch_id} (family={cfg.family}, encoder-decoder): the paged "
            "runtime covers decoder-only models — use the legacy lockstep "
            "ServeEngine")
    if cfg.family == "ssm":
        return {"ssm": SSMStatePool(cfg, state_bits)}
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        return {"ssm": SSMStatePool(cfg, state_bits, n_layers=cfg.n_layers),
                "attn": GQAPages(cfg, kv_bits,
                                 n_layers=cfg.n_layers // every)}
    attn_cls = MLALatentPages if cfg.attn_type == "mla" else GQAPages
    if cfg.n_experts and cfg.n_dense_layers:
        nd = cfg.n_dense_layers
        return {"attn_dense": attn_cls(cfg, kv_bits, n_layers=nd),
                "attn_moe": attn_cls(cfg, kv_bits,
                                     n_layers=cfg.n_layers - nd)}
    return {"attn": attn_cls(cfg, kv_bits)}
