"""Radix/trie prefix index over page contents (vLLM-style prefix caching).

The index maps chains of page-granular token chunks to the physical pages
that already hold their (quantized) KV/latent rows, so admission can map a
request's shared prompt prefix onto existing read-only pages instead of
re-prefilling and re-storing identical content.  The quantized-page layout
makes each shared page 4x the effective tokens per byte of a vLLM-style
fp16 page.

Structure: a trie whose edges are token tuples.  A *full* node holds exactly
``page_size`` tokens and may have children (the chain continues); a *partial*
node holds the tail of some registered prompt (< page_size tokens) and is
always a leaf.  Matching walks full nodes exactly, then closes with the
longest common prefix against any sibling (full or partial) — a sharer may
use a strict prefix of a cached page because attention reads are
length-masked: offsets past the match are never read.

Content contract (enforced by the pool/scheduler, not here):

  * a registered page's offsets ``[0, len(node.tokens))`` hold the KV of
    exactly those tokens at those absolute positions and are never
    rewritten — the registering sequence's later decode writes land only at
    offsets >= ``len(node.tokens)`` (disjoint, never read through the index);
  * a sequence that must *write* inside the registered range (the last,
    partially-filled prefix page) copies the page first (CoW, handled at
    admission by ``PagePool.admit_seq``);
  * eviction removes a node *and its subtree* — children become unreachable
    from the root, so a stale parent can never vouch for them.

The index is pure host logic; physical page 0 (the null page) never appears.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("tokens", "page", "children", "parent", "last_use")

    def __init__(self, tokens: tuple, page: int, parent: Optional["_Node"],
                 last_use: int):
        self.tokens = tokens
        self.page = page
        self.children: Dict[tuple, "_Node"] = {}
        self.parent = parent
        self.last_use = last_use

    @property
    def has_children(self) -> bool:
        return bool(self.children)


class PrefixIndex:
    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root: Dict[tuple, _Node] = {}
        self.by_page: Dict[int, _Node] = {}     # physical page -> its node
        self._tick = 0

    def __len__(self) -> int:
        return len(self.by_page)

    def __contains__(self, page: int) -> bool:
        return page in self.by_page

    def node_for(self, page: int) -> Optional[_Node]:
        return self.by_page.get(page)

    # ------------------------------------------------------------------ match
    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``tokens``.

        Returns ``(pages, matched)``: the physical pages covering the first
        ``matched`` tokens, in logical order.  All pages but the last are
        fully matched ``page_size`` chunks; the last may be a partial match
        (the caller reads only the matched offsets).
        """
        toks = [int(t) for t in tokens]
        T = self.page_size
        self._tick += 1
        pages: List[int] = []
        matched = 0
        children = self.root
        while matched < len(toks):
            rem = toks[matched:]
            node = None
            if len(rem) >= T:
                node = children.get(tuple(rem[:T]))
            if node is not None:                # exact full-page hop
                node.last_use = self._tick
                pages.append(node.page)
                matched += T
                children = node.children
                continue
            # close with the longest common prefix against any sibling —
            # partial use of a cached page is safe (length-masked reads)
            best, best_c = None, 0
            for key, child in children.items():
                c = 0
                for a, b in zip(key, rem):
                    if a != b:
                        break
                    c += 1
                if c > best_c:
                    best, best_c = child, c
            if best is not None:
                best.last_use = self._tick
                pages.append(best.page)
                matched += best_c
            break
        return pages, matched

    # --------------------------------------------------------------- register
    def register(self, tokens: Sequence[int], pages: Sequence[int],
                 n_tokens: int) -> int:
        """Index ``pages`` as holding ``tokens[:n_tokens]`` (page-chunked).

        Existing nodes are deduplicated (the first registrant's page stays
        authoritative); descent continues only through full nodes.  Returns
        the number of newly indexed pages.
        """
        toks = [int(t) for t in tokens[:n_tokens]]
        T = self.page_size
        self._tick += 1
        children = self.root
        parent: Optional[_Node] = None
        added = 0
        for i, page in enumerate(pages):
            chunk = tuple(toks[i * T:(i + 1) * T])
            if not chunk:
                break
            node = children.get(chunk)
            if node is None:
                if page in self.by_page:        # already indexed elsewhere
                    break
                node = _Node(chunk, int(page), parent, self._tick)
                children[chunk] = node
                self.by_page[int(page)] = node
                added += 1
            node.last_use = self._tick
            if len(chunk) < T:
                break                           # partial tail: always a leaf
            children = node.children
            parent = node
        return added

    # ----------------------------------------------------------------- evict
    def remove(self, page: int) -> List[int]:
        """Drop the node holding ``page`` and its whole subtree (children of
        an evicted page are unreachable from the root and must not linger).
        Returns every page released from the index, ``page`` included."""
        node = self.by_page.get(page)
        if node is None:
            return []
        siblings = node.parent.children if node.parent is not None else self.root
        siblings.pop(node.tokens, None)
        dropped: List[int] = []
        stack = [node]
        while stack:
            n = stack.pop()
            self.by_page.pop(n.page, None)
            dropped.append(n.page)
            stack.extend(n.children.values())
        return dropped
