"""Serving runtime: paged quantized caches + token-level scheduler + engines.

Per-layer cache behaviour (GQA KV pages, MLA latent pages, SSM state slots)
is supplied by the adapters in ``repro.serve.cache_adapters`` — one paged
runtime for every decoder-only family.
"""
from repro.serve.cache_adapters import (DecodeCtx, GQAPages, MLALatentPages,
                                        PrefillCtx, SSMStatePool,
                                        adapters_for)
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.loadgen import LoadSpec, SLO, build_workload, run_workload
from repro.serve.page_pool import PagePool
from repro.serve.prefix_index import PrefixIndex
from repro.serve.scheduler import SeqState, TokenScheduler
