"""Serving runtime: paged int4 KV cache + token-level scheduler + engines."""
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.page_pool import PagePool
from repro.serve.scheduler import SeqState, TokenScheduler
