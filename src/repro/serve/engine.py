"""Batched serving engine over a (quantized, rotated) model.

Pipeline: quantize/fuse offline -> prefill the prompt batch -> lockstep decode
with slot-based continuous batching (finished sequences are replaced by queued
requests between decode steps).  The rot context carries the online R3/R4
Hadamards + KV-quant hook, so the engine serves exactly the paper's Fig. 9
data path (W4 weights, A-quant at linears, 4-bit KV).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.common import NO_SHARD
from repro.quant import act_quant, fake_quant_act, kv_bytes, make_kv_quant
from repro.quant.context import set_act_quant


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, rot=None, mesh=None,
                 shd=NO_SHARD, batch_slots: int = 4, max_seq: int = 256,
                 a_bits: int = 16, kv_bits: int = 16, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.a_bits = a_bits
        rot = dict(rot or {})
        if kv_bits < 16 and rot.get("kv_quant") is None:
            rot["kv_quant"] = make_kv_quant(kv_bits)
        self.rot = rot
        self.kv_bits = kv_bits

        aq = (lambda x: fake_quant_act(x, a_bits)) if a_bits < 16 else None
        set_act_quant(aq)
        try:
            from repro.train import steps as S
            self._prefill = jax.jit(S.build_prefill(cfg, mesh=mesh, shd=shd,
                                                    rot=self.rot))
            self._decode = jax.jit(S.build_decode_step(cfg, mesh=mesh,
                                                       shd=shd, rot=self.rot))
        finally:
            set_act_quant(None)
        self._aq = aq

    # ------------------------------------------------------------------ #
    def generate(self, requests: List[Request], verbose: bool = False):
        """Serve a request list with slot-based continuous batching."""
        cfg = self.cfg
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        # all prompts padded to the same length for lockstep prefill
        plen = max(len(r.prompt) for r in queue)
        B = self.slots

        def take():
            return queue.pop(0) if queue else None

        for i in range(B):
            active[i] = take()

        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(active):
            if r is not None:
                toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        # grow cache to max_seq
        cache = jax.tree.map(
            lambda x: (jnp.pad(x, [(0, 0)] * 2
                               + [(0, self.max_seq - x.shape[2])]
                               + [(0, 0)] * (x.ndim - 3))
                       if x.ndim >= 3 and x.shape[2] == plen else x), cache)
        prefill_s = time.time() - t0

        last = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        pos = plen
        n_tokens = 0
        t0 = time.time()
        while any(r is not None for r in active) and pos < self.max_seq:
            logits, cache = self._decode(self.params, last[:, None], cache,
                                         jnp.int32(pos))
            nxt = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)
            nxt_np = np.array(nxt)   # writable copy (slot refill overwrites)
            for i, r in enumerate(active):
                if r is None:
                    continue
                r.out.append(int(nxt_np[i]))
                n_tokens += 1
                if len(r.out) >= r.max_new:
                    r.done = True
                    active[i] = take()   # continuous batching: refill slot
                    if active[i] is not None:
                        # new request decodes from its prompt tail token
                        nxt_np[i] = active[i].prompt[-1]
            last = jnp.asarray(nxt_np)
            pos += 1
        decode_s = time.time() - t0
        stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tokens / max(decode_s, 1e-9),
            "kv_cache_bytes": kv_bytes(
                B, self.max_seq, cfg.n_layers, max(cfg.n_kv_heads, 1),
                cfg.resolved_head_dim or 1, self.kv_bits),
        }
        if verbose:
            print(stats)
        return requests, stats
