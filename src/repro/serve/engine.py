"""Serving engines over a (quantized, rotated) model.

``PagedServeEngine`` is the runtime for *every* decoder-only family: a paged
quantized cache pool (``repro.serve.page_pool``) whose per-layer behaviour is
supplied by cache adapters (``repro.serve.cache_adapters``) — GQA KV pages,
MLA latent pages, SSM/conv state slots — a token-level continuous-batching
scheduler (``repro.serve.scheduler``) with chunked prefill, prefix caching
(shared prompts ride refcounted read-only pages with copy-on-write of the
boundary page; only the divergent suffix is prefilled) and on-demand page
growth with preemption-with-requeue, and the Pallas paged-attention kernels
(``repro.kernels.paged_attn``).  All jitted shapes
are fixed by the engine geometry (slots, page count, page size, chunk), so
one engine compiles a handful of programs — the calibrate-on-deploy flow
reuses them across repeat deployments.

Sampling is per request: greedy argmax by default, or temperature/top-k with
a per-request PRNG key threaded through the scheduler (deterministic replay:
the step key is the request key folded with the absolute position).

``ServeEngine`` is a thin compat wrapper that forwards every decoder-only
family to ``PagedServeEngine``; the legacy lockstep dense-cache loop is kept
verbatim only for encoder-decoder models (which the paged runtime does not
cover).  The lockstep slot refill is request-granular and does NOT prefill
the refilled prompt — a known correctness bug the paged engine fixes by
construction.

Observability (``repro.obs``): both engines take an ``obs=`` bundle —
metrics are always on (plain host counters/histograms: step-timing
percentiles, token totals, the scheduler/pool counters all share one
registry), per-request span tracing and ``jax.profiler`` annotation are
opt-in.  Timing uses ``time.perf_counter`` (monotonic — wall-clock NTP steps
must not corrupt prefill/decode intervals) and fences with
``block_until_ready`` where a bracket would otherwise measure async dispatch
instead of device time.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.common import NO_SHARD
from repro.obs import Obs
from repro.quant import fake_quant_act, kv_bytes, make_kv_quant, memory_bytes
from repro.serve.page_pool import PagePool
from repro.serve.scheduler import Request, SeqState, TokenScheduler

__all__ = ["Request", "ServeEngine", "PagedServeEngine"]


def _act_quant_hook(a_bits: int):
    return (lambda x: fake_quant_act(x, a_bits)) if a_bits < 16 else None


def _from_artifact(cls, artifact, paged: bool, **kw):
    """Cold-boot an engine from a QuantArtifact: packed weights on device,
    online rotations resolved from metadata, serving bits from the config
    snapshot — zero calls into the calibration stack."""
    from repro.artifacts.format import resolve_rotations
    cfg = artifact.cfg
    qc = cfg.quant
    if paged and not M.supports_paged(cfg):
        raise NotImplementedError(
            f"artifact config {cfg.arch_id} (family={cfg.family}"
            f"{', encoder-decoder' if cfg.is_encoder_decoder else ''}) is not "
            "covered by the paged runtime; fall back to the legacy lockstep "
            "engine: ServeEngine.from_artifact(...)")
    kw.setdefault("rot", resolve_rotations(artifact.rotations))
    kw.setdefault("a_bits", qc.a_bits)
    if paged and "kv_bits" not in kw and cfg.attn_type != "none" \
            and qc.kv_bits not in (4, 8):
        raise ValueError(
            f"artifact snapshot has kv_bits={qc.kv_bits}; the paged engine "
            "stores integer KV pages by default — pass kv_bits=4/8 (or 16 "
            "for raw fp16 pages) explicitly, or use the ServeEngine wrapper")
    kw.setdefault("kv_bits", qc.kv_bits)
    from repro.dist.sharding import tp_degree
    if tp_degree(kw.get("mesh")) > 1:
        # tensor-parallel cold boot: hand the engine the HOST mmap views —
        # PagedServeEngine places each leaf shard-wise off the artifact
        # (make_array_from_callback), so no device ever holds a full
        # projection weight
        return cls(cfg, artifact.params, **kw)
    params = jax.device_put(artifact.params)    # one transfer off the mmap
    return cls(cfg, params, **kw)


MAX_REP_HISTORY = 64     # repetition-penalty window (tokens per request)


def _build_sampler(vocab: int):
    """Per-slot sampling: greedy at temperature 0 (the oracle), else
    repetition penalty -> top-k -> top-p (nucleus) -> temperature softmax,
    keyed by the request key folded with the absolute position
    (deterministic replay: replaying a preempted request rebuilds the same
    history and keys, hence the same tokens).

    ``hist`` rows hold the last ``MAX_REP_HISTORY`` prompt+output tokens,
    padded with ``vocab`` (one past the real ids, scattered with
    mode='drop').  top_p=1.0 / rep_pen=1.0 are exact no-ops, so the default
    path is bit-identical to plain temperature/top-k sampling."""
    def sample(logits, temps, top_ks, top_ps, rep_pens, hist, keys,
               positions):
        lg = logits[:, 0, :vocab].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1)

        def one(lg_b, t, k, p, rp, h, key, pos):
            key = jax.random.fold_in(key, pos)
            # repetition penalty (CTRL): damp every token in the history —
            # divide positive logits, multiply negative ones
            seen = jnp.zeros((vocab,), bool).at[h].set(True, mode="drop")
            pen = jnp.where(lg_b > 0, lg_b / rp, lg_b * rp)
            lg_b = jnp.where(seen & (rp != 1.0), pen, lg_b)
            # top-k: k <= 0 means unrestricted
            kk = jnp.where(k > 0, k, vocab)
            srt = jnp.sort(lg_b)[::-1]                      # descending
            thresh = srt[jnp.clip(kk - 1, 0, vocab - 1)]
            lg_b = jnp.where(lg_b >= thresh, lg_b, -jnp.inf)
            # top-p over the survivors: keep the smallest prefix of the
            # descending distribution with mass >= p (the top token always
            # survives: its exclusive prefix mass is 0 < p)
            ps = jax.nn.softmax(lg_b)
            order = jnp.argsort(-lg_b)
            ps_sorted = ps[order]
            excl = jnp.cumsum(ps_sorted) - ps_sorted        # exclusive prefix
            keep = jnp.zeros((vocab,), bool).at[order].set(excl < p)
            lg_b = jnp.where(keep | (p >= 1.0), lg_b, -jnp.inf)
            return jax.random.categorical(key, lg_b / jnp.maximum(t, 1e-6))

        sampled = jax.vmap(one)(lg, temps, top_ks, top_ps, rep_pens, hist,
                                keys, positions.astype(jnp.uint32))
        return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
    return sample


class PagedServeEngine:
    """Paged serving runtime for every decoder-only family (W4 weights via
    params, A-quant hook, quantized KV/latent pages + int8 state slots,
    online R3/R4 via the rot context)."""

    def __init__(self, cfg: ModelConfig, params, rot=None, mesh=None,
                 shd=NO_SHARD, batch_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 a_bits: int = 16, kv_bits: int = 4, state_bits: int = 8,
                 base_seed: int = 0, prefix_cache: bool = True,
                 obs: Optional[Obs] = None):
        if kv_bits not in (4, 8, 16):
            raise ValueError("paged cache stores quantized KV (kv_bits 4/8) "
                             "or raw fp16 pages (kv_bits 16)")
        if not M.supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.arch_id} (family={cfg.family}"
                f"{', encoder-decoder' if cfg.is_encoder_decoder else ''}) "
                "is not covered by the paged runtime; fall back to the "
                "legacy lockstep engine: ServeEngine")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.kv_bits = kv_bits
        self.state_bits = state_bits
        self.base_seed = base_seed
        self.prefill_chunk = prefill_chunk or page_size
        self.rot = dict(rot or {})
        if num_pages is None:
            # every slot can hold a full-length sequence, + the null page
            num_pages = batch_slots * -(-max_seq // page_size) + 1
        # one Obs per engine: the pool's occupancy gauges, the scheduler's
        # lifecycle counters/spans and the step-timing histograms below all
        # publish into the same registry/tracer
        self.obs = obs if obs is not None else Obs()
        m = self.obs.metrics
        self._h_prefill = m.histogram(
            "serve_prefill_seconds",
            help="per-sequence chunked-prefill duration (device-fenced)")
        self._h_decode = m.histogram(
            "serve_decode_step_seconds",
            help="one batched decode step (all running slots)")
        self._h_itl = m.histogram(
            "serve_itl_seconds",
            help="inter-token latency: decode-step time per running request")
        self._c_prefill_s = m.counter("serve_prefill_seconds_total")
        self._c_decode_s = m.counter("serve_decode_seconds_total")
        self._c_prefill_tok = m.counter(
            "serve_prefill_tokens_total",
            help="tokens actually prefilled (prefix-cache hits excluded)")
        self._c_decode_tok = m.counter("serve_decode_tokens_total")
        self.pool = PagePool(cfg, num_pages=num_pages, page_size=page_size,
                             max_seq=max_seq, kv_bits=kv_bits,
                             state_bits=state_bits, n_slots=batch_slots,
                             prefix_cache=prefix_cache, obs=self.obs)
        self._has_state = any(not a.needs_pages
                              for a in self.pool.adapters.values())

        # tensor parallelism: a mesh with a non-trivial 'model' axis turns
        # the decode/prefill programs into one shard_map over that axis.
        # Params land shard-wise (host leaves — e.g. artifact mmap views —
        # are read block-by-block per device), KV pages split their head
        # axis, and the scheduler/prefix/CoW machinery above stays entirely
        # mesh-oblivious.
        from repro.dist.sharding import (place_serve_params, place_serve_pool,
                                         serve_tp_plan)
        self.tp_plan = serve_tp_plan(cfg, params, mesh, rot=self.rot,
                                     kv_bits=kv_bits, state_bits=state_bits) \
            if mesh is not None else None
        self.tp = self.tp_plan.tp if self.tp_plan is not None else 1
        if self.tp_plan is not None:
            self.params = place_serve_params(params, self.tp_plan)
            self.pool.state = place_serve_pool(self.pool.state, self.tp_plan)
            mesh, shd = None, NO_SHARD      # the shard_map owns the mesh
        elif not isinstance(jax.tree_util.tree_leaves(params)[0], jax.Array):
            self.params = jax.device_put(params)    # host views, tp=1 boot

        from repro.train import steps as S
        aq = _act_quant_hook(a_bits)
        # donate the pool state (arg 2 / arg 0): the step's output pool
        # aliases the input buffers instead of copying the whole pool every
        # token.  CPU XLA has no donation — skip it there to avoid warnings.
        cpu = jax.default_backend() == "cpu"
        donate = () if cpu else (2,)
        qkw = dict(kv_bits=kv_bits, state_bits=state_bits)
        self._prefill = jax.jit(S.build_paged_prefill_chunk(
            cfg, mesh=mesh, shd=shd, rot=self.rot, act_quant=aq,
            tp_plan=self.tp_plan, **qkw),
            donate_argnums=donate, static_argnums=(7,))
        # the raw (unjitted) decode step stays addressable for the analysis
        # contracts: they re-trace/re-lower it on demand (make_jaxpr,
        # donation lowering) without touching the serving jit's cache
        self._decode_fn = S.build_paged_decode_step(
            cfg, mesh=mesh, shd=shd, rot=self.rot, act_quant=aq,
            tp_plan=self.tp_plan, **qkw)
        self._decode = jax.jit(self._decode_fn, donate_argnums=donate)
        pool_donate = () if cpu else (0,)
        self._commit = jax.jit(S.build_paged_commit(cfg, **qkw),
                               donate_argnums=pool_donate)
        self._init_slot = jax.jit(S.build_paged_init_slot(cfg, **qkw),
                                  donate_argnums=pool_donate)
        self._copy_page = jax.jit(S.build_paged_copy_page(cfg, **qkw),
                                  donate_argnums=pool_donate)
        self._sample = jax.jit(_build_sampler(cfg.vocab_size))
        # greedy fast path: the default serving mode (and the test oracle)
        # must not pay the sampler's full-vocab sort per slot per step
        self._greedy = jax.jit(
            lambda lg: jnp.argmax(lg[:, 0, :cfg.vocab_size], -1)
            .astype(jnp.int32))

    @classmethod
    def from_artifact(cls, artifact, **kw) -> "PagedServeEngine":
        return _from_artifact(cls, artifact, paged=True, **kw)

    # ------------------------------------------------------------------ #
    # Analysis contracts (repro.analysis): the engine owns its compiled
    # programs, so it declares the invariants they must satisfy — pytest
    # and the CI gate consume these, never re-deriving them per-test.
    # ------------------------------------------------------------------ #
    def _decode_example_args(self):
        """Arguments shaped like one decode step on this engine's geometry
        (the same tuple the serve loop passes), for tracing/lowering."""
        B = self.slots
        tokens = jnp.zeros((B, 1), jnp.int32)
        tables = jnp.zeros((B, max(self.pool.max_pages_per_seq, 1)),
                           jnp.int32)
        vec = jnp.zeros((B,), jnp.int32)
        return (self.params, tokens, self.pool.state, tables, vec, vec, vec)

    def program_cache_sizes(self) -> Dict[str, int]:
        """Live jit-cache entry counts per compiled program."""
        progs = {"prefill": self._prefill, "decode": self._decode,
                 "commit": self._commit, "init_slot": self._init_slot,
                 "copy_page": self._copy_page, "sample": self._sample,
                 "greedy": self._greedy}
        return {k: v._cache_size() for k, v in progs.items()}

    def compile_budget(self) -> Dict[str, tuple]:
        """Expected jit-cache entry counts after serving any workload on
        this (fixed) geometry: decode compiles exactly once — more means
        the cache key leaked a traced-value dependency and every step
        recompiles; prefill compiles once per distinct chunk page count
        (``n_pages`` is a static arg, bounded by the pool geometry)."""
        # sample/greedy run at two geometries: the B=1 prefill tail sample
        # and the batched decode step
        return {"decode": (1, 1),
                "prefill": (1, max(self.pool.max_pages_per_seq, 1)),
                "commit": (0, 1), "init_slot": (0, 1), "copy_page": (0, 1),
                "sample": (0, 2), "greedy": (0, 2)}

    def recompile_contract(self, expect=None, *,
                           name: str = "serve/recompile"):
        """Recompilation sentinel over the live program caches; ``expect``
        overrides :meth:`compile_budget` (values: exact int or
        ``(min, max)``)."""
        from repro.analysis.rules import Contract, RecompileCount
        return Contract(
            name=name, owner="repro.serve.engine",
            checks=(RecompileCount(expect or self.compile_budget()),),
            live=self.program_cache_sizes,
            description="each program compiles within its geometry budget")

    def analysis_contracts(self, include_recompile: bool = False) -> list:
        """Contracts over this engine's decode program.

        Always: the donation audit (pool-state buffers must alias outputs
        when donated).  When the params carry packed ``QTensor`` payloads:
        the dtype-promotion audit.  When quant-health is disarmed and span
        tracing off: the zero-host-callback guarantee.  Under a TP plan on
        a single-stack GQA family: the one-psum-per-layer census declared
        by ``repro.models.common``.
        """
        from repro.analysis.jaxpr_lint import packed_payload_indices
        from repro.analysis.rules import Contract, DonationAliased, \
            PackedDtypeAudit
        from repro.models.common import tp_decode_collective_contract
        from repro.obs import quant_health

        args = self._decode_example_args()

        def trace():
            return jax.make_jaxpr(self._decode_fn)(*args)

        def lower():
            return jax.jit(self._decode_fn, donate_argnums=(2,)).lower(*args)

        out = []
        if self.tp_plan is not None:
            try:
                out.append(tp_decode_collective_contract(
                    self.cfg, self.tp_plan, trace))
            except ValueError:
                pass    # mixed stack: no structural census declared
        if not quant_health.armed() and not self.obs.tracing:
            out.append(quant_health.disarmed_callback_contract(
                "serve/disarmed-obs", trace, owner="repro.serve.engine"))
        if packed_payload_indices(args):
            out.append(Contract(
                name="serve/packed-dtype", owner="repro.serve.engine",
                checks=(PackedDtypeAudit(payload_args=lambda: args),),
                trace=trace,
                description="packed weights stay integer outside the "
                            "sanctioned dequant sites; f32 accumulation"))
        if self.tp_plan is None:
            # single-program lowering records accepted donations as
            # tf.aliasing_output even on CPU; the multi-device shard_map
            # lowering drops them there, so the TP engine declares no
            # donation contract (the invariant is backend-visible only on
            # accelerators)
            out.append(Contract(
                name="serve/donation", owner="repro.serve.engine",
                checks=(DonationAliased(min_aliased=len(
                    jax.tree_util.tree_leaves(self.pool.state))),),
                lower=lower,
                description="donated pool-state buffers alias step outputs"))
        if include_recompile:
            out.append(self.recompile_contract())
        return out

    # ------------------------------------------------------------------ #
    def _sample_one(self, seq: SeqState, logits_row, pos: int) -> int:
        """Sample one token from a [V']-row with the request's parameters."""
        r = seq.req
        if r.temperature <= 0:
            return int(self._greedy(jnp.asarray(logits_row)[None, None])[0])
        hist = np.full((1, MAX_REP_HISTORY), self.cfg.vocab_size, np.int32)
        tail = (list(r.prompt) + list(r.out))[-MAX_REP_HISTORY:]
        hist[0, :len(tail)] = tail
        tok = self._sample(
            jnp.asarray(logits_row)[None, None],
            jnp.asarray([r.temperature], jnp.float32),
            jnp.asarray([r.top_k], jnp.int32),
            jnp.asarray([r.top_p], jnp.float32),
            jnp.asarray([r.rep_penalty], jnp.float32),
            jnp.asarray(hist),
            jnp.asarray(seq.key_data[None]),
            jnp.asarray([pos], jnp.int32))
        return int(tok[0])

    def _prefill_seq(self, seq: SeqState) -> int:
        """Chunked prefill of one admitted prompt into its pages, starting
        past the prefix-cache hit (``seq.cached_len`` tokens already sit in
        shared pages; the boundary page is CoW-copied first, so every write
        below lands in a private page).  Chunk attention reads the whole
        page prefix, so the cached tokens are attended without being
        recomputed.  Returns the first generated token (prompt-tail
        sample); the fp32 recurrent carry is committed to the state slot at
        the end (state families never take the cached shortcut)."""
        cfg = self.cfg
        for src, dst in seq.cow_ops:
            self.pool.state = self._copy_page(
                self.pool.state, jnp.int32(src), jnp.int32(dst))
        seq.cow_ops = []
        prompt = np.asarray(seq.req.prompt, np.int32)
        C = self.prefill_chunk
        table = jnp.asarray(self.pool.block_table_row(seq.seq_id)[None])
        first = 0
        T = self.pool.page_size
        carry = M.init_prefill_carry(cfg, kv_bits=self.kv_bits,
                                     state_bits=self.state_bits)
        tail_logits = None
        tracing = self.obs.tracing
        for s0 in range(seq.cached_len, len(prompt), C):
            chunk = prompt[s0:s0 + C]
            toks = np.zeros((1, C), np.int32)
            toks[0, :len(chunk)] = chunk
            n_pages = min(-(-(s0 + C) // T), self.pool.max_pages_per_seq) \
                if self.pool.has_pages else 1
            tc0 = time.perf_counter() if tracing else 0.0
            with self.obs.annotate("serve.prefill_chunk"):
                logits, state, carry = self._prefill(
                    self.params, jnp.asarray(toks), self.pool.state, table,
                    jnp.int32(s0), carry, jnp.int32(len(chunk)), n_pages)
            self.pool.state = state
            if tracing:
                # per-chunk spans need a per-chunk fence; the untraced path
                # never syncs here (the tail sample syncs the whole prefill)
                jax.block_until_ready(logits)
                self.obs.emit("prefill_chunk", rid=seq.req.rid,
                              seq_id=seq.seq_id, tokens=len(chunk),
                              duration_s=time.perf_counter() - tc0)
            tail = len(prompt) - 1 - s0
            if 0 <= tail < C:
                tail_logits = logits[0, tail]
        if self._has_state:
            # single quantization event at the prefill->decode handoff
            self.pool.state = self._commit(
                self.pool.state, carry,
                jnp.int32(seq.slot + 1))
        if tail_logits is not None:
            first = self._sample_one(seq, tail_logits, len(prompt) - 1)
        return first

    def generate(self, requests: List[Request], verbose: bool = False):
        """Serve a request list with token-level continuous batching."""
        sched = TokenScheduler(self.pool, self.slots,
                               base_seed=self.base_seed, obs=self.obs)
        sched.add(list(requests))
        stats = self._serve_loop(sched)
        if verbose:
            print(stats)
        return requests, stats

    def serve_open_loop(self, arrivals, verbose: bool = False):
        """Open-loop serving: ``arrivals`` is ``[(t_offset_s, Request)]``
        sorted by offset.  Requests become visible to the scheduler only
        once the serving clock (``time.perf_counter`` from call entry)
        passes their offset — real admission under load, not a
        pre-enqueued batch.  The load generator (``repro.serve.loadgen``)
        builds the arrival list and turns the returned stats into a
        goodput/SLO report.

        Sampling parity contract: arrival timing changes *when* a request
        is admitted, never *what* it decodes — outputs are token-identical
        to ``generate`` over the same requests."""
        arrivals = list(arrivals)
        if any(b[0] < a[0] for a, b in zip(arrivals, arrivals[1:])):
            raise ValueError("arrivals must be sorted by time offset")
        sched = TokenScheduler(self.pool, self.slots,
                               base_seed=self.base_seed, obs=self.obs)
        pending = list(arrivals)[::-1]          # pop() from the tail = head
        t0 = time.perf_counter()

        def feed():
            now = time.perf_counter() - t0
            batch = []
            while pending and pending[-1][0] <= now:
                batch.append(pending.pop()[1])
            if batch:
                sched.add(batch)
            if not pending:
                return None                     # drained
            return max(0.0, pending[-1][0] - now)

        itl_by_rid: Dict[int, List[float]] = {}
        stats = self._serve_loop(sched, feed=feed, itl_by_rid=itl_by_rid)
        stats["serve_duration_s"] = time.perf_counter() - t0
        stats["request_latencies"] = sched.latencies()
        stats["itl_by_rid"] = itl_by_rid
        if verbose:
            print({k: v for k, v in stats.items()
                   if k not in ("request_latencies", "itl_by_rid")})
        return [r for _, r in arrivals], stats

    def _serve_loop(self, sched: TokenScheduler, feed=None,
                    itl_by_rid: Optional[Dict[int, List[float]]] = None):
        """The continuous-batching loop over one scheduler.  ``feed`` is
        polled once per iteration and returns seconds until the next
        arrival (``None`` = no more arrivals); ``itl_by_rid`` optionally
        collects per-request inter-token latency samples (the loadgen's
        p99-ITL SLO input) — ``None`` skips the bookkeeping entirely."""
        prefill_s = decode_s = 0.0
        n_prefill = n_decode = 0
        tracing = self.obs.tracing

        while True:
            wait = feed() if feed is not None else None
            if not sched.has_work():
                if wait is None:
                    break                     # drained + idle: done
                time.sleep(wait)              # idle until the next arrival
                continue
            # admit one request at a time: each admission's prefix match must
            # see the pages the *previous* admission just prefilled and
            # registered, so a batch sharing a prompt hits within one wave
            while True:
                admitted = sched.admit(limit=1)
                if not admitted:
                    break
                seq = admitted[0]
                t0 = time.perf_counter()
                if self._has_state:
                    # admission hygiene: the previous occupant's state slot
                    # must not linger (commit overwrites it anyway)
                    self.pool.state = self._init_slot(
                        self.pool.state, jnp.int32(seq.slot + 1))
                first = self._prefill_seq(seq)
                # the tail-token sample syncs the last chunk's executable but
                # not the commit/copy programs — fence so dt is device time
                jax.block_until_ready(self.pool.state)
                dt = time.perf_counter() - t0
                prefill_s += dt
                n_tok = len(seq.req.prompt) - seq.cached_len
                n_prefill += n_tok
                self._h_prefill.observe(dt)
                self._c_prefill_s.inc(dt)
                self._c_prefill_tok.inc(n_tok)
                # register before record_prefill: a max_new=1 request frees
                # its refcounts there, which would park the pages cache-free
                # only if they are already in the index
                sched.register_prefix(seq)
                sched.record_prefill(seq, first)
            if sched.n_running == 0:
                if sched.has_work():
                    sched.check_progress()   # stall: queued work can't fit
                continue   # admitted requests all finished at prefill
                           # (max_new=1) — their slots/pages are free again
            # on-demand growth (may preempt-and-requeue a victim): every
            # surviving sequence has a page under its next write position
            sched.ensure_capacity()
            (tokens, tables, positions, lengths, state_slots,
             (temps, top_ks, top_ps, rep_pens, hist, keys)) \
                = sched.batch_inputs()
            t0 = time.perf_counter()
            with self.obs.annotate("serve.decode_step"):
                logits, state = self._decode(
                    self.params, jnp.asarray(tokens), self.pool.state,
                    jnp.asarray(tables), jnp.asarray(positions),
                    jnp.asarray(lengths), jnp.asarray(state_slots))
                self.pool.state = state
                if temps.max() <= 0:
                    nxt = np.asarray(self._greedy(logits))
                else:
                    nxt = np.asarray(self._sample(
                        logits, jnp.asarray(temps), jnp.asarray(top_ks),
                        jnp.asarray(top_ps), jnp.asarray(rep_pens),
                        jnp.asarray(hist), jnp.asarray(keys),
                        jnp.asarray(positions)))
            # np.asarray above already synced the sampled tokens, so dt is
            # real device time — no extra fence needed
            dt = time.perf_counter() - t0
            decode_s += dt
            n_run = sched.n_running
            n_decode += n_run
            self._h_decode.observe(dt)
            self._c_decode_s.inc(dt)
            self._c_decode_tok.inc(n_run)
            # per-request inter-token latency: each running request got one
            # token out of this step
            for _ in range(n_run):
                self._h_itl.observe(dt)
            if itl_by_rid is not None:
                for s in sched.running:
                    if s is not None:
                        itl_by_rid.setdefault(s.req.rid, []).append(dt)
            if tracing:
                self.obs.emit("decode_step", n_running=n_run, duration_s=dt,
                              rids=[s.req.rid for s in sched.running
                                    if s is not None])
            sched.advance(nxt)

        cfg = self.cfg
        stats = {
            "prefill_s": prefill_s,
            # tokens actually prefilled: prefix-cache hits are excluded, so
            # this is smaller than prompt_tokens under shared-prompt traffic
            "prefill_tokens": n_prefill,
            "prefill_tok_per_s": n_prefill / max(prefill_s, 1e-9),
            "decode_s": decode_s,
            "decode_tok_per_s": n_decode / max(decode_s, 1e-9),
            **sched.counters(),
            # latency distribution estimates straight from the registry
            # histograms (cumulative over this engine's lifetime)
            "ttft_p50": sched._h_ttft.percentile(0.50),
            "ttft_p95": sched._h_ttft.percentile(0.95),
            "ttft_p99": sched._h_ttft.percentile(0.99),
            "itl_p50": self._h_itl.percentile(0.50),
            "itl_p95": self._h_itl.percentile(0.95),
            "itl_p99": self._h_itl.percentile(0.99),
            # actual paged footprint, not a dense-cache estimate
            "kv_cache_bytes": self.pool.nbytes,
            "cache_bytes_by_kind": self.pool.nbytes_by_kind,
            # tensor-parallel footprint: bytes ONE device holds (KV pages
            # split their head axis; latent/SSM state replicates), and the
            # analytic interconnect cost of the decode psums
            "tp_devices": self.tp,
            "kv_cache_bytes_per_device": self.pool.nbytes_per_device(self.tp),
            "psum_bytes_per_token": (
                self.tp_plan.psum_bytes_per_token()
                if self.tp_plan is not None else 0),
            "kv_cache_bytes_dense": kv_bytes(
                self.slots, self.max_seq, cfg.n_layers,
                max(cfg.n_kv_heads, 1), cfg.resolved_head_dim or 1,
                self.kv_bits),
            # packed QTensors report their real (codes + scales) footprint
            "weight_bytes": memory_bytes(self.params),
        }
        return stats


class ServeEngine:
    """Compat wrapper: every decoder-only family forwards to
    ``PagedServeEngine`` (continuous batching, quantized pages/state); the
    lockstep dense-cache loop below is kept verbatim ONLY for
    encoder-decoder models, request-granular refill bug and all."""

    def __init__(self, cfg: ModelConfig, params, rot=None, mesh=None,
                 shd=NO_SHARD, batch_slots: int = 4, max_seq: int = 256,
                 a_bits: int = 16, kv_bits: int = 16,
                 page_size: int = 16, obs: Optional[Obs] = None, **paged_kw):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.a_bits = a_bits
        self.kv_bits = kv_bits
        self.obs = obs if obs is not None else Obs()
        self._paged: Optional[PagedServeEngine] = None
        if M.supports_paged(cfg):
            # lossless compat at kv_bits=16: raw fp16 pages + f32 state slots
            paged_kw.setdefault("state_bits", 32 if kv_bits >= 16 else 8)
            self._paged = PagedServeEngine(
                cfg, params, rot=rot, mesh=mesh, shd=shd,
                batch_slots=batch_slots, max_seq=max_seq,
                page_size=page_size, a_bits=a_bits, kv_bits=kv_bits,
                obs=self.obs, **paged_kw)
            return
        rot = dict(rot or {})
        if kv_bits < 16 and rot.get("kv_quant") is None:
            rot["kv_quant"] = make_kv_quant(kv_bits)
        self.rot = rot

        # act-quant is threaded through the step builders so the hook is live
        # while jit *traces* (a set/clear around jit construction is a no-op —
        # tracing is lazy) and nothing global leaks across engines.
        aq = _act_quant_hook(a_bits)
        from repro.train import steps as S
        self._prefill = jax.jit(S.build_prefill(cfg, mesh=mesh, shd=shd,
                                                rot=self.rot, act_quant=aq))
        self._decode = jax.jit(S.build_decode_step(cfg, mesh=mesh, shd=shd,
                                                   rot=self.rot,
                                                   act_quant=aq))
        self._aq = aq

    @classmethod
    def from_artifact(cls, artifact, **kw) -> "ServeEngine":
        return _from_artifact(cls, artifact, paged=False, **kw)

    # ------------------------------------------------------------------ #
    def generate(self, requests: List[Request], verbose: bool = False):
        """Serve a request list (paged continuous batching for decoder-only
        families; the lockstep loop for enc-dec)."""
        if self._paged is not None:
            return self._paged.generate(requests, verbose=verbose)
        cfg = self.cfg
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        # all prompts padded to the same length for lockstep prefill
        plen = max(len(r.prompt) for r in queue)
        B = self.slots

        def take():
            return queue.pop(0) if queue else None

        for i in range(B):
            active[i] = take()

        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(active):
            if r is not None:
                toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))

        # grow the KV caches (seq on axis 2) to max_seq.  Only "kv*" subtrees:
        # SSM state [L,B,H,P,N] or cross-attention KV can collide with the
        # shape[2] == plen heuristic and must not be padded.
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == plen:
                return jnp.pad(x, [(0, 0)] * 2
                               + [(0, self.max_seq - x.shape[2])]
                               + [(0, 0)] * (x.ndim - 3))
            return x

        cache = {k: (jax.tree.map(grow, v) if k.startswith("kv") else v)
                 for k, v in cache.items()}
        # the pad/argmax above are async too: fence so prefill_s is the real
        # device-side prefill duration, not dispatch time
        jax.block_until_ready(cache)
        prefill_s = time.perf_counter() - t0
        self.obs.metrics.histogram(
            "serve_prefill_seconds",
            help="per-sequence chunked-prefill duration (device-fenced)"
        ).observe(prefill_s)

        last = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        pos = plen
        n_tokens = 0
        h_decode = self.obs.metrics.histogram(
            "serve_decode_step_seconds",
            help="one batched decode step (all running slots)")
        t0 = time.perf_counter()
        while any(r is not None for r in active) and pos < self.max_seq:
            ts = time.perf_counter()
            logits, cache = self._decode(self.params, last[:, None], cache,
                                         jnp.int32(pos))
            nxt = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)
            nxt_np = np.array(nxt)   # writable copy (slot refill overwrites)
            for i, r in enumerate(active):
                if r is None:
                    continue
                r.out.append(int(nxt_np[i]))
                n_tokens += 1
                if len(r.out) >= r.max_new:
                    r.done = True
                    active[i] = take()   # continuous batching: refill slot
                    if active[i] is not None:
                        # KNOWN BUG (fixed in PagedServeEngine): the refilled
                        # request decodes from its prompt tail without a
                        # prefill — it inherits the previous occupant's KV.
                        nxt_np[i] = active[i].prompt[-1]
            last = jnp.asarray(nxt_np)
            pos += 1
            h_decode.observe(time.perf_counter() - ts)
        decode_s = time.perf_counter() - t0
        stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tokens / max(decode_s, 1e-9),
            "kv_cache_bytes": kv_bytes(
                B, self.max_seq, cfg.n_layers, max(cfg.n_kv_heads, 1),
                cfg.resolved_head_dim or 1, self.kv_bits),
            "weight_bytes": memory_bytes(self.params),
        }
        if verbose:
            print(stats)
        return requests, stats
