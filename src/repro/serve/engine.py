"""Serving engines over a (quantized, rotated) model.

``PagedServeEngine`` is the real runtime: an int4 page-pool KV cache
(``repro.serve.page_pool``), a token-level continuous-batching scheduler
(``repro.serve.scheduler``) with chunked prefill, and the Pallas
paged-attention kernel (``repro.kernels.paged_attn``).  All jitted shapes are
fixed by the engine geometry (slots, page count, page size, chunk), so one
engine compiles exactly two programs — the calibrate-on-deploy flow reuses
them across repeat deployments.

``ServeEngine`` is the legacy lockstep dense-cache engine, kept for model
families the paged path doesn't cover (MLA/SSM/hybrid/enc-dec).  Its slot
refill is request-granular and does NOT prefill the refilled prompt — a known
correctness bug the paged engine fixes by construction.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.common import NO_SHARD
from repro.quant import fake_quant_act, kv_bytes, make_kv_quant, memory_bytes
from repro.serve.page_pool import PagePool
from repro.serve.scheduler import Request, SeqState, TokenScheduler

__all__ = ["Request", "ServeEngine", "PagedServeEngine"]


def _act_quant_hook(a_bits: int):
    return (lambda x: fake_quant_act(x, a_bits)) if a_bits < 16 else None


def _from_artifact(cls, artifact, paged: bool, **kw):
    """Cold-boot an engine from a QuantArtifact: packed weights on device,
    online rotations resolved from metadata, serving bits from the config
    snapshot — zero calls into the calibration stack."""
    from repro.artifacts.format import resolve_rotations
    qc = artifact.cfg.quant
    kw.setdefault("rot", resolve_rotations(artifact.rotations))
    kw.setdefault("a_bits", qc.a_bits)
    if paged and "kv_bits" not in kw and qc.kv_bits not in (4, 8):
        raise ValueError(
            f"artifact snapshot has kv_bits={qc.kv_bits}; the paged engine "
            "stores integer KV — pass kv_bits=4/8 explicitly or use the "
            "legacy ServeEngine")
    kw.setdefault("kv_bits", qc.kv_bits)
    params = jax.device_put(artifact.params)    # one transfer off the mmap
    return cls(artifact.cfg, params, **kw)


class PagedServeEngine:
    """Paged int4-KV serving runtime (W4 weights via params, A-quant hook,
    4/8-bit integer KV pages, online R3/R4 via the rot context)."""

    def __init__(self, cfg: ModelConfig, params, rot=None, mesh=None,
                 shd=NO_SHARD, batch_slots: int = 4, max_seq: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 a_bits: int = 16, kv_bits: int = 4, greedy: bool = True):
        if kv_bits not in (4, 8):
            raise ValueError("paged cache stores integer KV: kv_bits in {4,8}")
        if not M.supports_paged(cfg):
            raise NotImplementedError(
                f"{cfg.arch_id}: use the legacy ServeEngine")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.kv_bits = kv_bits
        self.prefill_chunk = prefill_chunk or page_size
        self.rot = dict(rot or {})
        if num_pages is None:
            # every slot can hold a full-length sequence, + the null page
            num_pages = batch_slots * -(-max_seq // page_size) + 1
        self.pool = PagePool(cfg, num_pages=num_pages, page_size=page_size,
                             max_seq=max_seq, kv_bits=kv_bits)

        from repro.train import steps as S
        aq = _act_quant_hook(a_bits)
        # donate the pool state (arg 2): the step's output pool aliases the
        # input buffers instead of copying the whole pool every token.  CPU
        # XLA has no donation — skip it there to avoid per-call warnings.
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._prefill = jax.jit(S.build_paged_prefill_chunk(
            cfg, mesh=mesh, shd=shd, rot=self.rot, act_quant=aq,
            kv_bits=kv_bits), donate_argnums=donate, static_argnums=(5,))
        self._decode = jax.jit(S.build_paged_decode_step(
            cfg, mesh=mesh, shd=shd, rot=self.rot, act_quant=aq,
            kv_bits=kv_bits), donate_argnums=donate)

    @classmethod
    def from_artifact(cls, artifact, **kw) -> "PagedServeEngine":
        return _from_artifact(cls, artifact, paged=True, **kw)

    # ------------------------------------------------------------------ #
    def _prefill_seq(self, seq: SeqState) -> int:
        """Chunked prefill of one admitted prompt into its reserved pages;
        returns the greedy first generated token (prompt-tail argmax)."""
        cfg = self.cfg
        prompt = np.asarray(seq.req.prompt, np.int32)
        C = self.prefill_chunk
        table = jnp.asarray(self.pool.block_table_row(seq.seq_id)[None])
        first = 0
        T = self.pool.page_size
        for s0 in range(0, len(prompt), C):
            chunk = prompt[s0:s0 + C]
            toks = np.zeros((1, C), np.int32)
            toks[0, :len(chunk)] = chunk
            n_pages = min(-(-(s0 + C) // T), self.pool.max_pages_per_seq)
            logits, state = self._prefill(self.params, jnp.asarray(toks),
                                          self.pool.state, table,
                                          jnp.int32(s0), n_pages)
            self.pool.state = state
            tail = len(prompt) - 1 - s0
            if 0 <= tail < C:
                first = int(jnp.argmax(logits[0, tail, :cfg.vocab_size]))
        return first

    def generate(self, requests: List[Request], verbose: bool = False):
        """Serve a request list with token-level continuous batching."""
        cfg = self.cfg
        sched = TokenScheduler(self.pool, self.slots)
        sched.add(list(requests))
        prefill_s = decode_s = 0.0
        n_prefill = n_decode = 0

        while sched.has_work():
            admitted = sched.admit()
            for seq in admitted:
                t0 = time.time()
                first = self._prefill_seq(seq)
                prefill_s += time.time() - t0
                n_prefill += len(seq.req.prompt)
                sched.record_prefill(seq, first)
            if sched.n_running == 0:
                if not admitted:
                    sched.check_progress()   # stall: queued work can't fit
                continue   # admitted requests all finished at prefill
                           # (max_new=1) — their slots/pages are free again
            tokens, tables, positions, lengths = sched.batch_inputs()
            t0 = time.time()
            logits, state = self._decode(
                self.params, jnp.asarray(tokens), self.pool.state,
                jnp.asarray(tables), jnp.asarray(positions),
                jnp.asarray(lengths))
            self.pool.state = state
            nxt = np.asarray(jnp.argmax(logits[:, 0, :cfg.vocab_size], -1))
            decode_s += time.time() - t0
            n_decode += sched.n_running
            sched.advance(nxt)

        stats = {
            "prefill_s": prefill_s,
            "prefill_tok_per_s": n_prefill / max(prefill_s, 1e-9),
            "decode_s": decode_s,
            "decode_tok_per_s": n_decode / max(decode_s, 1e-9),
            # actual paged footprint, not a dense-cache estimate
            "kv_cache_bytes": self.pool.nbytes,
            "kv_cache_bytes_dense": kv_bytes(
                self.slots, self.max_seq, cfg.n_layers,
                max(cfg.n_kv_heads, 1), cfg.resolved_head_dim or 1,
                self.kv_bits),
            # packed QTensors report their real (codes + scales) footprint
            "weight_bytes": memory_bytes(self.params),
        }
        if verbose:
            print(stats)
        return requests, stats


class ServeEngine:
    """Legacy lockstep dense-cache engine (request-granular slot refill)."""

    def __init__(self, cfg: ModelConfig, params, rot=None, mesh=None,
                 shd=NO_SHARD, batch_slots: int = 4, max_seq: int = 256,
                 a_bits: int = 16, kv_bits: int = 16, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = batch_slots
        self.a_bits = a_bits
        rot = dict(rot or {})
        if kv_bits < 16 and rot.get("kv_quant") is None:
            rot["kv_quant"] = make_kv_quant(kv_bits)
        self.rot = rot
        self.kv_bits = kv_bits

        # act-quant is threaded through the step builders so the hook is live
        # while jit *traces* (a set/clear around jit construction is a no-op —
        # tracing is lazy) and nothing global leaks across engines.
        aq = _act_quant_hook(a_bits)
        from repro.train import steps as S
        self._prefill = jax.jit(S.build_prefill(cfg, mesh=mesh, shd=shd,
                                                rot=self.rot, act_quant=aq))
        self._decode = jax.jit(S.build_decode_step(cfg, mesh=mesh, shd=shd,
                                                   rot=self.rot,
                                                   act_quant=aq))
        self._aq = aq

    @classmethod
    def from_artifact(cls, artifact, **kw) -> "ServeEngine":
        return _from_artifact(cls, artifact, paged=False, **kw)

    # ------------------------------------------------------------------ #
    def generate(self, requests: List[Request], verbose: bool = False):
        """Serve a request list with slot-based continuous batching."""
        cfg = self.cfg
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        # all prompts padded to the same length for lockstep prefill
        plen = max(len(r.prompt) for r in queue)
        B = self.slots

        def take():
            return queue.pop(0) if queue else None

        for i in range(B):
            active[i] = take()

        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(active):
            if r is not None:
                toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))

        # grow the KV caches (seq on axis 2) to max_seq.  Only "kv*" subtrees:
        # SSM state [L,B,H,P,N] or cross-attention KV can collide with the
        # shape[2] == plen heuristic and must not be padded.
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == plen:
                return jnp.pad(x, [(0, 0)] * 2
                               + [(0, self.max_seq - x.shape[2])]
                               + [(0, 0)] * (x.ndim - 3))
            return x

        cache = {k: (jax.tree.map(grow, v) if k.startswith("kv") else v)
                 for k, v in cache.items()}
        prefill_s = time.time() - t0

        last = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        pos = plen
        n_tokens = 0
        t0 = time.time()
        while any(r is not None for r in active) and pos < self.max_seq:
            logits, cache = self._decode(self.params, last[:, None], cache,
                                         jnp.int32(pos))
            nxt = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)
            nxt_np = np.array(nxt)   # writable copy (slot refill overwrites)
            for i, r in enumerate(active):
                if r is None:
                    continue
                r.out.append(int(nxt_np[i]))
                n_tokens += 1
                if len(r.out) >= r.max_new:
                    r.done = True
                    active[i] = take()   # continuous batching: refill slot
                    if active[i] is not None:
                        # KNOWN BUG (fixed in PagedServeEngine): the refilled
                        # request decodes from its prompt tail without a
                        # prefill — it inherits the previous occupant's KV.
                        nxt_np[i] = active[i].prompt[-1]
            last = jnp.asarray(nxt_np)
            pos += 1
        decode_s = time.time() - t0
        stats = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tokens / max(decode_s, 1e-9),
            "kv_cache_bytes": kv_bytes(
                B, self.max_seq, cfg.n_layers, max(cfg.n_kv_heads, 1),
                cfg.resolved_head_dim or 1, self.kv_bits),
            "weight_bytes": memory_bytes(self.params),
        }
        if verbose:
            print(stats)
        return requests, stats
