"""Token-level continuous-batching scheduler over the paged cache pool.

Request lifecycle: WAITING -(admit: prompt pages mapped/allocated, chunked
prefill of the uncached suffix)-> RUNNING -(on-demand page growth, possible
PREEMPTION back to WAITING)-> FINISHED.  Admission happens between any two
decode steps (token granularity, not request granularity): whenever a slot
frees up and the pool can cover the head-of-line request's *prompt*, it is
admitted — the longest prefix already in the pool's prefix index rides
existing read-only pages (refcount bump + copy-on-write of the last,
partially filled prefix page), and only the divergent suffix is prefilled
into fresh pages.  A refilled slot can never inherit the previous occupant's
stale KV: every written page is either freshly allocated or a private CoW
copy.  Recurrent-state families (SSM/hybrid) reserve no pages for their
recurrent state; their fixed-size slot is keyed by the scheduler slot
(physical slot = slot + 1, 0 is the null slot) and prefix caching is
disabled for them (a skipped prefill would skip the recurrence itself).

Decode-time memory is grown on demand: admission reserves prompt pages only,
and ``ensure_capacity`` (called before every decode step) appends one page
whenever a sequence's next write position crosses a page boundary.  When the
pool is exhausted, the lowest-progress running sequence is *preempted*: its
pages are recycled, its partial output discarded, and the request re-enters
the head of the waiting queue to be recomputed later (deterministic replay —
the PRNG seed is pinned at first admission).  The highest-progress sequence
is never preempted for a lower one, so the workload always makes progress;
a sequence that can neither grow nor find a victim is a genuine stall and
raises through ``check_progress``.

Sampling is per request: greedy by default (``temperature=0``, the test
oracle), or temperature/top-k with a per-request PRNG key derived from
``seed`` (or the sequence id) — the scheduler threads the key data and the
per-slot sampling parameters into the engine's fixed-shape decode inputs.

The scheduler is pure host logic: it owns request state and the page
allocator, and marshals the fixed-shape [slots]-batched inputs the jitted
decode step consumes.  Admission is FCFS without skip-ahead, so a giant
request cannot be starved by small ones slipping past it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.page_pool import PagePool


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    temperature: float = 0.0        # 0 = greedy argmax (the default oracle)
    top_k: int = 0                  # 0 = full vocab
    seed: Optional[int] = None      # per-request PRNG seed (None -> seq id)
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SeqState:
    """A running request: its slot, pages (held by seq_id) and progress."""
    req: Request
    seq_id: int
    slot: int
    pos: int = 0            # tokens written to the paged cache so far
    last_token: int = 0     # next decode input
    key_data: Optional[np.ndarray] = None   # raw PRNG key data, [2] uint32
    cached_len: int = 0     # prompt tokens already in shared pages
    cow_ops: List[Tuple[int, int]] = field(default_factory=list)


class TokenScheduler:
    def __init__(self, pool: PagePool, slots: int, base_seed: int = 0):
        self.pool = pool
        self.slots = slots
        self.base_seed = base_seed
        self.waiting: deque[Request] = deque()
        self.running: List[Optional[SeqState]] = [None] * slots
        self.finished: List[SeqState] = []
        self._next_id = 0
        # serving counters (pool counters are engine-lifetime cumulative, so
        # snapshot them to report per-scheduler deltas)
        self.preemptions = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self._cow0 = pool.cow_copies
        self._evict0 = pool.evictions

    # ----------------------------------------------------------------- state
    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.running)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_running > 0

    def add(self, requests: List[Request]) -> None:
        for req in requests:
            if req.max_new < 1:
                raise ValueError(
                    f"max_new must be >= 1, got {req.max_new} (prefill "
                    f"always samples one token at the prompt tail)")
            if req.done or req.out:
                raise ValueError(
                    "request was already served (done or non-empty out); "
                    "submit a fresh Request instead of reusing one")
        self.waiting.extend(requests)

    def counters(self) -> Dict[str, float]:
        """Serving counters for this scheduler's lifetime (one ``generate``
        call): prefix hits, CoW copies, cache evictions, preemptions."""
        return {
            "prompt_tokens": self.prompt_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / max(1, self.prompt_tokens)),
            "cow_copies": self.pool.cow_copies - self._cow0,
            "prefix_evictions": self.pool.evictions - self._evict0,
            "preemptions": self.preemptions,
        }

    # ------------------------------------------------------------- admission
    def admit(self, limit: Optional[int] = None) -> List[SeqState]:
        """Fill free slots from the waiting queue while pages last.  Returns
        the newly admitted sequences; the engine must apply each sequence's
        ``cow_ops`` and prefill ``prompt[cached_len:]`` before the next
        decode step (and before the next ``admit`` call — an admission may
        map pages whose contents the pending prefill is about to write)."""
        admitted = []
        for slot in range(self.slots):
            if limit is not None and len(admitted) >= limit:
                break
            if self.running[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if self.pool.pages_for(len(req.prompt) + req.max_new) \
                    > self.pool.max_pages_per_seq:
                break       # can never run; surfaces via check_progress
            res = self.pool.admit_seq(self._next_id, req.prompt)
            if res is None:
                break                     # FCFS: no skip-ahead past the head
            cached_len, cow_ops = res
            self.waiting.popleft()
            seq = SeqState(req, self._next_id, slot, cached_len=cached_len,
                           cow_ops=cow_ops)
            self._next_id += 1
            # pin the seed at first admission so a preempted request replays
            # the same sample stream after requeue (its seq_id will differ)
            if req.seed is None:
                req.seed = self.base_seed + seq.seq_id
            key = jax.random.PRNGKey(req.seed)
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)      # typed-key impls
            seq.key_data = np.asarray(key, np.uint32)
            self.running[slot] = seq
            self.prefix_hit_tokens += cached_len
            self.prompt_tokens += len(req.prompt)
            admitted.append(seq)
        return admitted

    def check_progress(self, growth_stalled: Optional[SeqState] = None) -> None:
        """Deadlock guard.  Two stall shapes, both fatal:

        *admission stall* — work is queued but nothing runs and the head
        request cannot fit; *growth stall* — a mid-decode sequence crossed a
        page boundary with zero free pages and no preemptible victim (it is
        the only running sequence, so preemption cannot help)."""
        if growth_stalled is not None:
            seq = growth_stalled
            raise MemoryError(
                f"growth stall: seq {seq.seq_id} at {seq.pos} tokens needs "
                f"page {seq.pos // self.pool.page_size + 1}; pool has "
                f"{self.pool.free_pages} free of {self.pool.num_pages - 1} "
                f"and no preemptible victim (n_running={self.n_running})")
        if self.has_work() and self.n_running == 0:
            req = self.waiting[0]
            need = self.pool.pages_for(len(req.prompt) + req.max_new)
            prompt_need = self.pool.pages_for(len(req.prompt))
            detail = (f"exceeds the per-seq cap of "
                      f"{self.pool.max_pages_per_seq} pages (max_seq)"
                      if need > self.pool.max_pages_per_seq else
                      f"prompt alone needs {prompt_need} pages; pool has "
                      f"{self.pool.free_pages} free of "
                      f"{self.pool.num_pages - 1}")
            raise MemoryError(
                f"request of {len(req.prompt)}+{req.max_new} tokens needs "
                f"{need} pages; {detail}")

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self) -> None:
        """On-demand page growth before a decode step: every running
        sequence gets the page covering its next write position, preempting
        the lowest-progress victim when the pool runs dry.  Processing order
        is descending progress, so the sequences closest to finishing grow
        first and are never preempted for a younger one."""
        if not self.pool.has_pages:
            return
        order = sorted((s for s in self.running if s is not None),
                       key=lambda s: -s.pos)
        for seq in order:
            if self.running[seq.slot] is not seq:
                continue                # already preempted this round
            need = seq.pos // self.pool.page_size + 1
            while self.pool.seq_page_count(seq.seq_id) < need:
                if self.pool.grow_seq(seq.seq_id):
                    continue
                victim = self._pick_victim(seq)
                if victim is None:
                    self.check_progress(growth_stalled=seq)
                self.preempt(victim)
                if victim is seq:
                    break

    def _pick_victim(self, grower: SeqState) -> Optional[SeqState]:
        """Lowest-progress running sequence (ties -> youngest).  When every
        other sequence has made at least as much progress, the grower itself
        is the cheapest recomputation — self-preempt.  None = no victim at
        all (the grower runs alone): a genuine stall."""
        others = [s for s in self.running
                  if s is not None and s is not grower]
        if not others:
            return None
        victim = min(others, key=lambda s: (s.pos, -s.seq_id))
        return victim if victim.pos < grower.pos else grower

    def preempt(self, victim: SeqState) -> None:
        """Recycle the victim's pages and requeue it at the head of the line
        (recomputation-style preemption: partial output is discarded and the
        pinned seed replays the identical sample stream on re-admission)."""
        self.pool.free_seq(victim.seq_id)
        self.running[victim.slot] = None
        req = victim.req
        req.out.clear()
        req.done = False
        self.waiting.appendleft(req)
        self.preemptions += 1

    # ------------------------------------------------------------ progress
    def record_prefill(self, seq: SeqState, first_token: int) -> None:
        """Prompt fully in pages; ``first_token`` sampled at the prompt tail.
        ``add()`` guarantees max_new >= 1, so the appended token can never
        overshoot the budget."""
        seq.pos = len(seq.req.prompt)
        seq.last_token = first_token
        seq.req.out.append(first_token)
        if len(seq.req.out) >= seq.req.max_new:
            self._finish(seq)

    def register_prefix(self, seq: SeqState) -> None:
        """Index the sequence's prompt pages — call after its prefill ran
        (contents valid) and before ``record_prefill`` (which may free the
        pages of a max_new=1 request)."""
        self.pool.register_prefix(seq.seq_id, seq.req.prompt)

    def state_slot(self, seq: SeqState) -> int:
        """Physical state slot for a running sequence (0 is the null slot)."""
        return seq.slot + 1

    def batch_inputs(self):
        """Fixed-shape [slots] decode inputs; idle slots get length 0 (fully
        masked), write position 0 (the pool's null page) and state slot 0
        (the null state slot).  Returns (tokens, tables, positions, lengths,
        state_slots, sample_inputs) where sample_inputs = (temps, top_ks,
        key_data) drives per-request sampling."""
        B, Pmax = self.slots, self.pool.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        tables = np.zeros((B, Pmax), np.int32)
        positions = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        state_slots = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            tokens[slot, 0] = seq.last_token
            tables[slot] = self.pool.block_table_row(seq.seq_id)
            positions[slot] = seq.pos
            lengths[slot] = seq.pos + 1
            state_slots[slot] = self.state_slot(seq)
            temps[slot] = seq.req.temperature
            top_ks[slot] = seq.req.top_k
            keys[slot] = seq.key_data
        return (tokens, tables, positions, lengths, state_slots,
                (temps, top_ks, keys))

    def advance(self, next_tokens: np.ndarray) -> List[SeqState]:
        """Consume one decode step's sampled tokens; returns newly finished."""
        done = []
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            seq.pos += 1
            tok = int(next_tokens[slot])
            seq.req.out.append(tok)
            seq.last_token = tok
            if len(seq.req.out) >= seq.req.max_new:
                done.append(seq)
                self._finish(seq)
        return done

    def _finish(self, seq: SeqState) -> None:
        seq.req.done = True
        self.pool.free_seq(seq.seq_id)
        self.running[seq.slot] = None
        self.finished.append(seq)
