"""Token-level continuous-batching scheduler over the paged cache pool.

Request lifecycle: WAITING -(admit: pages reserved, chunked prefill)->
RUNNING -(max_new tokens)-> FINISHED.  Admission happens between any two
decode steps (token granularity, not request granularity): whenever a slot
frees up and the pool has pages for ``len(prompt) + max_new`` tokens, the
head-of-line request is admitted and prefilled *into its own pages* — a
refilled slot can never inherit the previous occupant's stale KV, which is
the legacy engine's refill bug fixed by construction.  Recurrent-state
families (SSM/hybrid) reserve no pages; their fixed-size state slot is keyed
by the scheduler slot (physical slot = slot + 1, 0 is the null slot).

Sampling is per request: greedy by default (``temperature=0``, the test
oracle), or temperature/top-k with a per-request PRNG key derived from
``seed`` (or the sequence id) — the scheduler threads the key data and the
per-slot sampling parameters into the engine's fixed-shape decode inputs.

The scheduler is pure host logic: it owns request state and the page
allocator, and marshals the fixed-shape [slots]-batched inputs the jitted
decode step consumes.  Admission is FCFS without skip-ahead, so a giant
request cannot be starved by small ones slipping past it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.page_pool import PagePool


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    temperature: float = 0.0        # 0 = greedy argmax (the default oracle)
    top_k: int = 0                  # 0 = full vocab
    seed: Optional[int] = None      # per-request PRNG seed (None -> seq id)
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SeqState:
    """A running request: its slot, pages (held by seq_id) and progress."""
    req: Request
    seq_id: int
    slot: int
    pos: int = 0            # tokens written to the paged cache so far
    last_token: int = 0     # next decode input
    key_data: Optional[np.ndarray] = None   # raw PRNG key data, [2] uint32


class TokenScheduler:
    def __init__(self, pool: PagePool, slots: int, base_seed: int = 0):
        self.pool = pool
        self.slots = slots
        self.base_seed = base_seed
        self.waiting: deque[Request] = deque()
        self.running: List[Optional[SeqState]] = [None] * slots
        self.finished: List[SeqState] = []
        self._next_id = 0

    # ----------------------------------------------------------------- state
    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.running)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_running > 0

    def add(self, requests: List[Request]) -> None:
        self.waiting.extend(requests)

    # ------------------------------------------------------------- admission
    def admit(self) -> List[SeqState]:
        """Fill free slots from the waiting queue while pages last.  Returns
        the newly admitted sequences; the engine must prefill each before the
        next decode step."""
        admitted = []
        for slot in range(self.slots):
            if self.running[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = len(req.prompt) + req.max_new
            if not self.pool.can_alloc(need):
                break                     # FCFS: no skip-ahead past the head
            self.waiting.popleft()
            seq = SeqState(req, self._next_id, slot)
            seed = req.seed if req.seed is not None \
                else (self.base_seed + seq.seq_id)
            key = jax.random.PRNGKey(seed)
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)      # typed-key impls
            seq.key_data = np.asarray(key, np.uint32)
            self._next_id += 1
            self.pool.alloc_seq(seq.seq_id, need)
            self.running[slot] = seq
            admitted.append(seq)
        return admitted

    def check_progress(self) -> None:
        """Deadlock guard: work is queued but nothing runs and nothing fits."""
        if self.has_work() and self.n_running == 0:
            req = self.waiting[0]
            need = self.pool.pages_for(len(req.prompt) + req.max_new)
            detail = (f"exceeds the per-seq cap of "
                      f"{self.pool.max_pages_per_seq} pages (max_seq)"
                      if need > self.pool.max_pages_per_seq else
                      f"pool has {self.pool.free_pages} free of "
                      f"{self.pool.num_pages - 1}")
            raise MemoryError(
                f"request of {len(req.prompt)}+{req.max_new} tokens needs "
                f"{need} pages; {detail}")

    # ------------------------------------------------------------ progress
    def record_prefill(self, seq: SeqState, first_token: int) -> None:
        """Prompt fully in pages; ``first_token`` sampled at the prompt tail."""
        seq.pos = len(seq.req.prompt)
        seq.last_token = first_token
        seq.req.out.append(first_token)
        if len(seq.req.out) >= seq.req.max_new:
            self._finish(seq)

    def state_slot(self, seq: SeqState) -> int:
        """Physical state slot for a running sequence (0 is the null slot)."""
        return seq.slot + 1

    def batch_inputs(self):
        """Fixed-shape [slots] decode inputs; idle slots get length 0 (fully
        masked), write position 0 (the pool's null page) and state slot 0
        (the null state slot).  Returns (tokens, tables, positions, lengths,
        state_slots, sample_inputs) where sample_inputs = (temps, top_ks,
        key_data) drives per-request sampling."""
        B, Pmax = self.slots, self.pool.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        tables = np.zeros((B, Pmax), np.int32)
        positions = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        state_slots = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            tokens[slot, 0] = seq.last_token
            tables[slot] = self.pool.block_table_row(seq.seq_id)
            positions[slot] = seq.pos
            lengths[slot] = seq.pos + 1
            state_slots[slot] = self.state_slot(seq)
            temps[slot] = seq.req.temperature
            top_ks[slot] = seq.req.top_k
            keys[slot] = seq.key_data
        return (tokens, tables, positions, lengths, state_slots,
                (temps, top_ks, keys))

    def advance(self, next_tokens: np.ndarray) -> List[SeqState]:
        """Consume one decode step's sampled tokens; returns newly finished."""
        done = []
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            seq.pos += 1
            tok = int(next_tokens[slot])
            seq.req.out.append(tok)
            seq.last_token = tok
            if len(seq.req.out) >= seq.req.max_new:
                done.append(seq)
                self._finish(seq)
        return done

    def _finish(self, seq: SeqState) -> None:
        seq.req.done = True
        self.pool.free_seq(seq.seq_id)
        self.running[seq.slot] = None
        self.finished.append(seq)
