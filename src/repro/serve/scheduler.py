"""Token-level continuous-batching scheduler over the paged cache pool.

Request lifecycle: WAITING -(admit: prompt pages mapped/allocated, chunked
prefill of the uncached suffix)-> RUNNING -(on-demand page growth, possible
PREEMPTION back to WAITING)-> FINISHED.  Admission happens between any two
decode steps (token granularity, not request granularity): whenever a slot
frees up and the pool can cover the head-of-line request's *prompt*, it is
admitted — the longest prefix already in the pool's prefix index rides
existing read-only pages (refcount bump + copy-on-write of the last,
partially filled prefix page), and only the divergent suffix is prefilled
into fresh pages.  A refilled slot can never inherit the previous occupant's
stale KV: every written page is either freshly allocated or a private CoW
copy.  Recurrent-state families (SSM/hybrid) reserve no pages for their
recurrent state; their fixed-size slot is keyed by the scheduler slot
(physical slot = slot + 1, 0 is the null slot) and prefix caching is
disabled for them (a skipped prefill would skip the recurrence itself).

Decode-time memory is grown on demand: admission reserves prompt pages only,
and ``ensure_capacity`` (called before every decode step) appends one page
whenever a sequence's next write position crosses a page boundary.  When the
pool is exhausted, the lowest-progress running sequence is *preempted*: its
pages are recycled, its partial output discarded, and the request re-enters
the head of the waiting queue to be recomputed later (deterministic replay —
the PRNG seed is pinned at first admission).  The highest-progress sequence
is never preempted for a lower one, so the workload always makes progress;
a sequence that can neither grow nor find a victim is a genuine stall and
raises through ``check_progress``.

Sampling is per request: greedy by default (``temperature=0``, the test
oracle), or temperature/top-k with a per-request PRNG key derived from
``seed`` (or the sequence id) — the scheduler threads the key data and the
per-slot sampling parameters into the engine's fixed-shape decode inputs.

The scheduler is pure host logic: it owns request state and the page
allocator, and marshals the fixed-shape [slots]-batched inputs the jitted
decode step consumes.  Admission is FCFS without skip-ahead, so a giant
request cannot be starved by small ones slipping past it.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import Obs
from repro.serve.page_pool import PagePool


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    temperature: float = 0.0        # 0 = greedy argmax (the default oracle)
    top_k: int = 0                  # 0 = full vocab
    top_p: float = 1.0              # nucleus mass; 1.0 = no truncation
    rep_penalty: float = 1.0        # CTRL repetition penalty; 1.0 = off
    seed: Optional[int] = None      # per-request PRNG seed (None -> seq id)
    out: List[int] = field(default_factory=list)
    done: bool = False
    rid: Optional[int] = None       # trace id: assigned at enqueue, stable
                                    # across preemption/requeue (seq_id isn't)


@dataclass
class SeqState:
    """A running request: its slot, pages (held by seq_id) and progress."""
    req: Request
    seq_id: int
    slot: int
    pos: int = 0            # tokens written to the paged cache so far
    last_token: int = 0     # next decode input
    key_data: Optional[np.ndarray] = None   # raw PRNG key data, [2] uint32
    cached_len: int = 0     # prompt tokens already in shared pages
    cow_ops: List[Tuple[int, int]] = field(default_factory=list)


class TokenScheduler:
    def __init__(self, pool: PagePool, slots: int, base_seed: int = 0,
                 obs: Optional[Obs] = None):
        self.pool = pool
        self.slots = slots
        self.base_seed = base_seed
        self.waiting: deque[Request] = deque()
        self.running: List[Optional[SeqState]] = [None] * slots
        self.finished: List[SeqState] = []
        self._next_id = 0
        self._next_rid = 0
        # one metrics surface (repro.obs): counters are registry-cumulative;
        # ``counters()`` stays the per-scheduler-lifetime compat view by
        # snapshotting the registry at construction.  Default to the pool's
        # Obs so a bare TokenScheduler(pool, ...) shares its registry.
        self.obs = obs if obs is not None else pool.obs
        m = self.obs.metrics
        self._c_preempt = m.counter(
            "serve_preemptions_total",
            help="sequences preempted (pages recycled, request requeued)")
        self._c_prompt = m.counter(
            "serve_prompt_tokens_total", help="prompt tokens submitted")
        self._c_hit = m.counter(
            "serve_prefix_hit_tokens_total",
            help="prompt tokens served from cached prefix pages")
        self._c_reject = m.counter(
            "serve_admission_rejects_total",
            help="requests rejected at add() (invalid max_new / reused)")
        self._c_admission_stall = m.counter(
            "serve_admission_stalls_total",
            help="fatal stalls: queued head request can never fit")
        self._c_growth_stall = m.counter(
            "serve_growth_stalls_total",
            help="fatal stalls: growth needed, no page, no victim")
        self._h_queue = m.histogram(
            "serve_queue_seconds", help="enqueue/requeue -> admission wait")
        self._h_ttft = m.histogram(
            "serve_ttft_seconds", help="enqueue -> first token latency")
        m.gauge("serve_queue_depth",
                help="requests waiting for admission").set_fn(
                    lambda: len(self.waiting))
        m.gauge("serve_running",
                help="sequences in decode slots").set_fn(
                    lambda: self.n_running)
        base = lambda c: c.value
        self._base = {c: base(c) for c in
                      (self._c_preempt, self._c_prompt, self._c_hit,
                       pool._c_cow, pool._c_evict)}
        # per-request trace bookkeeping (rid-keyed; host-side only)
        self._arrival: Dict[int, float] = {}    # first enqueue (TTFT basis)
        self._queued_at: Dict[int, float] = {}  # latest (re)enqueue
        self._ttft: Dict[int, float] = {}
        self._queue_s: Dict[int, float] = {}    # latest admission's wait

    def _delta(self, counter) -> int:
        return int(counter.value - self._base[counter])

    # compat attribute views (per-scheduler deltas, like the old plain ints)
    @property
    def preemptions(self) -> int:
        return self._delta(self._c_preempt)

    @property
    def prompt_tokens(self) -> int:
        return self._delta(self._c_prompt)

    @property
    def prefix_hit_tokens(self) -> int:
        return self._delta(self._c_hit)

    # ----------------------------------------------------------------- state
    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.running)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_running > 0

    def add(self, requests: List[Request]) -> None:
        for req in requests:
            if req.max_new < 1:
                # error paths count before raising: a serving loop that
                # swallows the exception still shows up on dashboards
                self._c_reject.inc()
                raise ValueError(
                    f"max_new must be >= 1, got {req.max_new} (prefill "
                    f"always samples one token at the prompt tail)")
            if req.done or req.out:
                self._c_reject.inc()
                raise ValueError(
                    "request was already served (done or non-empty out); "
                    "submit a fresh Request instead of reusing one")
        now = time.perf_counter()
        for req in requests:
            if req.rid is None:
                req.rid = self._next_rid
                self._next_rid += 1
            self._arrival[req.rid] = now
            self._queued_at[req.rid] = now
            self.obs.emit("enqueue", rid=req.rid,
                          prompt_len=len(req.prompt), max_new=req.max_new)
        self.waiting.extend(requests)

    def counters(self) -> Dict[str, float]:
        """Serving counters for this scheduler's lifetime (one ``generate``
        call): prefix hits, CoW copies, cache evictions, preemptions.
        A thin compat view over the obs registry — values are the registry
        counters minus their value at scheduler construction."""
        prompt = self._delta(self._c_prompt)
        hits = self._delta(self._c_hit)
        return {
            "prompt_tokens": prompt,
            "prefix_hit_tokens": hits,
            "prefix_hit_rate": hits / max(1, prompt),
            "cow_copies": self._delta(self.pool._c_cow),
            "prefix_evictions": self._delta(self.pool._c_evict),
            "preemptions": self._delta(self._c_preempt),
        }

    def latencies(self) -> Dict[int, Dict[str, float]]:
        """Per-request latency snapshot, rid-keyed: TTFT (from first
        enqueue) and the latest admission's queue wait.  The load
        generator's goodput/SLO inputs — only requests whose first token
        was produced appear."""
        return {rid: {"ttft_s": t, "queue_s": self._queue_s.get(rid, 0.0)}
                for rid, t in self._ttft.items()}

    # ------------------------------------------------------------- admission
    def admit(self, limit: Optional[int] = None) -> List[SeqState]:
        """Fill free slots from the waiting queue while pages last.  Returns
        the newly admitted sequences; the engine must apply each sequence's
        ``cow_ops`` and prefill ``prompt[cached_len:]`` before the next
        decode step (and before the next ``admit`` call — an admission may
        map pages whose contents the pending prefill is about to write)."""
        admitted = []
        for slot in range(self.slots):
            if limit is not None and len(admitted) >= limit:
                break
            if self.running[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            if self.pool.pages_for(len(req.prompt) + req.max_new) \
                    > self.pool.max_pages_per_seq:
                break       # can never run; surfaces via check_progress
            res = self.pool.admit_seq(self._next_id, req.prompt)
            if res is None:
                break                     # FCFS: no skip-ahead past the head
            cached_len, cow_ops = res
            self.waiting.popleft()
            seq = SeqState(req, self._next_id, slot, cached_len=cached_len,
                           cow_ops=cow_ops)
            self._next_id += 1
            # pin the seed at first admission so a preempted request replays
            # the same sample stream after requeue (its seq_id will differ)
            if req.seed is None:
                req.seed = self.base_seed + seq.seq_id
            key = jax.random.PRNGKey(req.seed)
            if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)      # typed-key impls
            seq.key_data = np.asarray(key, np.uint32)
            self.running[slot] = seq
            self._c_hit.inc(cached_len)
            self._c_prompt.inc(len(req.prompt))
            now = time.perf_counter()
            queue_s = now - self._queued_at.get(req.rid, now)
            self._queue_s[req.rid] = queue_s
            self._h_queue.observe(queue_s)
            self.obs.emit("admit", rid=req.rid, seq_id=seq.seq_id, slot=slot,
                          cached_len=cached_len, queue_s=queue_s)
            admitted.append(seq)
        return admitted

    def check_progress(self, growth_stalled: Optional[SeqState] = None) -> None:
        """Deadlock guard.  Two stall shapes, both fatal:

        *admission stall* — work is queued but nothing runs and the head
        request cannot fit; *growth stall* — a mid-decode sequence crossed a
        page boundary with zero free pages and no preemptible victim (it is
        the only running sequence, so preemption cannot help)."""
        if growth_stalled is not None:
            seq = growth_stalled
            self._c_growth_stall.inc()
            raise MemoryError(
                f"growth stall: seq {seq.seq_id} at {seq.pos} tokens needs "
                f"page {seq.pos // self.pool.page_size + 1}; pool has "
                f"{self.pool.free_pages} free of {self.pool.num_pages - 1} "
                f"and no preemptible victim (n_running={self.n_running})")
        if self.has_work() and self.n_running == 0:
            req = self.waiting[0]
            need = self.pool.pages_for(len(req.prompt) + req.max_new)
            prompt_need = self.pool.pages_for(len(req.prompt))
            detail = (f"exceeds the per-seq cap of "
                      f"{self.pool.max_pages_per_seq} pages (max_seq)"
                      if need > self.pool.max_pages_per_seq else
                      f"prompt alone needs {prompt_need} pages; pool has "
                      f"{self.pool.free_pages} free of "
                      f"{self.pool.num_pages - 1}")
            self._c_admission_stall.inc()
            raise MemoryError(
                f"request of {len(req.prompt)}+{req.max_new} tokens needs "
                f"{need} pages; {detail}")

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self) -> None:
        """On-demand page growth before a decode step: every running
        sequence gets the page covering its next write position, preempting
        the lowest-progress victim when the pool runs dry.  Processing order
        is descending progress, so the sequences closest to finishing grow
        first and are never preempted for a younger one."""
        if not self.pool.has_pages:
            return
        order = sorted((s for s in self.running if s is not None),
                       key=lambda s: -s.pos)
        for seq in order:
            if self.running[seq.slot] is not seq:
                continue                # already preempted this round
            need = seq.pos // self.pool.page_size + 1
            while self.pool.seq_page_count(seq.seq_id) < need:
                if self.pool.grow_seq(seq.seq_id):
                    continue
                victim = self._pick_victim(seq)
                if victim is None:
                    self.check_progress(growth_stalled=seq)
                self.preempt(victim)
                if victim is seq:
                    break

    def _pick_victim(self, grower: SeqState) -> Optional[SeqState]:
        """Lowest-progress running sequence (ties -> youngest).  When every
        other sequence has made at least as much progress, the grower itself
        is the cheapest recomputation — self-preempt.  None = no victim at
        all (the grower runs alone): a genuine stall."""
        others = [s for s in self.running
                  if s is not None and s is not grower]
        if not others:
            return None
        victim = min(others, key=lambda s: (s.pos, -s.seq_id))
        return victim if victim.pos < grower.pos else grower

    def preempt(self, victim: SeqState) -> None:
        """Recycle the victim's pages and requeue it at the head of the line
        (recomputation-style preemption: partial output is discarded and the
        pinned seed replays the identical sample stream on re-admission)."""
        req = victim.req
        self.obs.emit("preempt", rid=req.rid, seq_id=victim.seq_id,
                      pos=victim.pos,
                      pages_held=self.pool.seq_page_count(victim.seq_id))
        self.pool.free_seq(victim.seq_id)
        self.running[victim.slot] = None
        req.out.clear()
        req.done = False
        # requeue restarts the queue-wait clock; the TTFT basis (_arrival)
        # stays pinned at the first enqueue — replay latency is real latency
        self._queued_at[req.rid] = time.perf_counter()
        self.waiting.appendleft(req)
        self._c_preempt.inc()

    # ------------------------------------------------------------ progress
    def record_prefill(self, seq: SeqState, first_token: int) -> None:
        """Prompt fully in pages; ``first_token`` sampled at the prompt tail.
        ``add()`` guarantees max_new >= 1, so the appended token can never
        overshoot the budget."""
        seq.pos = len(seq.req.prompt)
        seq.last_token = first_token
        seq.req.out.append(first_token)
        rid = seq.req.rid
        now = time.perf_counter()
        ttft = now - self._arrival.get(rid, now)
        self._ttft[rid] = ttft      # a preempted request re-observes: its
        self._h_ttft.observe(ttft)  # replayed first token is real latency
        self.obs.emit("first_token", rid=rid, seq_id=seq.seq_id, ttft_s=ttft)
        if len(seq.req.out) >= seq.req.max_new:
            self._finish(seq)

    def register_prefix(self, seq: SeqState) -> None:
        """Index the sequence's prompt pages — call after its prefill ran
        (contents valid) and before ``record_prefill`` (which may free the
        pages of a max_new=1 request)."""
        self.pool.register_prefix(seq.seq_id, seq.req.prompt)

    def state_slot(self, seq: SeqState) -> int:
        """Physical state slot for a running sequence (0 is the null slot)."""
        return seq.slot + 1

    def batch_inputs(self):
        """Fixed-shape [slots] decode inputs; idle slots get length 0 (fully
        masked), write position 0 (the pool's null page) and state slot 0
        (the null state slot).  Returns (tokens, tables, positions, lengths,
        state_slots, sample_inputs) where sample_inputs = (temps, top_ks,
        top_ps, rep_pens, hist, key_data) drives per-request sampling.
        ``hist`` rows are the last ``MAX_REP_HISTORY`` prompt+output tokens,
        padded with vocab_size (the sampler drops out-of-range scatters);
        preemption clears ``out``, so a replayed request rebuilds the exact
        same history at every position — deterministic replay holds."""
        from repro.serve.engine import MAX_REP_HISTORY
        B, Pmax = self.slots, self.pool.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        tables = np.zeros((B, Pmax), np.int32)
        positions = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        state_slots = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        top_ks = np.zeros((B,), np.int32)
        top_ps = np.ones((B,), np.float32)
        rep_pens = np.ones((B,), np.float32)
        hist = np.full((B, MAX_REP_HISTORY), self.pool.cfg.vocab_size,
                       np.int32)
        keys = np.zeros((B, 2), np.uint32)
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            tokens[slot, 0] = seq.last_token
            tables[slot] = self.pool.block_table_row(seq.seq_id)
            positions[slot] = seq.pos
            lengths[slot] = seq.pos + 1
            state_slots[slot] = self.state_slot(seq)
            temps[slot] = seq.req.temperature
            top_ks[slot] = seq.req.top_k
            top_ps[slot] = seq.req.top_p
            rep_pens[slot] = seq.req.rep_penalty
            tail = (list(seq.req.prompt) + seq.req.out)[-MAX_REP_HISTORY:]
            hist[slot, :len(tail)] = tail
            keys[slot] = seq.key_data
        return (tokens, tables, positions, lengths, state_slots,
                (temps, top_ks, top_ps, rep_pens, hist, keys))

    def advance(self, next_tokens: np.ndarray) -> List[SeqState]:
        """Consume one decode step's sampled tokens; returns newly finished."""
        done = []
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            seq.pos += 1
            tok = int(next_tokens[slot])
            seq.req.out.append(tok)
            seq.last_token = tok
            if len(seq.req.out) >= seq.req.max_new:
                done.append(seq)
                self._finish(seq)
        return done

    def _finish(self, seq: SeqState) -> None:
        seq.req.done = True
        rid = seq.req.rid
        if self.obs.tracing:
            now = time.perf_counter()
            ttft = self._ttft.get(rid, 0.0)
            decode_s = now - self._arrival.get(rid, now) - ttft
            n_tok = len(seq.req.out)
            self.obs.emit(
                "finish", rid=rid, seq_id=seq.seq_id, n_tokens=n_tok,
                pages_held=self.pool.seq_page_count(seq.seq_id),
                ttft_s=ttft, queue_s=self._queue_s.get(rid, 0.0),
                itl_mean_s=decode_s / max(1, n_tok - 1))
        self.pool.free_seq(seq.seq_id)
        self.running[seq.slot] = None
        self.finished.append(seq)
