"""Token-level continuous-batching scheduler over the paged KV cache.

Request lifecycle: WAITING -(admit: pages reserved, chunked prefill)->
RUNNING -(max_new tokens)-> FINISHED.  Admission happens between any two
decode steps (token granularity, not request granularity): whenever a slot
frees up and the pool has pages for ``len(prompt) + max_new`` tokens, the
head-of-line request is admitted and prefilled *into its own pages* — a
refilled slot can never inherit the previous occupant's stale KV, which is
the legacy engine's refill bug fixed by construction.

The scheduler is pure host logic: it owns request state and the page
allocator, and marshals the fixed-shape [slots]-batched inputs the jitted
decode step consumes.  Admission is FCFS without skip-ahead, so a giant
request cannot be starved by small ones slipping past it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serve.page_pool import PagePool


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SeqState:
    """A running request: its slot, pages (held by seq_id) and progress."""
    req: Request
    seq_id: int
    slot: int
    pos: int = 0            # tokens written to the paged cache so far
    last_token: int = 0     # next decode input


class TokenScheduler:
    def __init__(self, pool: PagePool, slots: int):
        self.pool = pool
        self.slots = slots
        self.waiting: deque[Request] = deque()
        self.running: List[Optional[SeqState]] = [None] * slots
        self.finished: List[SeqState] = []
        self._next_id = 0

    # ----------------------------------------------------------------- state
    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.running)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_running > 0

    def add(self, requests: List[Request]) -> None:
        self.waiting.extend(requests)

    # ------------------------------------------------------------- admission
    def admit(self) -> List[SeqState]:
        """Fill free slots from the waiting queue while pages last.  Returns
        the newly admitted sequences; the engine must prefill each before the
        next decode step."""
        admitted = []
        for slot in range(self.slots):
            if self.running[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = len(req.prompt) + req.max_new
            if not self.pool.can_alloc(need):
                break                     # FCFS: no skip-ahead past the head
            self.waiting.popleft()
            seq = SeqState(req, self._next_id, slot)
            self._next_id += 1
            self.pool.alloc_seq(seq.seq_id, need)
            self.running[slot] = seq
            admitted.append(seq)
        return admitted

    def check_progress(self) -> None:
        """Deadlock guard: work is queued but nothing runs and nothing fits."""
        if self.has_work() and self.n_running == 0:
            req = self.waiting[0]
            need = self.pool.pages_for(len(req.prompt) + req.max_new)
            detail = (f"exceeds the per-seq cap of "
                      f"{self.pool.max_pages_per_seq} pages (max_seq)"
                      if need > self.pool.max_pages_per_seq else
                      f"pool has {self.pool.free_pages} free of "
                      f"{self.pool.num_pages - 1}")
            raise MemoryError(
                f"request of {len(req.prompt)}+{req.max_new} tokens needs "
                f"{need} pages; {detail}")

    # ------------------------------------------------------------ progress
    def record_prefill(self, seq: SeqState, first_token: int) -> None:
        """Prompt fully in pages; ``first_token`` = argmax at the prompt tail."""
        seq.pos = len(seq.req.prompt)
        seq.last_token = first_token
        seq.req.out.append(first_token)
        if len(seq.req.out) >= seq.req.max_new:
            self._finish(seq)

    def batch_inputs(self):
        """Fixed-shape [slots] decode inputs; idle slots get length 0 (fully
        masked) and write position 0 (the pool's null page)."""
        B, Pmax = self.slots, self.pool.max_pages_per_seq
        tokens = np.zeros((B, 1), np.int32)
        tables = np.zeros((B, Pmax), np.int32)
        positions = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            tokens[slot, 0] = seq.last_token
            tables[slot] = self.pool.block_table_row(seq.seq_id)
            positions[slot] = seq.pos
            lengths[slot] = seq.pos + 1
        return tokens, tables, positions, lengths

    def advance(self, next_tokens: np.ndarray) -> List[SeqState]:
        """Consume one decode step's sampled tokens; returns newly finished."""
        done = []
        for slot, seq in enumerate(self.running):
            if seq is None:
                continue
            seq.pos += 1
            tok = int(next_tokens[slot])
            seq.req.out.append(tok)
            seq.last_token = tok
            if len(seq.req.out) >= seq.req.max_new:
                done.append(seq)
                self._finish(seq)
        return done

    def _finish(self, seq: SeqState) -> None:
        seq.req.done = True
        self.pool.free_seq(seq.seq_id)
        self.running[seq.slot] = None
        self.finished.append(seq)
