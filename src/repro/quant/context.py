"""Trace-time quantization context.

``models.common.linear`` consults this before every matmul, so enabling W?A?
simulation requires zero plumbing through model code.  The hook is a
trace-time constant: set it before tracing/jit, clear after.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

_STATE = {"act_quant": None}


def set_act_quant(fn: Optional[Callable]) -> None:
    _STATE["act_quant"] = fn


def get_act_quant() -> Optional[Callable]:
    return _STATE["act_quant"]


@contextlib.contextmanager
def act_quant(fn: Callable):
    """with act_quant(lambda x: fake_quant_act(x, 4)): ... trace model ..."""
    prev = _STATE["act_quant"]
    _STATE["act_quant"] = fn
    try:
        yield
    finally:
        _STATE["act_quant"] = prev
