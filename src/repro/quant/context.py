"""Trace-time quantization context.

``models.common.linear`` consults this before every matmul, so enabling W?A?
simulation requires zero plumbing through model code.  The hook is a
trace-time constant held in a ``ContextVar`` — per-thread/per-context, so
concurrent engine construction (each tracing under its own hook) cannot race.
Prefer the ``act_quant`` context manager (or the explicit ``act_quant=``
argument on the step builders in ``repro.train.steps``) over the raw setter.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable, Optional

_ACT_QUANT: contextvars.ContextVar[Optional[Callable]] = \
    contextvars.ContextVar("act_quant", default=None)


def set_act_quant(fn: Optional[Callable]) -> None:
    _ACT_QUANT.set(fn)


def get_act_quant() -> Optional[Callable]:
    return _ACT_QUANT.get()


@contextlib.contextmanager
def act_quant(fn: Optional[Callable]):
    """with act_quant(lambda x: fake_quant_act(x, 4)): ... trace model ..."""
    token = _ACT_QUANT.set(fn)
    try:
        yield
    finally:
        _ACT_QUANT.reset(token)
