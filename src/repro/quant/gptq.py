"""GPTQ weight reconstruction in JAX (blocked Cholesky form).

Column-sequential error compensation (Frantar et al. 2022): for each input
column j, quantize, divide the residual by ``Hinv[j,j]`` and propagate it into
the not-yet-quantized columns.  Implemented as a ``lax.scan`` over columns with
the weight matrix as carry — O(out * in^2), offline calibration cost.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def hessian(x: jax.Array, damp: float = 0.01) -> jax.Array:
    """H = 2 X^T X + damping (x: [N, in] calibration inputs)."""
    h = 2.0 * (x.astype(jnp.float32).T @ x.astype(jnp.float32))
    diag = jnp.diagonal(h)
    # dead columns
    dead = diag == 0
    h = h + jnp.diag(jnp.where(dead, 1.0, 0.0))
    lam = damp * jnp.mean(jnp.where(dead, 0.0, diag))
    return h + lam * jnp.eye(h.shape[0], dtype=h.dtype)


@partial(jax.jit, static_argnames=("bits",))
def gptq_quantize(w: jax.Array, h: jax.Array, bits: int = 4,
                  clip_ratio: float = 1.0) -> Tuple[jax.Array, jax.Array]:
    """w [out, in]; h [in, in] -> (dequantized weights, int codes)."""
    out_dim, n = w.shape
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True)
                        * clip_ratio / qmax, 1e-8)          # per out-channel

    hinv = jnp.linalg.inv(h)
    L = jnp.linalg.cholesky(hinv)
    U = L.T                                                 # hinv = U^T U

    wf = w.astype(jnp.float32)

    def body(carry, j):
        W = carry
        col = W[:, j]
        q = jnp.clip(jnp.round(col / scale[:, 0]), -qmax - 1, qmax)
        dq = q * scale[:, 0]
        d = U[j, j]
        err = (col - dq) / d
        row = U[j] * (jnp.arange(n) >= j)                   # zero past columns
        W = W - err[:, None] * row[None, :]
        return W, q.astype(jnp.int8)

    W_final, q_cols = jax.lax.scan(body, wf, jnp.arange(n))
    return W_final.astype(w.dtype), q_cols.T               # W_final[:, j] == dq_j


def rtn_quantize(w: jax.Array, bits: int = 4,
                 clip_ratio: float = 1.0) -> jax.Array:
    """Round-to-nearest baseline with identical scale convention."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=-1, keepdims=True)
                        * clip_ratio / qmax, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    return (q * scale).astype(w.dtype)


def recon_error(w: jax.Array, w_q: jax.Array, x: jax.Array) -> jax.Array:
    """||X (W - Wq)^T||_F^2 / N — the GPTQ objective."""
    d = (w - w_q).astype(jnp.float32)
    e = x.astype(jnp.float32) @ d.T
    return jnp.mean(jnp.sum(e * e, axis=-1))
