"""Quantization substrate: RTN/GPTQ weights, per-token activations, KV cache."""
from repro.quant.context import act_quant, get_act_quant, set_act_quant
from repro.quant.gptq import gptq_quantize, hessian, recon_error, rtn_quantize
from repro.quant.kv_cache import (QuantKV, dequantize_kv, kv_bytes,
                                  make_kv_quant, packed_dim, paged_kv_bytes,
                                  quantize_kv, quantkv_bytes)
from repro.quant.qlinear import (dense_weight, memory_bytes, pack_params,
                                 pack_weight, projection_weight_bytes,
                                 qlinear_matmul, qtensor_matmul,
                                 quantize_params)
from repro.quant.quantizers import (QTensor, dequant_act, dequant_weight,
                                    fake_quant_act, fake_quant_kv,
                                    fake_quant_weight, pack_int4, quant_act,
                                    quant_weight, unpack_int4)
