"""Quantizers: RTN weights, per-token asymmetric activations, KV-cache quant.

Paper settings (§5): per-channel symmetric weights (GPTQ-reconstructed),
per-token asymmetric activations, 4-bit KV.  ``fake_*`` variants are QDQ
(quantize->dequantize) used for quality evaluation — bit-exact with the real
integer path; the integer path lives in qlinear.py / kernels.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    """Integer-quantized tensor + affine metadata."""
    q: jax.Array            # int8 storage (int4 values occupy [-8, 7])
    scale: jax.Array
    zero: Optional[jax.Array]   # None => symmetric


# --------------------------------------------------------------------------- #
# Weights: per-output-channel symmetric (optionally grouped)
# --------------------------------------------------------------------------- #
def quant_weight(w: jax.Array, bits: int = 4, group: int = -1,
                 clip_ratio: float = 1.0) -> QTensor:
    """w [..., out, in] -> symmetric int; scale per (out-channel[, group])."""
    qmax = 2 ** (bits - 1) - 1
    if group > 0:
        shp = w.shape
        wg = w.reshape(shp[:-1] + (shp[-1] // group, group))
        amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True) * clip_ratio
        scale = jnp.maximum(amax / qmax, 1e-8)
        q = jnp.clip(jnp.round(wg / scale), -qmax - 1, qmax)
        return QTensor(q.reshape(shp).astype(jnp.int8),
                       scale.reshape(shp[:-1] + (shp[-1] // group,)), None)
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True) * clip_ratio
    scale = jnp.maximum(amax / qmax, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    return QTensor(q.astype(jnp.int8), scale, None)


def dequant_weight(qt: QTensor, group: int = -1,
                   dtype=jnp.float32) -> jax.Array:
    if group > 0:
        shp = qt.q.shape
        qg = qt.q.reshape(shp[:-1] + (shp[-1] // group, group)).astype(dtype)
        return (qg * qt.scale[..., None].astype(dtype)).reshape(shp)
    return qt.q.astype(dtype) * qt.scale.astype(dtype)


def fake_quant_weight(w: jax.Array, bits: int = 4, group: int = -1,
                      clip_ratio: float = 1.0) -> jax.Array:
    qt = quant_weight(w, bits=bits, group=group, clip_ratio=clip_ratio)
    return dequant_weight(qt, group=group, dtype=w.dtype)


# --------------------------------------------------------------------------- #
# Activations: per-token asymmetric
# --------------------------------------------------------------------------- #
def quant_act(x: jax.Array, bits: int = 4) -> QTensor:
    """x [..., d] -> asymmetric uint-range int; scale/zero per token (row)."""
    qmax = 2 ** bits - 1
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((x - lo) / scale), 0, qmax)
    return QTensor(q.astype(jnp.uint8), scale, lo)


def dequant_act(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    return qt.q.astype(dtype) * qt.scale.astype(dtype) + qt.zero.astype(dtype)


def fake_quant_act(x: jax.Array, bits: int = 4) -> jax.Array:
    if bits >= 16:
        return x
    return dequant_act(quant_act(x, bits), dtype=x.dtype)


# --------------------------------------------------------------------------- #
# KV cache: per (token, head) asymmetric — paper's 4-bit KV setting
# --------------------------------------------------------------------------- #
def fake_quant_kv(kv: jax.Array, bits: int = 4) -> jax.Array:
    """kv [..., hd]: affine per leading index (token x head)."""
    if bits >= 16:
        return kv
    qmax = 2 ** bits - 1
    lo = jnp.min(kv, axis=-1, keepdims=True)
    hi = jnp.max(kv, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((kv - lo) / scale), 0, qmax)
    return (q * scale + lo).astype(kv.dtype)


# --------------------------------------------------------------------------- #
# int4 packing (two nibbles per int8 byte) — serving storage format
# --------------------------------------------------------------------------- #
def pack_int4(q: jax.Array) -> jax.Array:
    """int8 values in [-8,7], last dim even -> packed uint8 [..., d/2]."""
    lo = (q[..., 0::2] & 0xF).astype(jnp.uint8)
    hi = (q[..., 1::2] & 0xF).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(p: jax.Array) -> jax.Array:
    """packed uint8 -> int8 in [-8,7], interleaved back."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,))
