"""Quantizers: RTN weights, per-token asymmetric activations, KV-cache quant.

Paper settings (§5): per-channel symmetric weights (GPTQ-reconstructed),
per-token asymmetric activations, 4-bit KV.  ``fake_*`` variants are QDQ
(quantize->dequantize) used for quality evaluation — bit-exact with the real
integer path; the integer path lives in qlinear.py / kernels.

Quantization-health taps: ``quant_weight`` / ``quant_act`` sample clip rate
and scale dynamic range through ``repro.obs.quant_health.tap``.  The tap is
gated at trace time — unless a registry is armed (``quant_health.sampling``),
the call returns before touching any array, so the default path compiles to
exactly the same program as before.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs import quant_health


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Integer-quantized tensor + affine metadata (registered pytree).

    Children (traced): ``q`` (integer codes), ``scale``, ``zero`` (None =>
    symmetric).  Static aux data rides through jit/scan/vmap untouched:

      ``bits``         code width (4/8)
      ``group``        scale granularity on the last dim (-1 = per channel)
      ``in_features``  *logical* last-dim size before even/group padding —
                       odd in-feature weights pad their codes, mirroring the
                       odd-head-dim handling in quant/kv_cache.py
      ``packed``       True => two int4 nibbles per uint8 byte on the last dim
    """
    __slots__ = ("q", "scale", "zero", "bits", "group", "in_features", "packed")

    def __init__(self, q, scale, zero=None, *, bits: int = 8, group: int = -1,
                 in_features: Optional[int] = None, packed: bool = False):
        self.q = q
        self.scale = scale
        self.zero = zero
        self.bits = int(bits)
        self.group = int(group)
        self.in_features = None if in_features is None else int(in_features)
        self.packed = bool(packed)

    @property
    def stored_in_dim(self) -> int:
        """Last-dim size of the dequantized codes (incl. any padding)."""
        return self.q.shape[-1] * (2 if self.packed else 1)

    @property
    def logical_shape(self) -> Tuple[int, ...]:
        k = self.stored_in_dim if self.in_features is None else self.in_features
        return tuple(self.q.shape[:-1]) + (k,)

    def tree_flatten(self):
        return ((self.q, self.scale, self.zero),
                (self.bits, self.group, self.in_features, self.packed))

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)   # children may be tracers/sentinels
        obj.q, obj.scale, obj.zero = children
        obj.bits, obj.group, obj.in_features, obj.packed = aux
        return obj

    def __repr__(self):
        q = self.q
        shape = getattr(q, "shape", None)
        return (f"QTensor(q={shape}, bits={self.bits}, group={self.group}, "
                f"in_features={self.in_features}, packed={self.packed})")


# --------------------------------------------------------------------------- #
# Weights: per-output-channel symmetric (optionally grouped)
# --------------------------------------------------------------------------- #
def quant_weight(w: jax.Array, bits: int = 4, group: int = -1,
                 clip_ratio: float = 1.0) -> QTensor:
    """w [..., out, in] -> symmetric int; scale per (out-channel[, group])."""
    qmax = 2 ** (bits - 1) - 1
    if group > 0:
        shp = w.shape
        wg = w.reshape(shp[:-1] + (shp[-1] // group, group))
        amax = jnp.max(jnp.abs(wg), axis=-1, keepdims=True) * clip_ratio
        scale = jnp.maximum(amax / qmax, 1e-8)
        q = jnp.clip(jnp.round(wg / scale), -qmax - 1, qmax)
        quant_health.tap("weight", q, scale, bits, symmetric=True)
        return QTensor(q.reshape(shp).astype(jnp.int8),
                       scale.reshape(shp[:-1] + (shp[-1] // group,)), None,
                       bits=bits, group=group, in_features=shp[-1])
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True) * clip_ratio
    scale = jnp.maximum(amax / qmax, 1e-8)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    quant_health.tap("weight", q, scale, bits, symmetric=True)
    return QTensor(q.astype(jnp.int8), scale, None, bits=bits,
                   in_features=w.shape[-1])


def dequant_weight(qt: QTensor, group: Optional[int] = None,
                   dtype=jnp.float32) -> jax.Array:
    if group is None:
        group = qt.group
    if group > 0:
        shp = qt.q.shape
        qg = qt.q.reshape(shp[:-1] + (shp[-1] // group, group)).astype(dtype)
        return (qg * qt.scale[..., None].astype(dtype)).reshape(shp)
    return qt.q.astype(dtype) * qt.scale.astype(dtype)


def fake_quant_weight(w: jax.Array, bits: int = 4, group: int = -1,
                      clip_ratio: float = 1.0) -> jax.Array:
    qt = quant_weight(w, bits=bits, group=group, clip_ratio=clip_ratio)
    return dequant_weight(qt, group=group, dtype=w.dtype)


# --------------------------------------------------------------------------- #
# Activations: per-token asymmetric
# --------------------------------------------------------------------------- #
def quant_act(x: jax.Array, bits: int = 4) -> QTensor:
    """x [..., d] -> asymmetric uint-range int; scale/zero per token (row)."""
    qmax = 2 ** bits - 1
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((x - lo) / scale), 0, qmax)
    quant_health.tap("act", q, scale, bits, symmetric=False)
    return QTensor(q.astype(jnp.uint8), scale, lo, bits=bits)


def dequant_act(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    return qt.q.astype(dtype) * qt.scale.astype(dtype) + qt.zero.astype(dtype)


def fake_quant_act(x: jax.Array, bits: int = 4) -> jax.Array:
    if bits >= 16:
        return x
    return dequant_act(quant_act(x, bits), dtype=x.dtype)


# --------------------------------------------------------------------------- #
# KV cache: per (token, head) asymmetric — paper's 4-bit KV setting
# --------------------------------------------------------------------------- #
def fake_quant_kv(kv: jax.Array, bits: int = 4) -> jax.Array:
    """kv [..., hd]: affine per leading index (token x head)."""
    if bits >= 16:
        return kv
    qmax = 2 ** bits - 1
    lo = jnp.min(kv, axis=-1, keepdims=True)
    hi = jnp.max(kv, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((kv - lo) / scale), 0, qmax)
    return (q * scale + lo).astype(kv.dtype)


# --------------------------------------------------------------------------- #
# int4 packing (two nibbles per int8 byte) — serving storage format
# --------------------------------------------------------------------------- #
def pack_int4(q: jax.Array) -> jax.Array:
    """int8 values in [-8,7], last dim even -> packed uint8 [..., d/2]."""
    lo = (q[..., 0::2] & 0xF).astype(jnp.uint8)
    hi = (q[..., 1::2] & 0xF).astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_int4(p: jax.Array) -> jax.Array:
    """packed uint8 -> int8 in [-8,7], interleaved back."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,))
