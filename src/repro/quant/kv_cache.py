"""Quantized KV cache (paper: 4-bit KV with R3 online-Hadamard smoothing).

Two layers of support:
  * QDQ hook (``make_kv_quant``) plugged into the model's rot context — the
    cache stores fake-quantized values, so decode quality matches the real
    integer cache bit-for-bit.
  * Integer storage (``QuantKV``) — int8-packed int4 codes + fp16 scales, the
    serving memory format; ``kv_bytes`` reports the real footprint used by the
    serve engine for capacity planning.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.quant.quantizers import fake_quant_kv, pack_int4, unpack_int4


def make_kv_quant(bits: int):
    """Rot-context hook: quantize K/V (or MLA latent) at cache-write time."""
    if bits >= 16:
        return None
    return lambda kv: fake_quant_kv(kv, bits)


class QuantKV(NamedTuple):
    q: jax.Array        # packed codes [B,S,H,hd/2] uint8 (4-bit) or int8 (8-bit)
    scale: jax.Array    # [B,S,H,1] fp16
    zero: jax.Array     # [B,S,H,1] fp16


def quantize_kv(kv: jax.Array, bits: int = 4) -> QuantKV:
    qmax = 2 ** bits - 1
    lo = jnp.min(kv, axis=-1, keepdims=True)
    hi = jnp.max(kv, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((kv - lo) / scale), 0, qmax).astype(jnp.uint8)
    if bits == 4:
        q = q[..., 0::2] | (q[..., 1::2] << 4)   # two nibbles per byte
    return QuantKV(q, scale.astype(jnp.float16), lo.astype(jnp.float16))


def dequantize_kv(qkv: QuantKV, bits: int = 4, dtype=jnp.bfloat16) -> jax.Array:
    q = qkv.q
    if bits == 4:
        lo = (q & 0xF).astype(dtype)
        hi = ((q >> 4) & 0xF).astype(dtype)
        q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[:-1] + (q.shape[-1] * 2,))
    else:
        q = q.astype(dtype)
    return q * qkv.scale.astype(dtype) + qkv.zero.astype(dtype)


def kv_bytes(batch: int, seq: int, n_layers: int, n_kv: int, hd: int,
             bits: int) -> int:
    """Cache footprint (codes + per-(token,head) fp16 scale/zero)."""
    codes = batch * seq * n_layers * n_kv * hd * 2 * bits // 8
    meta = batch * seq * n_layers * n_kv * 2 * 2 * 2   # scale+zero fp16, K and V
    return codes + meta
