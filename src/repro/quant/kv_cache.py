"""Quantized KV cache (paper: 4-bit KV with R3 online-Hadamard smoothing).

Two layers of support:
  * QDQ hook (``make_kv_quant``) plugged into the model's rot context — the
    hook round-trips through the *integer* ``QuantKV`` format (fp16 scale/zero
    included), so decode quality matches the real integer cache bit-for-bit.
  * Integer storage (``QuantKV``) — int8-packed int4 codes + fp16 scales, the
    serving memory format; ``kv_bytes`` / ``paged_kv_bytes`` report the real
    footprint used by the serve engine for capacity planning.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def make_kv_quant(bits: int):
    """Rot-context hook: quantize K/V (or MLA latent) at cache-write time.

    Round-trips through ``QuantKV`` (integer codes, fp16 scale/zero) so the
    fake-quant decode path is bit-exact with the packed serving cache.
    """
    if bits >= 16:
        return None
    return lambda kv: dequantize_kv(quantize_kv(kv, bits), bits, kv.dtype,
                                    head_dim=kv.shape[-1])


class QuantKV(NamedTuple):
    q: jax.Array        # packed codes [B,S,H,ceil(hd/2)] uint8 (4-bit) or [...,hd] (8-bit)
    scale: jax.Array    # [B,S,H,1] fp16
    zero: jax.Array     # [B,S,H,1] fp16


def packed_dim(hd: int, bits: int) -> int:
    """Bytes per head row of codes (odd 4-bit dims pad one nibble)."""
    return (hd * bits + 7) // 8


def quantize_kv(kv: jax.Array, bits: int = 4) -> QuantKV:
    qmax = 2 ** bits - 1
    lo = jnp.min(kv, axis=-1, keepdims=True)
    hi = jnp.max(kv, axis=-1, keepdims=True)
    scale = jnp.maximum((hi - lo) / qmax, 1e-8)
    q = jnp.clip(jnp.round((kv - lo) / scale), 0, qmax).astype(jnp.uint8)
    if bits == 4:
        if q.shape[-1] % 2:                      # pad odd head dims
            q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
        q = q[..., 0::2] | (q[..., 1::2] << 4)   # two nibbles per byte
    return QuantKV(q, scale.astype(jnp.float16), lo.astype(jnp.float16))


def dequantize_kv(qkv: QuantKV, bits: int = 4, dtype=jnp.bfloat16,
                  head_dim: int | None = None) -> jax.Array:
    """Unpack codes back to values; ``head_dim`` trims odd-dim padding."""
    q = qkv.q
    if bits == 4:
        lo = (q & 0xF).astype(dtype)
        hi = ((q >> 4) & 0xF).astype(dtype)
        q = jnp.stack([lo, hi], axis=-1).reshape(q.shape[:-1] + (q.shape[-1] * 2,))
    else:
        q = q.astype(dtype)
    if head_dim is not None:
        q = q[..., :head_dim]
    return q * qkv.scale.astype(dtype) + qkv.zero.astype(dtype)


def quantkv_bytes(qkv: QuantKV) -> int:
    """Bytes actually held by one QuantKV (codes + scale + zero)."""
    return sum(int(x.size) * x.dtype.itemsize for x in qkv)


def kv_bytes(batch: int, seq: int, n_layers: int, n_kv: int, hd: int,
             bits: int) -> int:
    """Dense-cache footprint (codes + per-(token,head) fp16 scale/zero)."""
    per_tok_head = 2 * packed_dim(hd, bits) if bits < 16 else 2 * hd * 2
    codes = batch * seq * n_layers * n_kv * per_tok_head      # K and V
    meta = batch * seq * n_layers * n_kv * 2 * 2 * 2 if bits < 16 else 0
    return codes + meta


def paged_kv_bytes(n_pages: int, page_size: int, n_layers: int, n_kv: int,
                   hd: int, bits: int) -> int:
    """Actual footprint of a page pool: allocation is per page, not per seq."""
    return kv_bytes(1, n_pages * page_size, n_layers, n_kv, hd, bits)


def latent_bytes(n_tokens: int, n_layers: int, kv_lora_rank: int,
                 rope_dim: int, bits: int) -> int:
    """MLA latent-cache footprint: per token one quantized ``c_kv`` row
    (kv_lora_rank wide) + one rope-key row (rope_dim wide), each with a
    per-token fp16 scale/zero pair — the paged-MLA page format."""
    if bits >= 16:
        return n_tokens * n_layers * 2 * (kv_lora_rank + rope_dim)
    codes = n_tokens * n_layers * (packed_dim(kv_lora_rank, bits)
                                   + packed_dim(rope_dim, bits))
    meta = n_tokens * n_layers * 2 * 2 * 2          # scale+zero, fp16, 2 rows
    return codes + meta


def paged_latent_bytes(n_pages: int, page_size: int, n_layers: int,
                       kv_lora_rank: int, rope_dim: int, bits: int) -> int:
    return latent_bytes(n_pages * page_size, n_layers, kv_lora_rank, rope_dim,
                        bits)


def ssm_state_bytes(n_slots: int, n_layers: int, conv_taps: int, conv_dim: int,
                    n_heads: int, head_dim: int, state_dim: int,
                    bits: int) -> int:
    """Per-slot recurrent-state footprint (conv window + SSD state).

    ``bits`` 8 = int8 codes + per-row fp16 scale/zero (QuantKV convention);
    ``bits`` >= 16 = raw f32 (the legacy dense-cache layout, compat path).
    """
    if bits >= 16:
        return n_slots * n_layers * 4 * (conv_taps * conv_dim
                                         + n_heads * head_dim * state_dim)
    conv = n_slots * n_layers * conv_taps * (packed_dim(conv_dim, bits) + 4)
    h = n_slots * n_layers * n_heads * head_dim * (packed_dim(state_dim, bits)
                                                   + 4)
    return conv + h
