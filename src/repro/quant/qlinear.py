"""Quantized model parameters: QDQ simulation + int4/int8-packed serving weights.

``quantize_params``       — fake-quantize (QDQ) all projection weights; shares
                            the integer codes + fp16 scales with the packed
                            path, so QDQ is bit-exact with what serving stores.
``pack_params``           — replace projection weights with packed QTensors
                            (serving memory format; consumed by the Pallas
                            quant_matmul kernel / qlinear_matmul fallback).
``qtensor_matmul``        — the model-layer dispatch: Pallas kernel when the
                            tensor qualifies, jnp fallback otherwise.

Odd in-feature weights are padded to the packing/group multiple with zero
codes (exact: zero columns contribute nothing) and record their logical
``in_features`` on the QTensor, mirroring the odd-head-dim handling in
``quant/kv_cache.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.quant.quantizers import (QTensor, dequant_weight, pack_int4,
                                    quant_weight, unpack_int4)

# projection-weight leaf names (rotation consumers/producers); everything else
# (norms, biases, embeddings, router, conv, SSM scalars) stays high precision.
_WEIGHT_KEYS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "fc1", "fc2",
    "in_proj", "out_proj", "wq_a", "wq_b", "wkv_a", "wkv_b",
}


def _is_weight(path) -> bool:
    last = path[-1]
    name = getattr(last, "key", getattr(last, "name", None))
    return name in _WEIGHT_KEYS


def _pad_multiple(group: int) -> int:
    """Smallest in-feature multiple that satisfies nibble packing (2) and the
    scale-group width simultaneously."""
    if group <= 0:
        return 2
    return group if group % 2 == 0 else 2 * group


def pack_weight(w: jax.Array, bits: int = 4, group: int = -1,
                clip_ratio: float = 1.0, pack: bool = True) -> QTensor:
    """Quantize one weight [..., out, in] into the serving QTensor format.

    Pads odd/non-group in-features with zero columns (recorded as
    ``in_features``), stores fp16 scales, and nibble-packs int4 codes when
    ``pack``.  int8 codes stay one byte per element.
    """
    K = w.shape[-1]
    mult = _pad_multiple(group)
    Kp = -(-K // mult) * mult
    if Kp != K:
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 1) + [(0, Kp - K)])
    qt = quant_weight(w, bits=bits, group=group, clip_ratio=clip_ratio)
    scale = qt.scale.astype(jnp.float16)
    if pack and bits == 4:
        return QTensor(pack_int4(qt.q), scale, None, bits=4, group=group,
                       in_features=K, packed=True)
    return QTensor(qt.q, scale, None, bits=bits, group=group, in_features=K)


def dense_weight(w, dtype) -> jax.Array:
    """Dequantize a (possibly packed) weight leaf back to a dense array,
    trimming in-feature padding.  Plain arrays pass through with a cast."""
    if not isinstance(w, QTensor):
        return w.astype(dtype)
    if w.zero is not None:
        raise NotImplementedError(
            "dense_weight handles symmetric weight QTensors only")
    q = unpack_int4(w.q) if w.packed else w.q
    dq = dequant_weight(QTensor(q, w.scale, None, bits=w.bits, group=w.group),
                        dtype=dtype)
    if w.in_features is not None and w.in_features != dq.shape[-1]:
        dq = dq[..., :w.in_features]
    return dq


def quantize_params(cfg: ModelConfig, params: dict,
                    qcfg: Optional[QuantConfig] = None) -> dict:
    """RTN fake-quant every projection weight (QDQ, same pytree).

    Round-trips through the same codes + fp16 scales as ``pack_params``, so
    QDQ quality numbers are bit-exact with the packed serving weights.
    """
    qcfg = qcfg or cfg.quant

    def fn(path, leaf):
        if _is_weight(path) and leaf.ndim >= 2 and qcfg.w_bits < 16:
            qt = pack_weight(leaf, bits=qcfg.w_bits, group=qcfg.w_group_size,
                             clip_ratio=qcfg.w_clip_ratio, pack=False)
            return dense_weight(qt, leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fn, params)


def pack_params(cfg: ModelConfig, params: dict,
                qcfg: Optional[QuantConfig] = None) -> dict:
    """Replace projection weights with packed QTensors (serving format)."""
    qcfg = qcfg or cfg.quant

    def fn(path, leaf):
        if _is_weight(path) and leaf.ndim >= 2 and qcfg.w_bits < 16:
            return pack_weight(leaf, bits=qcfg.w_bits, group=qcfg.w_group_size,
                               clip_ratio=qcfg.w_clip_ratio)
        return leaf

    return jax.tree_util.tree_map_with_path(fn, params)


def qlinear_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """y = x @ dequant(qt).T — jnp fallback/oracle with f32 accumulation;
    the Pallas kernel fuses unpack+dequant+matmul in VMEM
    (repro.kernels.quant_matmul)."""
    w = dense_weight(qt, jnp.float32)           # [..., N, K] logical
    y = jnp.einsum("...i,oi->...o", x.astype(jnp.float32), w)
    return y.astype(x.dtype)


def qtensor_matmul(x: jax.Array, qt: QTensor) -> jax.Array:
    """Model-layer dispatch for QTensor weights: Pallas quant_matmul kernel
    for 2-D packed-int4 / int8 tensors, jnp fallback for 3-D expert stacks
    and exotic bit widths.  Symmetric weights only (zero must be None)."""
    if qt.q.ndim == 2 and qt.zero is None and (
            (qt.bits == 4 and qt.packed) or (qt.bits == 8 and not qt.packed)):
        from repro.kernels.quant_matmul.ops import quant_matmul
        return quant_matmul(x, qt)
    return qlinear_matmul(x, qt)


def memory_bytes(params: dict) -> int:
    """Total storage bytes of a (possibly packed) param tree."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(l.size) * l.dtype.itemsize for l in leaves)


def projection_weight_bytes(params: dict) -> Tuple[int, int]:
    """(actual_bytes, fp16_equivalent_bytes) over projection-weight leaves.

    ``actual_bytes`` counts what the tree really holds (packed codes + scales
    for QTensors, raw array bytes otherwise); ``fp16_equivalent_bytes`` is the
    logical element count at 2 bytes each — the QDQ-fp16 serving footprint the
    packed format replaces.
    """
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QTensor))[0]
    actual = fp16 = 0
    for path, leaf in flat:
        if not any(getattr(p, "key", None) in _WEIGHT_KEYS for p in path):
            continue
        if isinstance(leaf, QTensor):
            actual += sum(int(a.size) * a.dtype.itemsize
                          for a in (leaf.q, leaf.scale) if a is not None)
            logical = 1
            for d in leaf.logical_shape:
                logical *= int(d)
            fp16 += 2 * logical
        else:
            actual += int(leaf.size) * leaf.dtype.itemsize
            fp16 += 2 * int(leaf.size)
    return actual, fp16
