"""Quantized model parameters: QDQ simulation + int4-packed serving weights.

``quantize_params``       — fake-quantize (QDQ) all projection weights (RTN or
                            GPTQ given calibration inputs); quality-exact with
                            the paper's W4 setting, runs through normal matmuls.
``pack_params``           — int4-pack projection weights into QTensor storage
                            (serving memory format; consumed by the
                            quant_matmul kernel / qlinear_matmul fallback).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, QuantConfig
from repro.quant.quantizers import (QTensor, dequant_weight, fake_quant_weight,
                                    pack_int4, quant_weight, unpack_int4)

# projection-weight leaf names (rotation consumers/producers); everything else
# (norms, biases, embeddings, router, conv, SSM scalars) stays high precision.
_WEIGHT_KEYS = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "fc1", "fc2",
    "in_proj", "out_proj", "wq_a", "wq_b", "wkv_a", "wkv_b",
}


def _is_weight(path) -> bool:
    last = path[-1]
    name = getattr(last, "key", getattr(last, "name", None))
    return name in _WEIGHT_KEYS


def quantize_params(cfg: ModelConfig, params: dict,
                    qcfg: Optional[QuantConfig] = None) -> dict:
    """RTN fake-quant every projection weight (QDQ, same pytree)."""
    qcfg = qcfg or cfg.quant

    def fn(path, leaf):
        if _is_weight(path) and leaf.ndim >= 2:
            return fake_quant_weight(leaf, bits=qcfg.w_bits,
                                     group=qcfg.w_group_size,
                                     clip_ratio=qcfg.w_clip_ratio)
        return leaf

    return jax.tree_util.tree_map_with_path(fn, params)


def pack_params(cfg: ModelConfig, params: dict,
                qcfg: Optional[QuantConfig] = None) -> dict:
    """Replace projection weights with int4-packed QTensors (serving format)."""
    qcfg = qcfg or cfg.quant

    def fn(path, leaf):
        if _is_weight(path) and leaf.ndim >= 2 and leaf.shape[-1] % 2 == 0:
            qt = quant_weight(leaf, bits=qcfg.w_bits, group=qcfg.w_group_size,
                              clip_ratio=qcfg.w_clip_ratio)
            return QTensor(pack_int4(qt.q), qt.scale.astype(jnp.float16), None)
        return leaf

    return jax.tree_util.tree_map_with_path(fn, params)


def qlinear_matmul(x: jax.Array, qt: QTensor, group: int = -1) -> jax.Array:
    """y = x @ dequant(qt).T — jnp fallback; the Pallas kernel fuses unpack+
    dequant+matmul in VMEM (repro.kernels.quant_matmul)."""
    q = unpack_int4(qt.q)
    w = q.astype(x.dtype) * qt.scale.astype(x.dtype)
    return jnp.einsum("...i,oi->...o", x, w)


def memory_bytes(params: dict) -> int:
    """Total storage bytes of a (possibly packed) param tree."""
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(l.size) * l.dtype.itemsize for l in leaves)
