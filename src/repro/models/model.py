"""Top-level functional model API for all assigned architectures.

    init_params(cfg, key)                      -> params pytree
    forward(cfg, params, tokens, ...)          -> (logits, aux)
    loss_fn(cfg, params, batch, ...)           -> (loss, metrics)
    prefill(cfg, params, tokens, ...)          -> (logits, cache)
    decode_step(cfg, params, token, cache, pos)-> (logits, cache)

Caches are stacked over layers (leading dim) and consumed/produced by
lax.scan — identical structure across prefill/decode so serve_step lowers
with a fixed-size cache (decode shapes: cache length == shape.seq_len).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import (NO_SHARD, apply_norm, cross_entropy,
                                 norm_params, softcap)


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def init_params(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 16)
    D, V, L = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    p = {"embed": (jax.random.normal(ks[0], (V, D), jnp.float32) * 0.02).astype(dt)}
    if cfg.pos_embed == "learned":
        p["pos_dec"] = (jax.random.normal(ks[1], (cfg.max_seq_len, D),
                                          jnp.float32) * 0.01).astype(dt)
        if cfg.is_encoder_decoder:
            p["pos_enc"] = (jax.random.normal(ks[2], (cfg.encoder_seq, D),
                                              jnp.float32) * 0.01).astype(dt)

    if cfg.family == "ssm":
        p["layers"] = tfm.stacked(lambda k: tfm.mamba_block_params(cfg, k),
                                  jax.random.split(ks[3], L))
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups, rest = L // every, L % every
        gkeys = jax.random.split(ks[3], n_groups * every).reshape(n_groups, every, 2)
        p["mamba_groups"] = jax.vmap(jax.vmap(
            lambda k: tfm.mamba_block_params(cfg, k)))(gkeys)
        if rest:
            p["mamba_rest"] = tfm.stacked(
                lambda k: tfm.mamba_block_params(cfg, k),
                jax.random.split(ks[4], rest))
        p["shared"] = tfm.dense_block_params(cfg, ks[5])
    elif cfg.is_encoder_decoder:
        p["enc_layers"] = tfm.stacked(
            lambda k: tfm.dense_block_params(cfg, k),
            jax.random.split(ks[3], cfg.n_encoder_layers))
        p["dec_layers"] = tfm.stacked(
            lambda k: tfm.dense_block_params(cfg, k, cross_attn=True),
            jax.random.split(ks[4], L))
        p["enc_norm"] = norm_params(cfg, D)
    elif cfg.n_experts and cfg.n_dense_layers:
        p["dense_layers"] = tfm.stacked(
            lambda k: tfm.dense_block_params(cfg, k),
            jax.random.split(ks[3], cfg.n_dense_layers))
        p["moe_layers"] = tfm.stacked(
            lambda k: tfm.dense_block_params(cfg, k, use_moe=True),
            jax.random.split(ks[4], L - cfg.n_dense_layers))
    else:
        p["layers"] = tfm.stacked(
            lambda k: tfm.dense_block_params(cfg, k, use_moe=bool(cfg.n_experts)),
            jax.random.split(ks[3], L))

    p["final_norm"] = norm_params(cfg, D)
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[6], (V, D), jnp.float32)
                        / math.sqrt(D)).astype(dt)
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": (jax.random.normal(ks[7], (D, 2 * D), jnp.float32)
                     / math.sqrt(2 * D)).astype(dt),
            "norm": norm_params(cfg, D),
            "block": tfm.dense_block_params(cfg, ks[8]),
        }
    return p


# --------------------------------------------------------------------------- #
# Shared pieces
# --------------------------------------------------------------------------- #
def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def _head(cfg: ModelConfig, params: dict, x: jax.Array, shd=NO_SHARD) -> jax.Array:
    # fusion (core/rotations.py) unties embeddings: prefer lm_head if present
    w = params["lm_head"] if "lm_head" in params else params["embed"]
    logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    if "lm_head_bias" in params:
        logits = logits + params["lm_head_bias"].astype(logits.dtype)
    logits = softcap(logits, cfg.logit_softcap)
    return shd(logits, "logits")


def _windows(cfg: ModelConfig, n: int) -> jnp.ndarray:
    return tfm.layer_windows(cfg, n)


# --------------------------------------------------------------------------- #
# Forward (train / eval full sequence)
# --------------------------------------------------------------------------- #
def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frames: Optional[jax.Array] = None, shd=NO_SHARD, mesh=None,
            rot=None, want_mtp: bool = False):
    """tokens [B,S] -> (logits [B,S,V], aux dict)."""
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed(cfg, params, tokens)
    x = shd(x, "act_bsd")
    aux = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        x = tfm.mamba_stack(cfg, params["layers"], x, shd=shd)
    elif cfg.family == "hybrid":
        x = tfm.hybrid_stack(cfg, params, x, positions, shd=shd, mesh=mesh,
                             rot=rot)
    elif cfg.is_encoder_decoder:
        enc = frames.astype(x.dtype) + params["pos_enc"][None].astype(x.dtype)
        enc, _ = tfm.dense_stack(cfg, params["enc_layers"], enc,
                                 jnp.arange(enc.shape[1], dtype=jnp.int32),
                                 _windows(cfg, cfg.n_encoder_layers),
                                 shd=shd, causal=False)
        enc = apply_norm(cfg, params["enc_norm"], enc)
        x = x + params["pos_dec"][positions][None].astype(x.dtype)
        x, _ = tfm.dense_stack(cfg, params["dec_layers"], x, positions,
                               _windows(cfg, cfg.n_layers), shd=shd,
                               encoder_out=enc)
    elif "dense_layers" in params:        # deepseek: dense prefix + moe rest
        x, _ = tfm.dense_stack(cfg, params["dense_layers"], x, positions,
                               _windows(cfg, cfg.n_dense_layers), shd=shd,
                               mesh=mesh, rot=rot)
        x, aux = tfm.dense_stack(cfg, params["moe_layers"], x, positions,
                                 _windows(cfg, cfg.n_layers - cfg.n_dense_layers),
                                 shd=shd, mesh=mesh, rot=rot)
    else:
        x, aux = tfm.dense_stack(cfg, params["layers"], x, positions,
                                 _windows(cfg, cfg.n_layers), shd=shd,
                                 mesh=mesh, rot=rot)

    h_final = x
    x = apply_norm(cfg, params["final_norm"], x)
    logits = _head(cfg, params, x, shd=shd)
    extras = {"aux": aux}
    if want_mtp and cfg.mtp_depth and "mtp" in params:
        # MTP (deepseek-v3): predict token t+2 from h_t combined with emb_{t+1}
        mp = params["mtp"]
        h = apply_norm(cfg, mp["norm"], h_final[:, :-1])
        nxt = _embed(cfg, params, tokens[:, 1:])
        comb = jnp.concatenate([h, nxt], axis=-1)
        hin = jnp.einsum("bsk,dk->bsd", comb, mp["proj"].astype(comb.dtype))
        hmtp, _ = tfm.dense_block(cfg, mp["block"], hin, positions[:-1],
                                  shd=shd, mesh=mesh)
        extras["mtp_logits"] = _head(cfg, params, hmtp, shd=shd)
    return logits, extras


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, shd=NO_SHARD,
            mesh=None, rot=None):
    logits, extras = forward(cfg, params, batch["tokens"],
                             frames=batch.get("frames"), shd=shd, mesh=mesh,
                             rot=rot, want_mtp=True)
    loss = cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    metrics = {"ce": loss}
    if cfg.n_experts:
        loss = loss + cfg.moe_aux_loss * extras["aux"]
        metrics["aux"] = extras["aux"]
    if "mtp_logits" in extras:
        mtp_loss = cross_entropy(extras["mtp_logits"], batch["labels"][:, 1:])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# --------------------------------------------------------------------------- #
# Prefill: full forward that also builds the cache
# --------------------------------------------------------------------------- #
def _dense_stack_prefill(cfg, layers, x, positions, windows, shd=NO_SHARD,
                         mesh=None, rot=None, encoder_out=None):
    def body(carry, xs):
        x, = carry
        lp, win = xs
        h = apply_norm(cfg, lp["ln1"], x)
        h, kv = attn_mod.attention(cfg, lp["attn"], h, positions, window=win,
                                   shd=shd, rot=rot, return_kv=True)
        if cfg.sandwich_norm:
            h = apply_norm(cfg, lp["post_ln1"], h)
        x = x + h
        cross_kv = None
        if encoder_out is not None:
            h = apply_norm(cfg, lp["ln_x"], x)
            h, cross_kv = attn_mod.attention(cfg, lp["xattn"], h, positions,
                                             shd=shd, kv_override=encoder_out,
                                             return_kv=True)
            x = x + h
        h = apply_norm(cfg, lp["ln2"], x)
        if "moe" in lp:
            h, _ = ffn_mod.moe_forward(cfg, lp["moe"], h, shd=shd, mesh=mesh,
                                       rot=rot)
        else:
            h = ffn_mod.mlp_forward(cfg, lp["mlp"], h, shd=shd, rot=rot)
        if cfg.sandwich_norm:
            h = apply_norm(cfg, lp["post_ln2"], h)
        x = shd(x + h, "act_bsd")
        ys = (kv, cross_kv) if encoder_out is not None else kv
        return (x,), ys

    (x,), kvs = jax.lax.scan(body, (x,), (layers, windows))
    return x, kvs


def _mamba_stack_prefill(cfg, layers, x, shd=NO_SHARD):
    def body(x, lp):
        h = apply_norm(cfg, lp["ln"], x)
        out, st = ssm_mod.mamba2_forward(cfg, lp["mixer"], h, shd=shd,
                                         return_state=True)
        return shd(x + out, "act_bsd"), st
    return jax.lax.scan(body, x, layers)


def _kv_cache_dict(cfg, kvs):
    if cfg.attn_type == "mla":
        return {"ckv": kvs[0], "krope": kvs[1]}
    return {"k": kvs[0], "v": kvs[1]}


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frames: Optional[jax.Array] = None, shd=NO_SHARD, mesh=None,
            rot=None):
    """Returns (logits [B,S,V], cache)."""
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed(cfg, params, tokens)
    cache = {}

    if cfg.family == "ssm":
        x, st = _mamba_stack_prefill(cfg, params["layers"], x, shd=shd)
        cache["ssm"] = st
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(x, glp):
            x, st = _mamba_stack_prefill(cfg, glp, x, shd=shd)
            h = apply_norm(cfg, shared["ln1"], x)
            h, kv = attn_mod.attention(cfg, shared["attn"], h, positions,
                                       shd=shd, rot=rot, return_kv=True)
            x = x + h
            h = apply_norm(cfg, shared["ln2"], x)
            x = x + ffn_mod.mlp_forward(cfg, shared["mlp"], h, shd=shd, rot=rot)
            return x, (st, kv)

        x, (st_g, kv) = jax.lax.scan(group_body, x, params["mamba_groups"])
        cache["ssm_groups"] = st_g
        cache["kv_shared"] = _kv_cache_dict(cfg, kv)
        if "mamba_rest" in params:
            x, st_r = _mamba_stack_prefill(cfg, params["mamba_rest"], x, shd=shd)
            cache["ssm_rest"] = st_r
    elif cfg.is_encoder_decoder:
        enc = frames.astype(x.dtype) + params["pos_enc"][None].astype(x.dtype)
        enc, _ = tfm.dense_stack(cfg, params["enc_layers"], enc,
                                 jnp.arange(enc.shape[1], dtype=jnp.int32),
                                 _windows(cfg, cfg.n_encoder_layers),
                                 shd=shd, causal=False)
        enc = apply_norm(cfg, params["enc_norm"], enc)
        x = x + params["pos_dec"][positions][None].astype(x.dtype)
        x, (kv, cross_kv) = _dense_stack_prefill(
            cfg, params["dec_layers"], x, positions,
            _windows(cfg, cfg.n_layers), shd=shd, encoder_out=enc)
        cache["kv"] = _kv_cache_dict(cfg, kv)
        cache["cross"] = {"k": cross_kv[0], "v": cross_kv[1]}
    elif "dense_layers" in params:
        x, kv_d = _dense_stack_prefill(cfg, params["dense_layers"], x,
                                       positions,
                                       _windows(cfg, cfg.n_dense_layers),
                                       shd=shd, mesh=mesh, rot=rot)
        x, kv_m = _dense_stack_prefill(cfg, params["moe_layers"], x, positions,
                                       _windows(cfg, cfg.n_layers - cfg.n_dense_layers),
                                       shd=shd, mesh=mesh, rot=rot)
        cache["kv_dense"] = _kv_cache_dict(cfg, kv_d)
        cache["kv_moe"] = _kv_cache_dict(cfg, kv_m)
    else:
        x, kv = _dense_stack_prefill(cfg, params["layers"], x, positions,
                                     _windows(cfg, cfg.n_layers), shd=shd,
                                     mesh=mesh, rot=rot)
        cache["kv"] = _kv_cache_dict(cfg, kv)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _head(cfg, params, x, shd=shd)
    return logits, cache


# --------------------------------------------------------------------------- #
# Decode step
# --------------------------------------------------------------------------- #
def _dense_decode_stack(cfg, layers, x, kv_cache, pos, windows, shd=NO_SHARD,
                        mesh=None, rot=None, cross=None, cp_fn=None):
    def body(x, xs):
        if cross is not None:
            lp, cache_l, cr_l, win = xs
        else:
            lp, cache_l, win = xs
            cr_l = None
        h = apply_norm(cfg, lp["ln1"], x)
        h, new_cache = attn_mod.attn_decode(cfg, lp["attn"], h, cache_l, pos,
                                            window=win, shd=shd, rot=rot,
                                            cp_fn=cp_fn)
        if cfg.sandwich_norm:
            h = apply_norm(cfg, lp["post_ln1"], h)
        x = x + h
        if cr_l is not None:
            h = apply_norm(cfg, lp["ln_x"], x)
            B = h.shape[0]
            hd = cfg.resolved_head_dim
            from repro.models.common import linear
            q = linear(h, lp["xattn"]["wq"], lp["xattn"].get("bq"))
            q = q.reshape(B, cfg.n_heads, hd)
            Se = cr_l["k"].shape[1]
            kp = jnp.arange(Se, dtype=jnp.int32)
            o = attn_mod.decode_attn_scores(
                q, cr_l["k"], cr_l["v"], kp,
                jnp.full((B, 1), Se, jnp.int32))
            o = linear(o.reshape(B, 1, -1), lp["xattn"]["wo"],
                       lp["xattn"].get("bo"))
            x = x + o
        h = apply_norm(cfg, lp["ln2"], x)
        if "moe" in lp:
            h, _ = ffn_mod.moe_forward(cfg, lp["moe"], h, shd=shd, mesh=mesh,
                                       rot=rot)
        else:
            h = ffn_mod.mlp_forward(cfg, lp["mlp"], h, shd=shd, rot=rot)
        if cfg.sandwich_norm:
            h = apply_norm(cfg, lp["post_ln2"], h)
        x = x + h
        return x, new_cache

    xs = (layers, kv_cache, cross, windows) if cross is not None else \
         (layers, kv_cache, windows)
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, new_cache


def _mamba_decode_stack(cfg, layers, x, cache, shd=NO_SHARD, rot=None):
    sq = (rot or {}).get("state_quant")

    def body(x, xs):
        lp, cache_l = xs
        h = apply_norm(cfg, lp["ln"], x)
        out, st = ssm_mod.mamba2_decode(cfg, lp["mixer"], h, cache_l, shd=shd)
        if sq is not None:
            # recurrent-state QDQ at write time: bit-exact with the paged
            # runtime's int8 state slots (the QuantKV convention)
            st = {k: sq(v) for k, v in st.items()}
        return x + out, st
    return jax.lax.scan(body, x, (layers, cache))


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, cache: dict,
                pos, shd=NO_SHARD, mesh=None, rot=None, cp_fn=None):
    """token [B,1] int32; pos scalar int32. Returns (logits [B,1,V], cache)."""
    x = _embed(cfg, params, token)
    new_cache = {}

    if cfg.family == "ssm":
        x, st = _mamba_decode_stack(cfg, params["layers"], x, cache["ssm"],
                                    shd=shd, rot=rot)
        new_cache["ssm"] = st
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(x, xs):
            glp, st_l, kv_l = xs
            x, st = _mamba_decode_stack(cfg, glp, x, st_l, shd=shd, rot=rot)
            h = apply_norm(cfg, shared["ln1"], x)
            h, new_kv = attn_mod.attn_decode(cfg, shared["attn"], h, kv_l, pos,
                                             shd=shd, rot=rot, cp_fn=cp_fn)
            x = x + h
            h = apply_norm(cfg, shared["ln2"], x)
            x = x + ffn_mod.mlp_forward(cfg, shared["mlp"], h, shd=shd, rot=rot)
            return x, (st, new_kv)

        x, (st_g, kv) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["ssm_groups"], cache["kv_shared"]))
        new_cache["ssm_groups"] = st_g
        new_cache["kv_shared"] = kv
        if "mamba_rest" in params:
            x, st_r = _mamba_decode_stack(cfg, params["mamba_rest"], x,
                                          cache["ssm_rest"], shd=shd, rot=rot)
            new_cache["ssm_rest"] = st_r
    elif cfg.is_encoder_decoder:
        x = x + params["pos_dec"][pos][None, None].astype(x.dtype)
        x, kv = _dense_decode_stack(cfg, params["dec_layers"], x, cache["kv"],
                                    pos, _windows(cfg, cfg.n_layers), shd=shd,
                                    cross=cache["cross"], cp_fn=cp_fn)
        new_cache["kv"] = kv
        new_cache["cross"] = cache["cross"]
    elif "dense_layers" in params:
        x, kv_d = _dense_decode_stack(cfg, params["dense_layers"], x,
                                      cache["kv_dense"], pos,
                                      _windows(cfg, cfg.n_dense_layers),
                                      shd=shd, mesh=mesh, rot=rot, cp_fn=cp_fn)
        x, kv_m = _dense_decode_stack(cfg, params["moe_layers"], x,
                                      cache["kv_moe"], pos,
                                      _windows(cfg, cfg.n_layers - cfg.n_dense_layers),
                                      shd=shd, mesh=mesh, rot=rot, cp_fn=cp_fn)
        new_cache["kv_dense"] = kv_d
        new_cache["kv_moe"] = kv_m
    else:
        x, kv = _dense_decode_stack(cfg, params["layers"], x, cache["kv"], pos,
                                    _windows(cfg, cfg.n_layers), shd=shd,
                                    mesh=mesh, rot=rot, cp_fn=cp_fn)
        new_cache["kv"] = kv

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _head(cfg, params, x, shd=shd)
    return logits, new_cache


# --------------------------------------------------------------------------- #
# Paged serve path (page-pool caches + state slots; see repro.serve)
# --------------------------------------------------------------------------- #
def supports_paged(cfg: ModelConfig) -> bool:
    """The paged runtime covers every decoder-only family: single-stack and
    mixed dense+MoE stacks (GQA or MLA latent pages), SSM state pools, and
    hybrid interleavings.  Only encoder-decoder models fall back to the
    legacy lockstep engine."""
    if cfg.is_encoder_decoder:
        return False
    if cfg.family == "ssm":
        return cfg.attn_type == "none"
    if cfg.family == "hybrid":
        return cfg.attn_type == "gqa" and cfg.pos_embed == "rope"
    return (cfg.family in ("dense", "moe", "vlm")
            and cfg.attn_type in ("gqa", "mla") and cfg.pos_embed == "rope")


def _paged_adapters(cfg: ModelConfig, kv_bits: int, state_bits: int) -> dict:
    from repro.serve.cache_adapters import adapters_for
    return adapters_for(cfg, kv_bits=kv_bits, state_bits=state_bits)


def _paged_block_tail(cfg, lp, x, h, shd, mesh, rot):
    """Post-attention residual + FFN shared by paged decode/prefill bodies;
    per-layer FFN dispatch ("moe" in the layer pytree) covers mixed
    dense+MoE stacks with no extra machinery."""
    if cfg.sandwich_norm:
        h = apply_norm(cfg, lp["post_ln1"], h)
    x = x + h
    h = apply_norm(cfg, lp["ln2"], x)
    if "moe" in lp:
        h, _ = ffn_mod.moe_forward(cfg, lp["moe"], h, shd=shd, mesh=mesh,
                                   rot=rot)
    else:
        h = ffn_mod.mlp_forward(cfg, lp["mlp"], h, shd=shd, rot=rot)
    if cfg.sandwich_norm:
        h = apply_norm(cfg, lp["post_ln2"], h)
    return x + h


def _paged_step(cfg: ModelConfig, params: dict, x: jax.Array, pool: dict,
                ctx, carry, shd, mesh, rot, kv_bits: int, state_bits: int):
    """Shared paged body: run the layer stack against the pool, dispatching
    each layer through its cache adapter (``ctx`` type selects decode vs
    prefill-chunk behaviour).  Returns (hidden, new_pool, new_carry)."""
    ads = _paged_adapters(cfg, kv_bits, state_bits)
    new_pool: dict = {}
    new_carry: dict = {} if carry is not None else None

    def attn_body(ad):
        def body(x, xs):
            lp, pool_l, win = xs
            h = apply_norm(cfg, lp["ln1"], x)
            h, new_pool_l, _ = ad.attend_or_mix(lp["attn"], h, pool_l, None,
                                                ctx, window=win, shd=shd,
                                                rot=rot)
            return _paged_block_tail(cfg, lp, x, h, shd, mesh, rot), new_pool_l
        return body

    if cfg.family == "ssm":
        ad = ads["ssm"]
        carry_ssm = None if carry is None else carry["ssm"]

        def body(x, xs):
            lp, st_l, cr_l = xs
            h = apply_norm(cfg, lp["ln"], x)
            out, new_st, new_cr = ad.attend_or_mix(lp["mixer"], h, st_l,
                                                   cr_l, ctx, shd=shd,
                                                   rot=rot)
            return x + out, (new_st, new_cr)

        x, (new_st, new_cr) = jax.lax.scan(
            body, x, (params["layers"], pool["ssm"], carry_ssm))
        new_pool["ssm"] = new_st
        if new_carry is not None:
            new_carry["ssm"] = new_cr
    elif cfg.family == "hybrid":
        x, new_pool, new_carry = _paged_hybrid(cfg, ads, params, x, pool,
                                               ctx, carry, shd, mesh, rot)
    else:
        if "dense_layers" in params:      # mixed: dense prefix + MoE rest,
            nd = cfg.n_dense_layers       # separate sub-states (no slice/
            x, new_pool["attn_dense"] = jax.lax.scan(    # concat copies)
                attn_body(ads["attn_dense"]), x,
                (params["dense_layers"], pool["attn_dense"],
                 _windows(cfg, nd)))
            x, new_pool["attn_moe"] = jax.lax.scan(
                attn_body(ads["attn_moe"]), x,
                (params["moe_layers"], pool["attn_moe"],
                 _windows(cfg, cfg.n_layers - nd)))
        else:
            x, new_pool["attn"] = jax.lax.scan(
                attn_body(ads["attn"]), x,
                (params["layers"], pool["attn"], _windows(cfg, cfg.n_layers)))
        if new_carry is not None:
            for name in pool:
                new_carry[name] = None if carry is None else carry.get(name)
    return x, new_pool, new_carry


def _paged_hybrid(cfg, ads, params, x, pool, ctx, carry, shd, mesh, rot):
    """Zamba2-style hybrid: groups of ``shared_attn_every`` mamba layers with
    the shared attention block (its KV paged per application) between them."""
    every = cfg.shared_attn_every
    n_groups, rest = cfg.n_layers // every, cfg.n_layers % every
    shared = params["shared"]
    ssm_ad, attn_ad = ads["ssm"], ads["attn"]

    def grp(tree):
        return jax.tree.map(
            lambda a: a[:n_groups * every].reshape((n_groups, every)
                                                   + a.shape[1:]), tree)

    def tail(tree):
        return jax.tree.map(lambda a: a[n_groups * every:], tree)

    carry_ssm = None if carry is None else carry["ssm"]
    g_state, r_state = grp(pool["ssm"]), tail(pool["ssm"])
    g_carry = None if carry_ssm is None else grp(carry_ssm)
    r_carry = None if carry_ssm is None else tail(carry_ssm)

    def mamba_body(x, xs):
        lp, st_l, cr_l = xs
        h = apply_norm(cfg, lp["ln"], x)
        out, new_st, new_cr = ssm_ad.attend_or_mix(lp["mixer"], h, st_l,
                                                   cr_l, ctx, shd=shd,
                                                   rot=rot)
        return x + out, (new_st, new_cr)

    def group_body(x, xs):
        glp, gst, gcr, kv_l = xs
        x, (new_st, new_cr) = jax.lax.scan(mamba_body, x, (glp, gst, gcr))
        h = apply_norm(cfg, shared["ln1"], x)
        h, new_kv, _ = attn_ad.attend_or_mix(shared["attn"], h, kv_l, None,
                                             ctx, shd=shd, rot=rot)
        x = x + h
        h = apply_norm(cfg, shared["ln2"], x)
        x = x + ffn_mod.mlp_forward(cfg, shared["mlp"], h, shd=shd, rot=rot)
        return x, (new_st, new_cr, new_kv)

    x, (g_new, g_new_cr, new_kv) = jax.lax.scan(
        group_body, x, (params["mamba_groups"], g_state, g_carry,
                        pool["attn"]))
    flat = jax.tree.map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), g_new)
    flat_cr = None if g_new_cr is None else jax.tree.map(
        lambda a: a.reshape((n_groups * every,) + a.shape[2:]), g_new_cr)
    if rest:
        x, (r_new, r_new_cr) = jax.lax.scan(
            mamba_body, x, (params["mamba_rest"], r_state, r_carry))
        flat = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                            flat, r_new)
        if flat_cr is not None:
            flat_cr = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   flat_cr, r_new_cr)
    new_pool = {"ssm": flat, "attn": new_kv}
    new_carry = None if carry is None else {"ssm": flat_cr,
                                            "attn": carry.get("attn")}
    return x, new_pool, new_carry


def paged_decode_step(cfg: ModelConfig, params: dict, token: jax.Array,
                      pool: dict, block_tables: jax.Array,
                      positions: jax.Array, lengths: jax.Array,
                      state_slots: Optional[jax.Array] = None,
                      shd=NO_SHARD, mesh=None, rot=None, kv_bits: int = 4,
                      state_bits: int = 8, tp_plan=None):
    """token [B,1]; pool: nested per-adapter state (leaves lead with the
    layer dim); positions/lengths [B] — each slot advances at its own
    position; state_slots [B] physical state slot per lane (0 = null slot,
    for idle lanes).  Returns (logits [B,1,V], new pool).

    With a ``tp_plan`` (repro.dist.sharding.serve_tp_plan) the whole step
    runs under one shard_map over the mesh 'model' axis: every shard traces
    the same mesh-oblivious body against its local weight/page blocks, and
    the only collectives are the psum seams in the layer code (exactly one
    per layer on the quantized-artifact path)."""
    if not supports_paged(cfg):
        raise NotImplementedError(f"no paged decode for {cfg.arch_id}")
    from repro.serve.cache_adapters import DecodeCtx
    if state_slots is None:
        if cfg.family in ("ssm", "hybrid"):
            # defaulting to slot 0 would read/write the reserved null slot —
            # the recurrence would silently reset every token
            raise ValueError(
                f"{cfg.arch_id}: recurrent-state families require explicit "
                "state_slots (physical slot per lane; 0 is the null slot)")
        state_slots = jnp.zeros_like(lengths)
    if tp_plan is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.models.common import tp_context
        lcfg = tp_plan.local_cfg()

        def _body(params_l, token_l, pool_l, bt, pos, lens, slots):
            with tp_context(ffn=tp_plan.ffn_sharded, moe=tp_plan.moe_sharded):
                return paged_decode_step(
                    lcfg, params_l, token_l, pool_l, bt, pos, lens, slots,
                    rot=rot, kv_bits=kv_bits, state_bits=state_bits)

        step = shard_map(
            _body, mesh=tp_plan.mesh,
            in_specs=(tp_plan.param_specs, P(), tp_plan.pool_specs,
                      P(), P(), P(), P()),
            out_specs=(P(), tp_plan.pool_specs),
            check_rep=False)
        return step(params, token, pool, block_tables, positions, lengths,
                    state_slots)
    ctx = DecodeCtx(block_tables, positions, lengths, state_slots)
    x = _embed(cfg, params, token)
    x, new_pool, _ = _paged_step(cfg, params, x, pool, ctx, None, shd, mesh,
                                 rot, kv_bits, state_bits)
    x = apply_norm(cfg, params["final_norm"], x)
    return _head(cfg, params, x, shd=shd), new_pool


def paged_prefill_chunk(cfg: ModelConfig, params: dict, tokens: jax.Array,
                        pool: dict, block_table: jax.Array, start,
                        carry: Optional[dict] = None, chunk_len=None,
                        shd=NO_SHARD, mesh=None, rot=None, kv_bits: int = 4,
                        state_bits: int = 8,
                        n_pages: Optional[int] = None, tp_plan=None):
    """tokens [1,C] (one chunk of one prompt); start: scalar chunk offset;
    carry: fp32 recurrent-state carry from the previous chunk (see
    ``init_prefill_carry``); chunk_len: valid tokens in the chunk (padding
    must not advance recurrent state); n_pages: static page prefix covering
    the chunk.  Returns (logits [1,C,V], new pool, new carry).

    ``tp_plan`` runs the chunk tensor-parallel under shard_map (see
    ``paged_decode_step``); the fp32 recurrent carry replicates — it spans
    the full model dims by construction."""
    if not supports_paged(cfg):
        raise NotImplementedError(f"no paged prefill for {cfg.arch_id}")
    from repro.serve.cache_adapters import PrefillCtx
    if carry is None:
        carry = init_prefill_carry(cfg, kv_bits=kv_bits,
                                   state_bits=state_bits)
    if chunk_len is None:
        chunk_len = tokens.shape[1]
    if tp_plan is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.models.common import tp_context
        lcfg = tp_plan.local_cfg()

        def _body(params_l, tokens_l, pool_l, bt, st, carry_l, cl):
            with tp_context(ffn=tp_plan.ffn_sharded, moe=tp_plan.moe_sharded):
                return paged_prefill_chunk(
                    lcfg, params_l, tokens_l, pool_l, bt, st, carry_l, cl,
                    rot=rot, kv_bits=kv_bits, state_bits=state_bits,
                    n_pages=n_pages)

        step = shard_map(
            _body, mesh=tp_plan.mesh,
            in_specs=(tp_plan.param_specs, P(), tp_plan.pool_specs,
                      P(), P(), P(), P()),
            out_specs=(P(), tp_plan.pool_specs, P()),
            check_rep=False)
        return step(params, tokens, pool, block_table,
                    jnp.asarray(start, jnp.int32), carry,
                    jnp.asarray(chunk_len, jnp.int32))
    ctx = PrefillCtx(block_table, jnp.asarray(start, jnp.int32),
                     jnp.asarray(chunk_len, jnp.int32), n_pages)
    x = _embed(cfg, params, tokens)
    x, new_pool, new_carry = _paged_step(cfg, params, x, pool, ctx, carry,
                                         shd, mesh, rot, kv_bits, state_bits)
    x = apply_norm(cfg, params["final_norm"], x)
    return _head(cfg, params, x, shd=shd), new_pool, new_carry


def init_prefill_carry(cfg: ModelConfig, kv_bits: int = 4,
                       state_bits: int = 8) -> dict:
    """fp32 single-sequence recurrent-state carry for chunked prefill (None
    per adapter kind that has no recurrent state)."""
    ads = _paged_adapters(cfg, kv_bits, state_bits)
    return {name: ad.init_carry() for name, ad in ads.items()}


def commit_prefill_state(cfg: ModelConfig, pool: dict, carry: dict,
                         phys_slot, kv_bits: int = 4,
                         state_bits: int = 8) -> dict:
    """Quantize a finished prefill's fp32 carry into its state slot — the
    single quantization event at the prefill->decode handoff."""
    ads = _paged_adapters(cfg, kv_bits, state_bits)
    return {name: ads[name].commit(pool[name], (carry or {}).get(name),
                                   phys_slot)
            for name in pool}


def init_pool_slot(cfg: ModelConfig, pool: dict, phys_slot,
                   kv_bits: int = 4, state_bits: int = 8) -> dict:
    """Zero one physical state slot (admission hygiene; pages are
    write-before-read and need no reset)."""
    ads = _paged_adapters(cfg, kv_bits, state_bits)
    return {name: ads[name].init_slot(pool[name], phys_slot)
            for name in pool}


def copy_pool_page(cfg: ModelConfig, pool: dict, src, dst,
                   kv_bits: int = 4, state_bits: int = 8) -> dict:
    """Duplicate one physical page across every page-bearing adapter
    (copy-on-write at admission when a sequence must append into a shared,
    partially filled prefix page).  Per-slot recurrent state is a no-op."""
    ads = _paged_adapters(cfg, kv_bits, state_bits)
    return {name: ads[name].copy_page(pool[name], src, dst)
            for name in pool}


# --------------------------------------------------------------------------- #
# Empty cache factories (decode-shape dry-run: cache of seq_len, one new token)
# --------------------------------------------------------------------------- #
def make_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    if cfg.family == "ssm":
        return {"ssm": ssm_mod.init_ssm_cache(cfg, batch, cfg.n_layers)}
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups, rest = cfg.n_layers // every, cfg.n_layers % every
        c = {"ssm_groups": jax.tree.map(
                lambda x: x.reshape((n_groups, every) + x.shape[1:]),
                ssm_mod.init_ssm_cache(cfg, batch, n_groups * every)),
             "kv_shared": attn_mod.init_cache(cfg, batch, max_seq, dtype,
                                              n_layers=n_groups)}
        if rest:
            c["ssm_rest"] = ssm_mod.init_ssm_cache(cfg, batch, rest)
        return c
    if cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim
        return {"kv": attn_mod.init_cache(cfg, batch, max_seq, dtype),
                "cross": {
                    "k": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                                    cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                                    cfg.n_kv_heads, hd), dtype)}}
    if cfg.n_experts and cfg.n_dense_layers:
        return {"kv_dense": attn_mod.init_cache(cfg, batch, max_seq, dtype,
                                                n_layers=cfg.n_dense_layers),
                "kv_moe": attn_mod.init_cache(
                    cfg, batch, max_seq, dtype,
                    n_layers=cfg.n_layers - cfg.n_dense_layers)}
    return {"kv": attn_mod.init_cache(cfg, batch, max_seq, dtype)}
