"""Shared model building blocks: norms, RoPE, init, softcap, sharding helper."""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------- #
# Sharding helper: models call shd(x, spec_name); a NoSharding default makes
# every model runnable on a single device with zero mesh machinery.
# --------------------------------------------------------------------------- #
class NoSharding:
    def __call__(self, x, name: str):
        return x


NO_SHARD = NoSharding()


# --------------------------------------------------------------------------- #
# Tensor-parallel context (serve TP under shard_map).
#
# The paged decode/prefill programs run their whole body inside one shard_map
# over the mesh 'model' axis; the layer code is mesh-oblivious except for the
# psum seams at the output projections.  Those seams consult this contextvar
# (same pattern as the activation-quant context in repro.quant.context):
# outside a TP trace every tp_psum is the identity, so single-device code is
# untouched.  ``ffn``/``moe`` record whether the FFN / MoE expert stacks are
# sharded in this trace — a replicated sub-block must NOT psum (it would
# multiply its output by the shard count).
# --------------------------------------------------------------------------- #
class TPContext:
    __slots__ = ("axis", "ffn", "moe")

    def __init__(self, axis: str, ffn: bool, moe: bool):
        self.axis = axis
        self.ffn = ffn
        self.moe = moe


_TP_CTX: contextvars.ContextVar = contextvars.ContextVar("tp_ctx", default=None)


def get_tp_ctx() -> Optional[TPContext]:
    return _TP_CTX.get()


@contextlib.contextmanager
def tp_context(axis: str = "model", ffn: bool = False, moe: bool = False):
    token = _TP_CTX.set(TPContext(axis, ffn, moe))
    try:
        yield
    finally:
        _TP_CTX.reset(token)


def tp_psum_attn(x: jax.Array) -> jax.Array:
    """Reduce a head-sharded attention output projection (identity w/o TP)."""
    ctx = _TP_CTX.get()
    return jax.lax.psum(x, ctx.axis) if ctx is not None else x


def tp_psum_ffn(x: jax.Array) -> jax.Array:
    """Reduce an f-sharded FFN down projection; identity when the FFN is
    replicated in this trace (online R4 pins the full hidden per shard)."""
    ctx = _TP_CTX.get()
    return jax.lax.psum(x, ctx.axis) if (ctx is not None and ctx.ffn) else x


def tp_psum_moe(x: jax.Array) -> jax.Array:
    """Combine expert-sharded MoE partial outputs (identity when replicated)."""
    ctx = _TP_CTX.get()
    return jax.lax.psum(x, ctx.axis) if (ctx is not None and ctx.moe) else x


def tp_row_linear(x: jax.Array, w, b: Optional[jax.Array] = None, *,
                  kind: str = "attn") -> jax.Array:
    """``linear`` for a row-sharded (in-feature-partitioned) projection.

    The per-token activation quantizer (repro.quant.context) derives its grid
    from the row's min/max.  Under TP the inputs of ``wo`` / the FFN down
    projection are shard-local — 1/tp of the feature axis — so a naive hook
    application would quantize on a different grid than the single-device
    engine and break token parity.  Fix: pmin/pmax the per-token extremes
    over the model axis (two 4-byte-per-token collectives, no psum) and
    append them as sentinel columns; the hook's local min/max then equal the
    global ones, reproducing the full-axis grid bit-for-bit, and the matmul
    runs with the hook disarmed.  ``kind="ffn"`` projections are only
    sharded when the trace's ffn flag is set (online R4 replicates them).
    Identity-cost outside TP or without a quant hook.
    """
    ctx = _TP_CTX.get()
    sharded = ctx is not None and (kind == "attn" or ctx.ffn)
    from repro.quant import context as qctx
    aq = qctx.get_act_quant()
    if not sharded or aq is None:
        return linear(x, w, b)
    lo = jax.lax.pmin(jnp.min(x, axis=-1, keepdims=True), ctx.axis)
    hi = jax.lax.pmax(jnp.max(x, axis=-1, keepdims=True), ctx.axis)
    xq = aq(jnp.concatenate([x, lo, hi], axis=-1))[..., :-2]
    with qctx.act_quant(None):
        return linear(xq, w, b)


def tp_shard_index() -> int:
    """This shard's index along the TP axis (0 outside a TP trace)."""
    ctx = _TP_CTX.get()
    return jax.lax.axis_index(ctx.axis) if ctx is not None else 0


def tp_moe_sharded() -> bool:
    ctx = _TP_CTX.get()
    return bool(ctx is not None and ctx.moe)


def expected_structural_tp_psums(cfg: ModelConfig, plan) -> int:
    """Structural psum count of ONE TP decode/prefill program trace.

    This module owns the psum seams (``tp_psum_attn``/``tp_psum_ffn``/
    ``tp_psum_moe``), so it also owns the expected census: single-stack
    attention families scan one shared layer body, which the jaxpr prints
    once — one attention psum plus the FFN psum when that sub-block shards.
    Mixed stacks (MoE interleaves, hybrid shared-attention groups) trace
    config-dependent multi-scan programs; the structural census is not
    declared for them (the analytic per-token count stays
    ``ServeTPPlan.psums_per_token``).
    """
    if plan is None:
        return 0
    if cfg.attn_type != "gqa" or cfg.n_experts or cfg.family == "hybrid":
        raise ValueError(
            f"structural TP psum census is declared only for single-stack "
            f"GQA families; {cfg.arch_id} (family={cfg.family}, "
            f"attn={cfg.attn_type}) traces a config-dependent multi-scan "
            "program")
    return 1 + int(plan.ffn_sharded)


def tp_decode_collective_contract(cfg: ModelConfig, plan, trace, *,
                                  name: str = "serve/tp-decode-collectives"):
    """The TP decode program's collective contract, declared at the seam
    that inserts the psums: exactly ``expected_structural_tp_psums`` psum
    equations, every one inside the layer scan body, and no
    ``all_gather``/``all_to_all`` anywhere (the paged TP path never
    rematerializes a full projection or gathers KV).

    ``trace`` is a thunk returning the decode program's ``ClosedJaxpr``
    (the engine supplies it); pytest and the CI gate consume this one
    declaration via ``repro.analysis.run_contract``.
    """
    from repro.analysis.rules import CollectiveCensus, Contract
    return Contract(
        name=name, owner="repro.models.common",
        checks=(CollectiveCensus(
            expect={"psum": expected_structural_tp_psums(cfg, plan)},
            forbid=("all_gather", "all_to_all"),
            require_in_scan=True),),
        trace=trace,
        description="one psum per layer-scan body on the TP decode path "
                    "(FFN psum only when the plan shards it); gathers "
                    "forbidden")


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_params(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------------- #
def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def dense_init(key, shape, in_dim: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def linear(x: jax.Array, w, b: Optional[jax.Array] = None) -> jax.Array:
    """y = x @ w.T (+ b).  w: [out, in] array or packed QTensor.

    Consults the trace-time activation-quant context (repro.quant.context):
    when set, x is per-token fake-quantized first — the paper's A4/A8 path.
    QTensor weights (pack_params / artifact cold-boot) dispatch through the
    Pallas quant_matmul kernel so int4 weights stay int4 in device memory.
    """
    from repro.quant import context as qctx
    aq = qctx.get_act_quant()
    if aq is not None:
        x = aq(x)
    from repro.quant.quantizers import QTensor
    if isinstance(w, QTensor):
        from repro.quant.qlinear import qtensor_matmul
        y = qtensor_matmul(x, w)
    else:
        y = jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy with optional z-loss; logits [..., V].

    Uses a one-hot contraction (not take_along_axis) so vocab-TP-sharded
    logits reduce with a psum instead of an all-gather under GSPMD.
    """
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + lmax[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(shifted * onehot, axis=-1) + lmax[..., 0].astype(jnp.float32)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
