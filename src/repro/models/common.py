"""Shared model building blocks: norms, RoPE, init, softcap, sharding helper."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# --------------------------------------------------------------------------- #
# Sharding helper: models call shd(x, spec_name); a NoSharding default makes
# every model runnable on a single device with zero mesh machinery.
# --------------------------------------------------------------------------- #
class NoSharding:
    def __call__(self, x, name: str):
        return x


NO_SHARD = NoSharding()


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_params(cfg: ModelConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------------- #
def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def dense_init(key, shape, in_dim: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def linear(x: jax.Array, w, b: Optional[jax.Array] = None) -> jax.Array:
    """y = x @ w.T (+ b).  w: [out, in] array or packed QTensor.

    Consults the trace-time activation-quant context (repro.quant.context):
    when set, x is per-token fake-quantized first — the paper's A4/A8 path.
    QTensor weights (pack_params / artifact cold-boot) dispatch through the
    Pallas quant_matmul kernel so int4 weights stay int4 in device memory.
    """
    from repro.quant import context as qctx
    aq = qctx.get_act_quant()
    if aq is not None:
        x = aq(x)
    from repro.quant.quantizers import QTensor
    if isinstance(w, QTensor):
        from repro.quant.qlinear import qtensor_matmul
        y = qtensor_matmul(x, w)
    else:
        y = jnp.einsum("...i,oi->...o", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy with optional z-loss; logits [..., V].

    Uses a one-hot contraction (not take_along_axis) so vocab-TP-sharded
    logits reduce with a psum instead of an all-gather under GSPMD.
    """
    lmax = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - lmax).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) \
        + lmax[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    ll = jnp.sum(shifted * onehot, axis=-1) + lmax[..., 0].astype(jnp.float32)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
