"""Feed-forward blocks: SwiGLU, plain GELU MLP, and two MoE implementations.

MoE paths:
  * ``einsum``  — GShard-style *grouped* capacity dispatch under GSPMD; right
    for few experts (Grok-1: 8e top-2).  Tokens are split into groups of
    ``MOE_GROUP`` so the one-hot dispatch tensor is O(T * g * K), not O(T^2);
    groups shard over data, experts over 'model' (EP) and XLA emits the
    all-to-alls from sharding propagation.
  * ``ragged`` — sort-by-expert + ``jax.lax.ragged_dot`` (megablox-style).
    Under a mesh this is an explicit shard_map: tokens are sequence-split over
    the EP axis, bucketed by destination expert shard, exchanged with
    all_to_all, matmul'd with the local expert slice via ragged_dot, and sent
    back.  Right for many experts (DeepSeek-V3: 256e top-8) where one-hot
    dispatch would be enormous.  Single-device fallback runs sort+ragged
    locally; tiny token counts (decode) use a psum-combine variant.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import (NO_SHARD, dense_init, linear, tp_moe_sharded,
                                 tp_psum_ffn, tp_psum_moe, tp_row_linear,
                                 tp_shard_index)
from repro.quant.qlinear import dense_weight

MOE_GROUP = 2048          # einsum-path dispatch group size (tokens)


# --------------------------------------------------------------------------- #
# Dense MLPs
# --------------------------------------------------------------------------- #
def mlp_params(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (f, d), d, dt),
            "w_up": dense_init(ks[1], (f, d), d, dt),
            "w_down": dense_init(ks[2], (d, f), f, dt),
        }
    # plain MLP with bias (whisper)
    return {
        "fc1": dense_init(ks[0], (f, d), d, dt),
        "b1": jnp.zeros((f,), dt),
        "fc2": dense_init(ks[1], (d, f), f, dt),
        "b2": jnp.zeros((d,), dt),
    }


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array, shd=NO_SHARD,
                rot=None) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"])
        h = shd(h, "act_bsf")
        if rot is not None and rot.get("r4") is not None:
            h = rot["r4"](h)   # online Hadamard before down-proj (R4)
        # serve TP: when the FFN is f-sharded, gate/up are column-sharded and
        # w_down row-sharded — psum the partial down projection (identity
        # when replicated, e.g. under an online R4 that needs the full f dim)
        return tp_psum_ffn(tp_row_linear(h, p["w_down"], kind="ffn"))
    h = jax.nn.gelu(linear(x, p["fc1"], p["b1"]))
    h = shd(h, "act_bsf")
    if rot is not None and rot.get("r4") is not None:
        h = rot["r4"](h)
    y = tp_psum_ffn(tp_row_linear(h, p["fc2"], kind="ffn"))
    return y + p["b2"].astype(y.dtype)


# --------------------------------------------------------------------------- #
# MoE: routing
# --------------------------------------------------------------------------- #
def moe_params(cfg: ModelConfig, key) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.ffn_hidden
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (e, d), d, jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (e, f, d), d, dt),
        "w_up": dense_init(ks[2], (e, f, d), d, dt),
        "w_down": dense_init(ks[3], (e, d, f), f, dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(cfg, ks[4], d_ff=cfg.ffn_hidden * cfg.n_shared_experts)
    if cfg.router_scale:
        p["router_bias"] = jnp.zeros((e,), jnp.float32)  # ds-v3 aux-free balancing
    return p


def _route(cfg: ModelConfig, router, router_bias, x: jax.Array):
    """x [T,D] -> (weights [T,k], idx [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,ed->te", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    if cfg.router_scale:                      # deepseek-v3: sigmoid + bias + renorm
        scores = jax.nn.sigmoid(logits)
        sel = scores + router_bias[None, :]
        _, idx = jax.lax.top_k(sel, cfg.moe_top_k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
        probs = scores / (jnp.sum(scores, -1, keepdims=True) + 1e-20)
    else:                                     # softmax routing (grok/mixtral style)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.moe_top_k)
        w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-20)
    E = logits.shape[-1]
    hot = jax.nn.one_hot(idx[:, 0], E)        # switch-style load-balance aux
    aux = E * jnp.sum(jnp.mean(hot, axis=0) * jnp.mean(probs, axis=0))
    return w, idx, aux


# --------------------------------------------------------------------------- #
# MoE: grouped capacity/einsum path (GSPMD)
# --------------------------------------------------------------------------- #
def moe_einsum(cfg: ModelConfig, p: dict, x: jax.Array,
               shd=NO_SHARD, rot=None) -> Tuple[jax.Array, jax.Array]:
    """x [T,D] -> (y [T,D], aux). GShard grouped dispatch."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    g = min(MOE_GROUP, T)
    G = T // g
    assert G * g == T, f"token count {T} not divisible by group {g}"
    w, idx, aux = _route(cfg, p["router"], p.get("router_bias"), x)
    cap = max(1, int(cfg.capacity_factor * g * K / E))

    idx_g = idx.reshape(G, g * K)
    onehot = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)          # [G,gK,E]
    slot = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1    # [G,gK] 0-based
    keep = (slot >= 0) & (slot < cap)
    oe = jax.nn.one_hot(idx_g, E, dtype=x.dtype)                # [G,gK,E]
    oslot = jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                           dtype=x.dtype)[..., :cap]            # [G,gK,cap]
    disp = jnp.einsum("gae,gac->gaec", oe, oslot)               # [G,gK,E,cap]
    disp = disp.reshape(G, g, K, E, cap)
    wcomb = jnp.einsum("gtkec,gtk->gtec", disp,
                       w.reshape(G, g, K).astype(x.dtype))      # [G,g,E,cap]
    disp = disp.sum(2)                                          # [G,g,E,cap]

    xg = x.reshape(G, g, D)
    xg = shd(xg, "moe_gtd")
    xe = jnp.einsum("gtd,gtec->gecd", xg, disp)                 # [G,E,cap,D]
    xe = shd(xe, "moe_gecd")
    # expert stacks are 3-D: packed QTensors dequantize here (the 2-D Pallas
    # GEMM covers the dense/shared projections via ``linear``)
    wg = dense_weight(p["w_gate"], x.dtype)
    wu = dense_weight(p["w_up"], x.dtype)
    wd = dense_weight(p["w_down"], x.dtype)
    h = jax.nn.silu(jnp.einsum("gecd,efd->gecf", xe, wg)) \
        * jnp.einsum("gecd,efd->gecf", xe, wu)
    if rot is not None and rot.get("r4") is not None:
        h = rot["r4"](h)      # online Hadamard before expert down-proj (R4)
    ye = jnp.einsum("gecf,edf->gecd", h, wd)                    # [G,E,cap,D]
    ye = shd(ye, "moe_gecd")
    y = jnp.einsum("gecd,gtec->gtd", ye, wcomb)
    return y.reshape(T, D), aux


# --------------------------------------------------------------------------- #
# MoE: sort + ragged_dot paths
# --------------------------------------------------------------------------- #
def _ragged_ffn(wg, wu, wd, xs: jax.Array, group_sizes: jax.Array,
                rot=None) -> jax.Array:
    """xs [M,D] sorted by expert; group_sizes [E] must sum to M."""
    g = jax.lax.ragged_dot(xs, jnp.swapaxes(wg, 1, 2), group_sizes)
    u = jax.lax.ragged_dot(xs, jnp.swapaxes(wu, 1, 2), group_sizes)
    h = jax.nn.silu(g) * u
    if rot is not None and rot.get("r4") is not None:
        h = rot["r4"](h)      # online Hadamard before expert down-proj (R4)
    return jax.lax.ragged_dot(h, jnp.swapaxes(wd, 1, 2), group_sizes)


def moe_ragged_local(cfg: ModelConfig, p: dict, x: jax.Array, rot=None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Single-device sort + ragged_dot MoE. x [T,D]."""
    T, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    w, idx, aux = _route(cfg, p["router"], p.get("router_bias"), x)
    flat_e = idx.reshape(-1)                  # [T*K]
    order = jnp.argsort(flat_e)
    xs = jnp.repeat(x, K, axis=0)[order]
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    ys = _ragged_ffn(dense_weight(p["w_gate"], x.dtype),
                     dense_weight(p["w_up"], x.dtype),
                     dense_weight(p["w_down"], x.dtype),
                     xs, group_sizes, rot=rot)
    y = jnp.zeros_like(xs).at[order].set(ys).reshape(T, K, D)
    y = (y * w[..., None].astype(x.dtype)).sum(1)
    return y, aux


def moe_ragged_ep(cfg: ModelConfig, p: dict, x: jax.Array, mesh,
                  ep_axis="model", dp_axes=("data",), rot=None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel sort+ragged MoE: shard_map + explicit all_to_all.

    x [T,D] sharded over dp_axes.  Inside shard_map each (data, model) device
    takes its 1/n_ep sequence slice of the data block (sequence-split EP),
    buckets assignments by destination expert shard with fixed capacity,
    all_to_all's buckets along the EP axis, runs ragged_dot over its local
    expert slice, all_to_all's results back, combines, and all_gathers the
    sequence slices so the output is again replicated over 'model'.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E, K = cfg.n_experts, cfg.moe_top_k
    ep_axes = (ep_axis,) if isinstance(ep_axis, str) else tuple(ep_axis)
    n_ep = int(np.prod([mesh.shape[a] for a in ep_axes]))
    e_local = E // n_ep
    T = x.shape[0]
    n_dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    t_rep = T // n_dp                       # tokens per data block
    n_rep = int(np.prod([mesh.shape[a] for a in ep_axes if a not in dp_axes])) or 1
    use_psum_path = (t_rep % n_rep != 0) or (t_rep < 2 * n_rep) or \
        (t_rep // n_rep < 8)
    dp_spec = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    ep_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    rb = p.get("router_bias")

    def _ep_index():
        idx = jax.lax.axis_index(ep_axes[0])
        for a in ep_axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def psum_fn(x_l, router, router_bias, wg, wu, wd):
        # tiny token counts (decode): every EP shard processes all tokens
        # against its local experts; combine with psum.
        tl, D = x_l.shape
        m = _ep_index()
        w, idx, aux = _route(cfg, router, router_bias, x_l)
        flat_e = idx.reshape(-1)
        local_e = flat_e - m * e_local
        valid = (local_e >= 0) & (local_e < e_local)
        local_e = jnp.clip(local_e, 0, e_local - 1)
        order = jnp.argsort(jnp.where(valid, local_e, e_local - 1))
        xs = jnp.repeat(x_l, K, axis=0)[order]
        group_sizes = jnp.bincount(
            jnp.where(valid, local_e, e_local - 1), length=e_local).astype(jnp.int32)
        ys = _ragged_ffn(wg.astype(x_l.dtype), wu.astype(x_l.dtype),
                         wd.astype(x_l.dtype), xs, group_sizes, rot=rot)
        ys = jnp.where(valid[order][:, None], ys, 0.0)
        y = jnp.zeros_like(xs).at[order].set(ys).reshape(tl, K, D)
        y = (y * w[..., None].astype(x_l.dtype)).sum(1)
        y = jax.lax.psum(y, ep_axes)
        return y, aux[None]

    def a2a_fn(x_l, router, router_bias, wg, wu, wd):
        D = x_l.shape[-1]
        m = _ep_index()
        # sequence-split only over axes the tokens are replicated across
        rep_axes = tuple(a for a in ep_axes if a not in dp_axes)
        n_rep = int(np.prod([mesh.shape[a] for a in rep_axes])) or 1
        ridx = jax.lax.axis_index(rep_axes[0]) if rep_axes else 0
        for a in rep_axes[1:]:
            ridx = ridx * mesh.shape[a] + jax.lax.axis_index(a)
        tl = x_l.shape[0] // n_rep
        x_me = jax.lax.dynamic_slice_in_dim(x_l, ridx * tl, tl, 0)  # my slice
        w, idx, aux = _route(cfg, router, router_bias, x_me)       # [tl,K]
        flat_e = idx.reshape(-1)                                   # [tl*K]
        dest = flat_e // e_local
        cap = max(8, int(cfg.capacity_factor * tl * K / n_ep))
        onehot = jax.nn.one_hot(dest, n_ep, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        keep = slot < cap
        src_rows = jnp.repeat(jnp.arange(tl), K)
        didx = dest
        sidx = jnp.where(keep, slot, cap)                          # cap -> dropped
        send_x = jnp.zeros((n_ep, cap, D), x_l.dtype)
        send_x = send_x.at[didx, sidx].set(x_me[src_rows], mode="drop")
        send_e = jnp.full((n_ep, cap), E, jnp.int32)
        send_e = send_e.at[didx, sidx].set(flat_e, mode="drop")
        recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0)         # [n_ep,cap,D]
        recv_e = jax.lax.all_to_all(send_e, ep_axes, 0, 0)
        rx = recv_x.reshape(n_ep * cap, D)
        re = recv_e.reshape(n_ep * cap)
        valid = re < E
        local_e = jnp.where(valid, re - m * e_local, e_local - 1)
        order = jnp.argsort(local_e)
        xs = rx[order]
        group_sizes = jnp.bincount(local_e, length=e_local).astype(jnp.int32)
        ys = _ragged_ffn(wg.astype(x_l.dtype), wu.astype(x_l.dtype),
                         wd.astype(x_l.dtype), xs, group_sizes, rot=rot)
        ys = jnp.where(valid[order][:, None], ys, 0.0)
        y_sorted_back = jnp.zeros_like(ys).at[order].set(ys)
        y_back = jax.lax.all_to_all(y_sorted_back.reshape(n_ep, cap, D),
                                    ep_axes, 0, 0)
        gathered = jnp.where(keep[:, None],
                             y_back[didx, jnp.minimum(sidx, cap - 1)], 0.0)
        y_tok = jnp.zeros((tl, K, D), x_l.dtype)
        karr = jnp.tile(jnp.arange(K), tl)
        y_tok = y_tok.at[src_rows, karr].add(gathered)
        y_me = (y_tok * w[..., None].astype(x_l.dtype)).sum(1)     # [tl,D]
        if rep_axes:
            y_me = jax.lax.all_gather(y_me, rep_axes, tiled=True)
        return y_me, aux[None]

    fn = shard_map(
        psum_fn if use_psum_path else a2a_fn, mesh=mesh,
        in_specs=(P(dp_spec, None), P(None, None),
                  (P(None) if rb is not None else None),
                  P(ep_spec, None, None), P(ep_spec, None, None),
                  P(ep_spec, None, None)),
        out_specs=(P(dp_spec, None), P(dp_spec)),
        check_rep=False)
    # shard_map in_specs are per-array: densify packed expert stacks first
    y, aux = fn(x, p["router"], rb, dense_weight(p["w_gate"], x.dtype),
                dense_weight(p["w_up"], x.dtype),
                dense_weight(p["w_down"], x.dtype))
    return y, jnp.mean(aux)


def moe_tp_local(cfg: ModelConfig, p: dict, x: jax.Array, rot=None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Serve-TP MoE: one shard's slice of an expert-sharded stack.

    Runs *inside* the paged engine's shard_map (never builds its own): the
    expert stacks arrive E-sharded along their leading axis while the router
    is replicated, so every shard routes all tokens over the full expert set
    identically to the single-device engine, masks the assignments that land
    outside its local expert range, ragged_dots the local slice, and the
    combine psum produces the full MoE output on every shard.
    """
    T, D = x.shape
    K = cfg.moe_top_k
    wg = dense_weight(p["w_gate"], x.dtype)
    wu = dense_weight(p["w_up"], x.dtype)
    wd = dense_weight(p["w_down"], x.dtype)
    e_local = wg.shape[0]
    m = tp_shard_index()
    w, idx, aux = _route(cfg, p["router"], p.get("router_bias"), x)
    flat_e = idx.reshape(-1)                              # [T*K] global ids
    local_e = flat_e - m * e_local
    valid = (local_e >= 0) & (local_e < e_local)
    local_clamped = jnp.where(valid, local_e, e_local - 1)
    order = jnp.argsort(local_clamped)
    xs = jnp.repeat(x, K, axis=0)[order]
    group_sizes = jnp.bincount(local_clamped, length=e_local).astype(jnp.int32)
    ys = _ragged_ffn(wg, wu, wd, xs, group_sizes, rot=rot)
    ys = jnp.where(valid[order][:, None], ys, 0.0)
    y = jnp.zeros_like(xs).at[order].set(ys).reshape(T, K, D)
    y = (y * w[..., None].astype(x.dtype)).sum(1)
    return tp_psum_moe(y), aux


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array, shd=NO_SHARD,
                mesh=None, rot=None) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (y [B,S,D], aux_loss). Adds shared experts if configured."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if tp_moe_sharded():
        # inside the serve-TP shard_map with E-sharded expert stacks — the
        # mesh arg must NOT route to moe_ragged_ep (no nested shard_map)
        y, aux = moe_tp_local(cfg, p, xt, rot=rot)
    elif cfg.moe_impl == "ragged":
        if mesh is not None and "model" in mesh.shape and mesh.shape["model"] > 1:
            dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            ep_axis = ("data", "model") if cfg.ep_axes == "all" else "model"
            y, aux = moe_ragged_ep(cfg, p, xt, mesh, ep_axis=ep_axis,
                                   dp_axes=dp_axes, rot=rot)
        else:
            y, aux = moe_ragged_local(cfg, p, xt, rot=rot)
    else:
        y, aux = moe_einsum(cfg, p, xt, shd=shd, rot=rot)
    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp_forward(cfg, p["shared"], x, shd=shd, rot=rot)
    return y, aux
