"""Attention: GQA (+bias, softcap, local windows), MLA (DeepSeek), decode paths.

Prefill/train uses a flash-style chunked attention (lax.scan over KV blocks with
an online-softmax accumulator) so the [S,S] score matrix never materializes.
Decode attends a single query against the KV cache; a context-parallel variant
(cache sharded over sequence, partial-softmax + psum combine) lives in
``repro.dist.cp_attention`` and is routed via the sharding context.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (NO_SHARD, apply_rope, dense_init, linear,
                                 norm_params, rmsnorm, softcap, tp_psum_attn,
                                 tp_row_linear)


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def gqa_params(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (hq * hd, d), d, dt),
        "wk": dense_init(ks[1], (hkv * hd, d), d, dt),
        "wv": dense_init(ks[2], (hkv * hd, d), d, dt),
        "wo": dense_init(ks[3], (d, hq * hd), hq * hd, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.o_bias:
        p["bo"] = jnp.zeros((d,), dt)
    return p


def mla_params(cfg: ModelConfig, key) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], (qlr, d), d, dt),
        "q_norm": {"scale": jnp.ones((qlr,), dt)},
        "wq_b": dense_init(ks[1], (h * (nope + rope), qlr), qlr, dt),
        "wkv_a": dense_init(ks[2], (kvlr + rope, d), d, dt),
        "kv_norm": {"scale": jnp.ones((kvlr,), dt)},
        "wkv_b": dense_init(ks[3], (h * (nope + vd), kvlr), kvlr, dt),
        "wo": dense_init(ks[4], (d, h * vd), h * vd, dt),
    }


def attn_params(cfg: ModelConfig, key) -> dict:
    return mla_params(cfg, key) if cfg.attn_type == "mla" else gqa_params(cfg, key)


# --------------------------------------------------------------------------- #
# Flash-style chunked attention core
# --------------------------------------------------------------------------- #
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      causal: bool = True, window=0,
                      logit_cap: float = 0.0, chunk: int = 512,
                      scale: Optional[float] = None) -> jax.Array:
    """q [B,Sq,Hq,hd]; k,v [B,Sk,Hkv,hd_k/hd_v]; GQA by head repetition.

    Online-softmax scan over KV chunks of size ``chunk``.  ``window`` may be a
    traced int32 scalar (per-layer local/global patterns scanned as xs);
    window <= 0 means global.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    hdv = v.shape[-1]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    big = jnp.iinfo(jnp.int32).max
    win = jnp.asarray(window, jnp.int32)
    win_eff = jnp.where(win > 0, win, big)

    nchunk = -(-Sk // chunk)
    pad = nchunk * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)

    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, Hkv, G, hd)
    kc = k.astype(jnp.float32).reshape(B, nchunk, chunk, Hkv, hd)
    vc = v.astype(jnp.float32).reshape(B, nchunk, chunk, Hkv, hdv)
    kpc = k_pos.reshape(nchunk, chunk)

    def body(carry, xs):
        m, l, o = carry                       # [B,Sq,Hkv,G], same, [B,Sq,Hkv,G,hdv]
        kb, vb, kp = xs                       # [B,chunk,Hkv,hd], ..., [chunk]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb)      # [B,Sq,Hkv,G,chunk]
        if logit_cap:
            s = softcap(s, logit_cap)
        mask = kp[None, :] < big                          # padding
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        mask &= (q_pos[:, None] - kp[None, :]) < win_eff  # local window (<=0: off)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd", p, vb)
        return (m_new, l_new, o_new), None

    init = (jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32),
            jnp.zeros((B, Sq, Hkv, G), jnp.float32),
            jnp.zeros((B, Sq, Hkv, G, hdv), jnp.float32))
    (m, l, o), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpc))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, Sq, Hq, hdv).astype(q.dtype)


# --------------------------------------------------------------------------- #
# GQA forward (train / prefill)
# --------------------------------------------------------------------------- #
def gqa_project(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array, rot=None):
    """Project + rope. Returns q [B,S,Hq,hd], k,v [B,S,Hkv,hd]."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if rot is not None and rot.get("r3") is not None:
        # online Hadamard on q/k (R3): (qH)(kH)^T == qk^T; smooths KV for quant
        q = rot["r3"](q)
        k = rot["r3"](k)
    if rot is not None and rot.get("kv_quant") is not None:
        # paper's KV-4bit: quantize at cache-write; QDQ == integer cache
        k = rot["kv_quant"](k)
        v = rot["kv_quant"](v)
    return q, k, v


def gqa_attention(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                  causal: bool = True, window=0, shd=NO_SHARD,
                  kv_override: Optional[jax.Array] = None,
                  rot=None, return_kv: bool = False):
    """Full-sequence GQA attention.

    kv_override: raw encoder hidden states [B,S_enc,D] (cross-attention) —
    K/V are projected from them with this layer's wk/wv, no RoPE, non-causal.
    return_kv: also return (k, v) for cache construction (prefill).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    if kv_override is not None:
        q = linear(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, hd)
        Se = kv_override.shape[1]
        k = linear(kv_override, p["wk"], p.get("bk")).reshape(B, Se, cfg.n_kv_heads, hd)
        v = linear(kv_override, p["wv"], p.get("bv")).reshape(B, Se, cfg.n_kv_heads, hd)
        k_pos = jnp.arange(Se, dtype=jnp.int32)
        causal = False
    else:
        q, k, v = gqa_project(cfg, p, x, positions, rot=rot)
        k_pos = positions
    if cfg.attn_shard == "seq" and kv_override is None:
        q = shd(q, "act_bshd_seq")       # queries sharded over S on 'model'
        k = shd(k, "act_bshd_rep")       # K/V replicated over 'model'
        v = shd(v, "act_bshd_rep")
    else:
        q = shd(q, "act_bshd_heads")     # heads on 'model'
        k = shd(k, "act_bskd_heads")
        v = shd(v, "act_bskd_heads")
    chunk = min(512, k.shape[1])
    o = chunked_attention(q, k, v, positions, k_pos, causal=causal,
                          window=window, logit_cap=cfg.attn_softcap, chunk=chunk)
    o = o.reshape(B, S, -1)
    out = linear(o, p["wo"], p.get("bo"))
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------- #
# MLA forward (train / prefill)
# --------------------------------------------------------------------------- #
def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                  shd=NO_SHARD, rot=None, return_kv: bool = False):
    B, S, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvlr = cfg.kv_lora_rank

    cq = rmsnorm(linear(x, p["wq_a"]), p["q_norm"]["scale"], cfg.norm_eps)
    q = linear(cq, p["wq_b"]).reshape(B, S, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = linear(x, p["wkv_a"])
    c_kv, k_rope = ckv[..., :kvlr], ckv[..., kvlr:]
    c_kv = rmsnorm(c_kv, p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # [B,S,1,r]
    if rot is not None and rot.get("kv_quant") is not None:
        # paper's KV-4bit on the MLA *latent*: quantize c_kv + rope key at
        # cache-write; QDQ == the integer latent pages the paged runtime holds
        c_kv = rot["kv_quant"](c_kv)
        k_rope = rot["kv_quant"](k_rope)

    kv = linear(c_kv, p["wkv_b"]).reshape(B, S, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, rope_d))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)

    q_full = shd(q_full, "act_bshd_heads")
    k = shd(k, "act_bshd_heads")
    v = shd(v, "act_bshd_heads")
    o = chunked_attention(q_full, k, v, positions, positions, causal=True,
                          chunk=min(512, S),
                          scale=1.0 / math.sqrt(nope + rope_d))
    out = linear(o.reshape(B, S, -1), p["wo"])
    if return_kv:
        # latent cache (absorbed-decode form): c_kv (normed) + rope key
        return out, (c_kv, k_rope[:, :, 0, :])
    return out


def attention(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
              causal: bool = True, window=0, shd=NO_SHARD,
              kv_override=None, rot=None, return_kv: bool = False):
    if cfg.attn_type == "mla":
        return mla_attention(cfg, p, x, positions, shd=shd, rot=rot,
                             return_kv=return_kv)
    return gqa_attention(cfg, p, x, positions, causal=causal, window=window,
                         shd=shd, kv_override=kv_override, rot=rot,
                         return_kv=return_kv)


# --------------------------------------------------------------------------- #
# Decode (single step, KV cache)
# --------------------------------------------------------------------------- #
def decode_attn_scores(q, k_cache, v_cache, k_pos, cur_pos, window: int = 0,
                       logit_cap: float = 0.0, scale: Optional[float] = None):
    """q [B,Hq,hd]; k/v_cache [B,S,Hkv,hd]; returns o [B,Hq,hdv] (plain path)."""
    B, Hq, hd = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    if logit_cap:
        s = softcap(s, logit_cap)
    big = jnp.iinfo(jnp.int32).max
    win = jnp.asarray(window, jnp.int32)
    win_eff = jnp.where(win > 0, win, big)
    valid = k_pos[None, :] <= cur_pos                       # [B,S] (cur_pos [B,1])
    valid &= (cur_pos - k_pos[None, :]) < win_eff
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, -1).astype(q.dtype)


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array, window: int = 0, shd=NO_SHARD,
               rot=None, cp_fn=None) -> Tuple[jax.Array, dict]:
    """x [B,1,D]; cache {'k','v': [B,Smax,Hkv,hd]}; pos scalar int32."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = gqa_project(cfg, p, x, positions, rot=rot)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, pos, 0, 0))
    k_pos = jnp.arange(k_cache.shape[1], dtype=jnp.int32)
    cur = jnp.full((B, 1), pos, jnp.int32)
    if cp_fn is not None:   # context-parallel: cache seq-sharded over 'model'
        o = cp_fn(q[:, 0], k_cache, v_cache, k_pos, cur, window, cfg.attn_softcap)
    else:
        o = decode_attn_scores(q[:, 0], k_cache, v_cache, k_pos, cur,
                               window=window, logit_cap=cfg.attn_softcap)
    out = linear(o.reshape(B, 1, -1), p["wo"], p.get("bo"))
    return out, {"k": k_cache, "v": v_cache}


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array, shd=NO_SHARD, rot=None,
               cp_fn=None) -> Tuple[jax.Array, dict]:
    """Absorbed MLA decode: cache holds the latent c_kv + rope key.

    cache: {'ckv': [B,Smax,kvlr], 'krope': [B,Smax,r]}
    """
    B = x.shape[0]
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvlr = cfg.kv_lora_rank
    positions = jnp.full((1,), pos, jnp.int32)

    cq = rmsnorm(linear(x, p["wq_a"]), p["q_norm"]["scale"], cfg.norm_eps)
    q = linear(cq, p["wq_b"]).reshape(B, 1, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, 0]   # [B,h,r]

    ckv_new = linear(x, p["wkv_a"])                                 # [B,1,kvlr+r]
    c_kv = rmsnorm(ckv_new[..., :kvlr], p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(ckv_new[..., None, kvlr:], positions, cfg.rope_theta)[:, 0, 0]
    if rot is not None and rot.get("kv_quant") is not None:
        c_kv = rot["kv_quant"](c_kv)
        k_rope = rot["kv_quant"](k_rope)

    ckv_cache = jax.lax.dynamic_update_slice(
        cache["ckv"], c_kv.astype(cache["ckv"].dtype), (0, pos, 0))
    krope_cache = jax.lax.dynamic_update_slice(
        cache["krope"], k_rope[:, None].astype(cache["krope"].dtype), (0, pos, 0))

    # absorb W_UK into q: q_lat [B,h,kvlr] (densify packed weights — the
    # absorbed form consumes wkv_b as a tensor, not through a GEMM)
    from repro.quant.qlinear import dense_weight
    wkv_b = dense_weight(p["wkv_b"], jnp.float32).reshape(h, nope + vd, kvlr)
    w_uk, w_uv = wkv_b[:, :nope], wkv_b[:, nope:]                   # [h,nope,kvlr],[h,vd,kvlr]
    q_lat = jnp.einsum("bhn,hnk->bhk", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + rope_d)
    s = (jnp.einsum("bhk,bsk->bhs", q_lat, ckv_cache.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      krope_cache.astype(jnp.float32))) * scale
    k_pos = jnp.arange(ckv_cache.shape[1], dtype=jnp.int32)
    s = jnp.where(k_pos[None, None, :] <= pos, s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsk->bhk", pr, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhk,hvk->bhv", o_lat, w_uv.astype(jnp.float32))
    out = linear(o.reshape(B, 1, h * vd).astype(x.dtype), p["wo"])
    return out, {"ckv": ckv_cache, "krope": krope_cache}


# --------------------------------------------------------------------------- #
# Paged decode / chunked prefill (int4 page-pool cache, serve runtime)
# --------------------------------------------------------------------------- #
def _strip_kv_quant(rot):
    """The paged path quantizes K/V for real at page-write time; drop the
    dense-cache QDQ hook so values aren't quantized twice."""
    if rot and rot.get("kv_quant") is not None:
        rot = {k: v for k, v in rot.items() if k != "kv_quant"}
    return rot or None


def _write_kv_pages(pool_l: dict, k: jax.Array, v: jax.Array,
                    pages: jax.Array, offs: jax.Array, kv_bits: int) -> dict:
    """Quantize k,v [N,H,hd] to QuantKV and scatter into pages[N]/offs[N].

    ``kv_bits=16``: the pool holds raw fp16 pages under ``k``/``v`` (the
    compat layout the demoted lockstep engine serves through) — no codes.
    """
    from repro.quant.kv_cache import quantize_kv
    if kv_bits >= 16:
        return {
            "k": pool_l["k"].at[pages, offs].set(k.astype(pool_l["k"].dtype)),
            "v": pool_l["v"].at[pages, offs].set(v.astype(pool_l["v"].dtype)),
        }
    qk = quantize_kv(k, kv_bits)
    qv = quantize_kv(v, kv_bits)
    return {
        "kq": pool_l["kq"].at[pages, offs].set(qk.q),
        "ks": pool_l["ks"].at[pages, offs].set(qk.scale[..., 0]),
        "kz": pool_l["kz"].at[pages, offs].set(qk.zero[..., 0]),
        "vq": pool_l["vq"].at[pages, offs].set(qv.q),
        "vs": pool_l["vs"].at[pages, offs].set(qv.scale[..., 0]),
        "vz": pool_l["vz"].at[pages, offs].set(qv.zero[..., 0]),
    }


def _write_latent_pages(pool_l: dict, c_kv: jax.Array, k_rope: jax.Array,
                        pages: jax.Array, offs: jax.Array,
                        kv_bits: int) -> dict:
    """Quantize MLA latent rows c_kv [N,kvlr] + k_rope [N,r] (per-token
    scale/zero, the QuantKV convention) and scatter into pages[N]/offs[N]."""
    from repro.quant.kv_cache import quantize_kv
    if kv_bits >= 16:
        return {
            "ckv": pool_l["ckv"].at[pages, offs].set(
                c_kv.astype(pool_l["ckv"].dtype)),
            "krope": pool_l["krope"].at[pages, offs].set(
                k_rope.astype(pool_l["krope"].dtype)),
        }
    qc = quantize_kv(c_kv, kv_bits)
    qr = quantize_kv(k_rope, kv_bits)
    return {
        "cq": pool_l["cq"].at[pages, offs].set(qc.q),
        "cs": pool_l["cs"].at[pages, offs].set(qc.scale[..., 0]),
        "cz": pool_l["cz"].at[pages, offs].set(qc.zero[..., 0]),
        "rq": pool_l["rq"].at[pages, offs].set(qr.q),
        "rs": pool_l["rs"].at[pages, offs].set(qr.scale[..., 0]),
        "rz": pool_l["rz"].at[pages, offs].set(qr.zero[..., 0]),
    }


def paged_gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, pool_l: dict,
                     block_tables: jax.Array, positions: jax.Array,
                     lengths: jax.Array, window=0, shd=NO_SHARD, rot=None,
                     kv_bits: int = 4) -> Tuple[jax.Array, dict]:
    """One decode token per slot against the paged int4 KV cache.

    x [B,1,D]; pool_l {kq,ks,kz,vq,vs,vz} [P,T,H,...] (one layer's slice);
    block_tables [B,Pmax]; positions [B] per-slot write position (sequences
    advance independently — no lockstep pos); lengths [B] valid tokens after
    the write (0 for an idle slot, whose write lands on the null page).
    """
    from repro.kernels.paged_attn.ops import paged_attention
    B = x.shape[0]
    T = next(iter(pool_l.values())).shape[1]
    q, k, v = gqa_project(cfg, p, x, positions[:, None],
                          rot=_strip_kv_quant(rot))
    pages = jnp.take_along_axis(block_tables, (positions // T)[:, None],
                                axis=1)[:, 0]
    new_pool = _write_kv_pages(pool_l, k[:, 0], v[:, 0], pages, positions % T,
                               kv_bits)
    o = paged_attention(q[:, 0], new_pool, block_tables, lengths,
                        bits=kv_bits, window=window,
                        logit_cap=cfg.attn_softcap)
    # TP: heads are sharded, wo is row-sharded — psum the partial output
    # projection, then add the (replicated) bias exactly once
    out = tp_psum_attn(tp_row_linear(o.reshape(B, 1, -1), p["wo"]))
    if p.get("bo") is not None:
        out = out + p["bo"].astype(out.dtype)
    return out, new_pool


def paged_gqa_prefill_chunk(cfg: ModelConfig, p: dict, x: jax.Array,
                            pool_l: dict, block_table: jax.Array,
                            start, window=0, shd=NO_SHARD, rot=None,
                            kv_bits: int = 4,
                            n_pages: Optional[int] = None) -> Tuple[jax.Array, dict]:
    """One prompt chunk of a single sequence: write K/V into its pages, then
    attend over the pages (prior chunks + causal self) — queries past the
    prompt tail write garbage that decode overwrites before it is ever read.

    x [1,C,D]; block_table [1,Pmax]; start: scalar int32 chunk offset.
    n_pages: static count of logical pages covering [0, start+C) — only that
    prefix is gathered/dequantized, so prefill cost tracks progress instead of
    re-densifying the whole reserved table every chunk.
    """
    from repro.kernels.paged_attn.ref import gather_pages
    B, C, _ = x.shape
    hd = cfg.resolved_head_dim
    T = next(iter(pool_l.values())).shape[1]
    positions = start + jnp.arange(C, dtype=jnp.int32)
    q, k, v = gqa_project(cfg, p, x, positions, rot=_strip_kv_quant(rot))
    # chunk overhang past the table (chunk > reserved coverage) must land on
    # the null page — a plain gather would *clamp* to the seq's last real page
    # and let padded-query garbage overwrite prompt KV
    logical = positions // T
    Pmax = block_table.shape[1]
    pages = jnp.where(logical < Pmax,
                      block_table[0, jnp.minimum(logical, Pmax - 1)], 0)
    new_pool = _write_kv_pages(pool_l, k[0], v[0], pages, positions % T,
                               kv_bits)
    gather_table = block_table if n_pages is None else block_table[:, :n_pages]
    kd, vd = gather_pages(new_pool, gather_table, bits=kv_bits, head_dim=hd)
    k_pos = jnp.arange(kd.shape[1], dtype=jnp.int32)
    o = chunked_attention(q, kd, vd, positions, k_pos, causal=True,
                          window=window, logit_cap=cfg.attn_softcap,
                          chunk=min(512, kd.shape[1]))
    out = tp_psum_attn(tp_row_linear(o.reshape(B, C, -1), p["wo"]))
    if p.get("bo") is not None:
        out = out + p["bo"].astype(out.dtype)
    return out, new_pool


def _mla_absorbed_q(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array):
    """Project queries in the absorbed-decode form.  x [B,S,D] ->
    q_lat [B,S,h,kvlr] (W_UK absorbed), q_rope [B,S,h,r], w_uv [h,vd,kvlr]."""
    from repro.quant.qlinear import dense_weight
    B, S, _ = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvlr = cfg.kv_lora_rank
    cq = rmsnorm(linear(x, p["wq_a"]), p["q_norm"]["scale"], cfg.norm_eps)
    q = linear(cq, p["wq_b"]).reshape(B, S, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    wkv_b = dense_weight(p["wkv_b"], jnp.float32).reshape(h, nope + vd, kvlr)
    w_uk, w_uv = wkv_b[:, :nope], wkv_b[:, nope:]
    q_lat = jnp.einsum("bshn,hnk->bshk", q_nope.astype(jnp.float32), w_uk)
    return q_lat, q_rope.astype(jnp.float32), w_uv


def _mla_latent_kv(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array):
    """New latent rows for the cache: c_kv [B,S,kvlr], k_rope [B,S,r]."""
    kvlr = cfg.kv_lora_rank
    ckv = linear(x, p["wkv_a"])
    c_kv = rmsnorm(ckv[..., :kvlr], p["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = apply_rope(ckv[..., None, kvlr:], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def paged_mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, pool_l: dict,
                     block_tables: jax.Array, positions: jax.Array,
                     lengths: jax.Array, window=0, shd=NO_SHARD, rot=None,
                     kv_bits: int = 4) -> Tuple[jax.Array, dict]:
    """Absorbed MLA decode over quantized latent pages: one token per slot.

    x [B,1,D]; pool_l {cq,cs,cz,rq,rs,rz} [P,T,...] (one layer's latent
    slice); positions/lengths [B] as in ``paged_gqa_decode``.  The page rows
    ARE the values (o_lat = p . c_kv); absorbed ``wkv_b`` is consumed as a
    tensor exactly like the dense ``mla_decode``.
    """
    from repro.kernels.paged_attn.ops import paged_mla_attention
    B = x.shape[0]
    h, vd = cfg.n_heads, cfg.v_head_dim
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    T = next(iter(pool_l.values())).shape[1]
    pos2 = positions[:, None]
    q_lat, q_rope, w_uv = _mla_absorbed_q(cfg, p, x, pos2)
    c_kv, k_rope = _mla_latent_kv(cfg, p, x, pos2)
    pages = jnp.take_along_axis(block_tables, (positions // T)[:, None],
                                axis=1)[:, 0]
    new_pool = _write_latent_pages(pool_l, c_kv[:, 0], k_rope[:, 0], pages,
                                   positions % T, kv_bits)
    o_lat = paged_mla_attention(q_lat[:, 0], q_rope[:, 0], new_pool,
                                block_tables, lengths, bits=kv_bits,
                                scale=scale)
    o = jnp.einsum("bhk,hvk->bhv", o_lat.astype(jnp.float32), w_uv)
    # h is the *local* head count under TP (latent pages replicate; only the
    # absorbed per-head projections shard) — psum the row-sharded wo output
    out = tp_psum_attn(tp_row_linear(o.reshape(B, 1, h * vd)
                                  .astype(x.dtype), p["wo"]))
    return out, new_pool


def paged_mla_prefill_chunk(cfg: ModelConfig, p: dict, x: jax.Array,
                            pool_l: dict, block_table: jax.Array,
                            start, window=0, shd=NO_SHARD, rot=None,
                            kv_bits: int = 4,
                            n_pages: Optional[int] = None) -> Tuple[jax.Array, dict]:
    """One prompt chunk against the latent pages (absorbed form throughout):
    write quantized c_kv + rope-key rows, then flash-attend the written page
    prefix with Hkv=1 and n_heads query groups (k = [c_kv | k_rope], v = c_kv).
    """
    from repro.kernels.paged_attn.ref import gather_latent_pages
    B, C, _ = x.shape
    h, vd = cfg.n_heads, cfg.v_head_dim
    kvlr, rope_d = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + rope_d)
    T = next(iter(pool_l.values())).shape[1]
    positions = start + jnp.arange(C, dtype=jnp.int32)
    q_lat, q_rope, w_uv = _mla_absorbed_q(cfg, p, x, positions)
    c_kv, k_rope = _mla_latent_kv(cfg, p, x, positions)
    # chunk overhang past the reserved table lands on the null page (see
    # paged_gqa_prefill_chunk)
    logical = positions // T
    Pmax = block_table.shape[1]
    pages = jnp.where(logical < Pmax,
                      block_table[0, jnp.minimum(logical, Pmax - 1)], 0)
    new_pool = _write_latent_pages(pool_l, c_kv[0], k_rope[0], pages,
                                   positions % T, kv_bits)
    gather_table = block_table if n_pages is None else block_table[:, :n_pages]
    ckv_d, kr_d = gather_latent_pages(new_pool, gather_table, bits=kv_bits,
                                      kv_lora_rank=kvlr, rope_dim=rope_d)
    qfull = jnp.concatenate([q_lat, q_rope], -1)          # [1,C,h,kvlr+r]
    k = jnp.concatenate([ckv_d, kr_d], -1)[:, :, None, :]  # [1,S,1,kvlr+r]
    v = ckv_d[:, :, None, :]                               # [1,S,1,kvlr]
    k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    o_lat = chunked_attention(qfull, k, v, positions, k_pos, causal=True,
                              chunk=min(512, k.shape[1]), scale=scale)
    o = jnp.einsum("bshk,hvk->bshv", o_lat.astype(jnp.float32), w_uv)
    out = tp_psum_attn(tp_row_linear(o.reshape(B, C, h * vd)
                                  .astype(x.dtype), p["wo"]))
    return out, new_pool


def attn_decode(cfg: ModelConfig, p: dict, x, cache, pos, window=0,
                shd=NO_SHARD, rot=None, cp_fn=None):
    if cfg.attn_type == "mla":
        return mla_decode(cfg, p, x, cache, pos, shd=shd, rot=rot,
                          cp_fn=cp_fn)
    return gqa_decode(cfg, p, x, cache, pos, window=window, shd=shd,
                      rot=rot, cp_fn=cp_fn)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               n_layers: Optional[int] = None) -> dict:
    """Stacked per-layer KV cache (leading layer dim for scan)."""
    L = cfg.n_layers if n_layers is None else n_layers
    if cfg.attn_type == "mla":
        return {
            "ckv": jnp.zeros((L, batch, max_seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((L, batch, max_seq, cfg.qk_rope_head_dim), dtype),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
    }
