"""Block assembly: scan-over-layers transformer stacks for every family.

Layer stacks are homogeneous pytrees with a leading layer dim consumed by
``lax.scan`` (keeps HLO compact — essential for the 512-device dry-run).
Heterogeneous patterns are expressed structurally:
  * gemma2 local/global      — per-layer scalar flag array scanned as xs
  * deepseek dense-then-moe  — two scans (dense prefix, MoE rest)
  * zamba2 hybrid            — nested scan: groups of N mamba layers, the
                               *shared* attention block applied between groups
  * whisper enc-dec          — separate encoder and decoder scans
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import ssm as ssm_mod
from repro.models.common import NO_SHARD, apply_norm, norm_params


# --------------------------------------------------------------------------- #
# Per-layer parameter factories
# --------------------------------------------------------------------------- #
def dense_block_params(cfg: ModelConfig, key, use_moe: bool = False,
                       cross_attn: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": norm_params(cfg, cfg.d_model),
        "attn": attn_mod.attn_params(cfg, ks[0]),
        "ln2": norm_params(cfg, cfg.d_model),
    }
    if use_moe:
        p["moe"] = ffn_mod.moe_params(cfg, ks[1])
    else:
        p["mlp"] = ffn_mod.mlp_params(cfg, ks[1])
    if cross_attn:
        p["ln_x"] = norm_params(cfg, cfg.d_model)
        p["xattn"] = attn_mod.attn_params(cfg, ks[2])
    if cfg.sandwich_norm:
        p["post_ln1"] = norm_params(cfg, cfg.d_model)
        p["post_ln2"] = norm_params(cfg, cfg.d_model)
    return p


def mamba_block_params(cfg: ModelConfig, key) -> dict:
    return {"ln": norm_params(cfg, cfg.d_model),
            "mixer": ssm_mod.ssm_params(cfg, key)}


def stacked(fn, keys):
    return jax.vmap(fn)(keys)


# --------------------------------------------------------------------------- #
# Block forwards
# --------------------------------------------------------------------------- #
def dense_block(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
                window: jax.Array | int = 0, shd=NO_SHARD, mesh=None, rot=None,
                encoder_out: Optional[jax.Array] = None,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    h = apply_norm(cfg, p["ln1"], x)
    h = attn_mod.attention(cfg, p["attn"], h, positions, causal=causal,
                           window=window, shd=shd, rot=rot)
    if cfg.sandwich_norm:
        h = apply_norm(cfg, p["post_ln1"], h)
    x = x + h
    if encoder_out is not None:
        h = apply_norm(cfg, p["ln_x"], x)
        h = attn_mod.attention(cfg, p["xattn"], h, positions, shd=shd,
                               kv_override=encoder_out)
        x = x + h
    h = apply_norm(cfg, p["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        h, aux = ffn_mod.moe_forward(cfg, p["moe"], h, shd=shd, mesh=mesh, rot=rot)
    else:
        h = ffn_mod.mlp_forward(cfg, p["mlp"], h, shd=shd, rot=rot)
    if cfg.sandwich_norm:
        h = apply_norm(cfg, p["post_ln2"], h)
    x = shd(x + h, "act_bsd")
    return x, aux


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array, shd=NO_SHARD
                ) -> jax.Array:
    h = apply_norm(cfg, p["ln"], x)
    return shd(x + ssm_mod.mamba2_forward(cfg, p["mixer"], h, shd=shd), "act_bsd")


# --------------------------------------------------------------------------- #
# Stacks (full-sequence forward: train / prefill-without-cache)
# --------------------------------------------------------------------------- #
def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def dense_stack(cfg: ModelConfig, layers: dict, x, positions, windows,
                shd=NO_SHARD, mesh=None, rot=None, encoder_out=None,
                causal=True):
    """layers: stacked params; windows: per-layer int32 array (0 = global)."""
    def body(carry, xs):
        x, aux = carry
        lp, win = xs
        x, a = dense_block(cfg, lp, x, positions, window=win, shd=shd,
                           mesh=mesh, rot=rot, encoder_out=encoder_out,
                           causal=causal)
        return (x, aux + a), None
    (x, aux), _ = jax.lax.scan(_maybe_remat(cfg, body), (x, 0.0),
                               (layers, windows))
    return x, aux


def mamba_stack(cfg: ModelConfig, layers: dict, x, shd=NO_SHARD):
    def body(x, lp):
        return mamba_block(cfg, lp, x, shd=shd), None
    x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, layers)
    return x


def hybrid_stack(cfg: ModelConfig, params: dict, x, positions,
                 shd=NO_SHARD, mesh=None, rot=None):
    """Zamba2: groups of ``shared_attn_every`` mamba layers, then the shared
    attention block; remainder layers at the end."""
    shared = params["shared"]

    def group_body(x, glp):
        x = mamba_stack(cfg, glp, x, shd=shd)
        x, _ = dense_block(cfg, shared, x, positions, shd=shd, mesh=mesh,
                           rot=rot)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(cfg, group_body), x,
                        params["mamba_groups"])
    if "mamba_rest" in params:
        x = mamba_stack(cfg, params["mamba_rest"], x, shd=shd)
    return x


# --------------------------------------------------------------------------- #
# Layer-kind metadata
# --------------------------------------------------------------------------- #
def layer_windows(cfg: ModelConfig, n_layers: int) -> jnp.ndarray:
    """Per-layer attention window (0 = global full attention)."""
    if not cfg.layer_pattern:
        return jnp.zeros((n_layers,), jnp.int32)
    pat = [cfg.local_window if c == "L" else 0
           for i, c in enumerate((cfg.layer_pattern
                                  * (n_layers // len(cfg.layer_pattern) + 1))
                                 [:n_layers])]
    return jnp.asarray(pat, jnp.int32)
