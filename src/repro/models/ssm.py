"""Mamba-2 (SSD — state-space duality) in JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6): quadratic
attention-like computation *within* chunks (MXU-friendly matmuls) + a linear
recurrence *across* chunk states (lax.scan).  Decode is the O(1) recurrent step
h <- exp(dt*A) h + dt*B x; y = C.h + D x.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import NO_SHARD, dense_init, linear, rmsnorm


def ssm_params(cfg: ModelConfig, key) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    nh, gn = cfg.ssm_nheads, cfg.ssm_groups * cfg.ssm_state
    conv_dim = cfg.conv_dim
    d_in_proj = 2 * di + 2 * gn + nh
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2 default)
    u = jax.random.uniform(ks[2], (nh,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))   # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d_in_proj, d), d, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (1.0 / cfg.ssm_conv)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)).astype(dt),
        "D": jnp.ones((nh,), dt),
        "dt_bias": dt_bias.astype(dt),
        "norm": {"scale": jnp.ones((di,), dt)},
        "out_proj": dense_init(jax.random.fold_in(key, 7), (d, di), di, dt),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, gn, nh = cfg.d_inner, cfg.ssm_groups * cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(pad: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width K, over a pre-padded window.

    ``pad`` [B,K-1+S,C] is the chunk prefixed with its left-context (carry
    rows from the previous chunk, or zeros at start-of-sequence); returns the
    S in-chunk outputs.
    """
    K = w.shape[0]
    S = pad.shape[1] - (K - 1)
    out = jnp.zeros(pad.shape[:1] + (S,) + pad.shape[2:], jnp.float32)
    for i in range(K):   # K is tiny (4); unrolled taps
        out = out + pad[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(pad.dtype)


def ssd_chunked(x: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                dt: jax.Array, chunk: int,
                h0: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x [B,S,H,P]; a=dt*A [B,S,H] (<=0); Bm/Cm [B,S,H,N]; dt [B,S,H].

    Returns y [B,S,H,P] and final state [B,H,P,N].
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(B_, nc, chunk, H, P).astype(f32)
    ac = a.reshape(B_, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B_, nc, chunk, H, N).astype(f32)
    Cc = Cm.reshape(B_, nc, chunk, H, N).astype(f32)
    dtc = dt.reshape(B_, nc, chunk, H).astype(f32)

    if h0 is None:
        h0 = jnp.zeros((B_, H, P, N), f32)

    def body(h, xs):
        xq, aq, Bq, Cq, dq = xs                       # [B,chunk,...]
        cum = jnp.cumsum(aq, axis=1)                  # inclusive [B,Q,H]
        # intra-chunk: L[t,s] = exp(cum[t]-cum[s]) for t>=s
        diff = cum[:, :, None, :] - cum[:, None, :, :]          # [B,t,s,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bthn,bshn->btsh", Cq, Bq) * L      # [B,t,s,H]
        y = jnp.einsum("btsh,bsh,bshp->bthp", scores, dq, xq)
        # carry-in contribution: decay exp(cum[t])
        y = y + jnp.einsum("bthn,bhpn->bthp", Cq * jnp.exp(cum)[..., None], h)
        # new chunk state
        decay_end = jnp.exp(cum[:, -1:, :] - cum)               # [B,Q,H]
        s_c = jnp.einsum("bsh,bshn,bshp->bhpn", decay_end * dq, Bq, xq)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + s_c
        return h_new, y

    h_final, yc = jax.lax.scan(
        body, h0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0), jnp.moveaxis(Bc, 1, 0),
         jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(dtc, 1, 0)))
    y = jnp.moveaxis(yc, 0, 1).reshape(B_, nc * chunk, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_reference(x, a, Bm, Cm, dt, h0=None):
    """Naive O(S) recurrent oracle for tests. Shapes as ssd_chunked."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B_, H, P, N), jnp.float32) if h0 is None else h0
    ys = []
    for t in range(S):
        h = (jnp.exp(a[:, t]).astype(jnp.float32)[:, :, None, None] * h
             + jnp.einsum("bh,bhn,bhp->bhpn", dt[:, t].astype(jnp.float32),
                          Bm[:, t].astype(jnp.float32), x[:, t].astype(jnp.float32)))
        ys.append(jnp.einsum("bhn,bhpn->bhp", Cm[:, t].astype(jnp.float32), h))
    return jnp.stack(ys, 1).astype(x.dtype), h


def _mamba2_apply(cfg: ModelConfig, p: dict, u: jax.Array,
                  state: dict | None, shd=NO_SHARD, valid_len=None):
    """Shared mixer core: full-sequence or one chunk of a longer sequence.

    ``state`` {'conv' [B,K-1,C], 'h' [B,H,P,N]} carries the previous chunk's
    raw conv tail + SSD state (None = start of sequence).  Returns
    (out [B,S,D], new_state) — chaining chunks equals the one-shot forward up
    to f32 reduction order.

    ``valid_len`` (traced scalar): number of real tokens in this chunk; the
    positions past it are padding and MUST NOT advance the recurrent state
    (dt is zeroed there, and the conv carry is sliced at the real tail) —
    unlike KV caches, recurrent state has no decode-overwrites-garbage
    escape hatch.
    """
    B, S, _ = u.shape
    H, P, N, G = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_inner
    K = cfg.ssm_conv
    zxbcdt = linear(u, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_carry = jnp.zeros((B, K - 1, xbc.shape[-1]), xbc.dtype) \
        if state is None else state["conv"]
    conv_pad = jnp.concatenate([conv_carry.astype(xbc.dtype), xbc], axis=1)
    xbc = _causal_conv(conv_pad, p["conv_w"], p["conv_b"])
    x = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di:di + G * N].reshape(B, S, G, N)
    Cm = xbc[..., di + G * N:].reshape(B, S, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=2)
    Cm = jnp.repeat(Cm, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))        # [B,S,H]
    if valid_len is not None:
        # padded positions: dt=0 -> decay exp(0)=1, input contribution 0, so
        # the SSD state carries through them untouched
        valid = jnp.arange(S, dtype=jnp.int32) < valid_len
        dt = jnp.where(valid[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # [H]
    a = dt * A
    x = shd(x, "ssm_bshp")
    h0 = None if state is None else state["h"].astype(jnp.float32)
    y, h_final = ssd_chunked(x, a, Bm, Cm, dt, cfg.ssm_chunk, h0=h0)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"]["scale"],
                cfg.norm_eps)
    out = linear(y.astype(u.dtype), p["out_proj"])
    if valid_len is None:
        new_conv = conv_pad[:, -(K - 1):]
    else:
        # conv_pad rows: [K-1 carry | S chunk]; real tokens end at index
        # K-1+valid_len, so the K-1 rows before it start at valid_len
        new_conv = jax.lax.dynamic_slice_in_dim(conv_pad, valid_len, K - 1,
                                                axis=1)
    new_state = {"conv": new_conv.astype(jnp.float32), "h": h_final}
    return out, new_state


def mamba2_forward(cfg: ModelConfig, p: dict, u: jax.Array,
                   shd=NO_SHARD, return_state: bool = False):
    """Full-sequence Mamba-2 mixer. u [B,S,D] -> [B,S,D] (+ state if asked)."""
    out, state = _mamba2_apply(cfg, p, u, None, shd=shd)
    return (out, state) if return_state else out


def mamba2_prefill_chunk(cfg: ModelConfig, p: dict, u: jax.Array,
                         state: dict, shd=NO_SHARD, valid_len=None):
    """One prompt chunk with carried state; see ``_mamba2_apply``."""
    return _mamba2_apply(cfg, p, u, state, shd=shd, valid_len=valid_len)


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
        "h": jnp.zeros((n_layers, batch, cfg.ssm_nheads, cfg.ssm_head_dim,
                        cfg.ssm_state), dtype),
    }


def mamba2_decode(cfg: ModelConfig, p: dict, u: jax.Array, cache: dict,
                  shd=NO_SHARD) -> Tuple[jax.Array, dict]:
    """Single-token recurrent step. u [B,1,D]; cache {'conv','h'} per layer."""
    B = u.shape[0]
    H, P, N, G = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_inner
    zxbcdt = linear(u[:, 0], p["in_proj"])                           # [B, dproj]
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    # conv state: window of last K-1 inputs
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)   # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc_c = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(u.dtype)
    new_conv = window[:, 1:]

    x = xbc_c[..., :di].reshape(B, H, P)
    Bm = xbc_c[..., di:di + G * N].reshape(B, G, N)
    Cm = xbc_c[..., di + G * N:].reshape(B, G, N)
    rep = H // G
    Bm = jnp.repeat(Bm, rep, axis=1)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))          # [B,H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h = cache["h"].astype(jnp.float32)
    h = (jnp.exp(dt * A)[:, :, None, None] * h
         + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm.astype(jnp.float32),
                      x.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"]["scale"],
                cfg.norm_eps)
    out = linear(y[:, None].astype(u.dtype), p["out_proj"])
    return out, {"conv": new_conv, "h": h.astype(cache["h"].dtype)}
