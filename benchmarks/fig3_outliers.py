"""Paper Figs. 3 & 6: outlier counts + quant error per transformation.

Both on synthetic Laplace-with-outliers (paper App. G statistics) and on real
captured activations of the trained tiny LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CFG, captured_acts, synthetic_acts
from repro.core import (calibrate_rotation, outlier_count, quant_error,
                        random_hadamard)


def run(smoke: bool = False) -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    synth = synthetic_acts(n=64, N=512) if smoke else synthetic_acts()
    for src, x in [("synthetic", synth),
                   ("captured", captured_acts(smoke)["r1"])]:
        n = x.shape[-1]
        had = random_hadamard(n, key)
        dart = calibrate_rotation(x, n, key, objective="whip",
                                  steps=20 if smoke else 80, lr=0.2)
        for name, r in [("identity", jnp.eye(n)), ("hadamard", had),
                        ("dartquant", dart)]:
            o = x @ r
            rows.append((f"fig3,{src},{name},outliers",
                         float(outlier_count(o)), "per_token"))
            rows.append((f"fig3,{src},{name},quant_err",
                         float(quant_error(o)), "mse"))
    return rows
