"""Shared benchmark fixtures: a trained tiny LM + captured activations."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import capture_activations
from repro.data.pipeline import batches, calibration_batch
from repro.models import model as M
from repro.models.common import cross_entropy
from repro.quant import act_quant as act_quant_ctx, fake_quant_act
from repro.train.trainer import Trainer

CFG = get_config("llama2-7b").reduced().replace(
    n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16,
    vocab_size=256)


@functools.lru_cache(maxsize=2)
def trained_model(smoke: bool = False):
    """Trained tiny LM; ``smoke`` trains a shorter (but still converging
    enough for ordering checks) run so CI can touch every table."""
    tr = Trainer(CFG, batch_size=8, seq_len=64, lr=5e-3)
    tr.train(25 if smoke else 100, verbose=False)
    return tr.params


@functools.lru_cache(maxsize=2)
def captured_acts(smoke: bool = False):
    params = trained_model(smoke)
    calib = jnp.asarray(calibration_batch(CFG, 4 if smoke else 8,
                                          32 if smoke else 64))
    return capture_activations(CFG, params, calib, sample_frac=0.5,
                               key=jax.random.PRNGKey(0))


def eval_ppl(cfg, params, a_bits=16, rot=None, seed=99, n_batches=4):
    """Perplexity averaged over several held-out batches (variance control)."""
    it = batches(cfg, 8, 64, seed=seed)
    evs = [next(it) for _ in range(n_batches)]
    toks = jnp.stack([jnp.asarray(b["tokens"]) for b in evs])
    labels = jnp.stack([jnp.asarray(b["labels"]) for b in evs])

    def run(t, l):
        logits, _ = M.forward(cfg, params, t, rot=rot)
        return cross_entropy(logits, l)

    jrun = jax.jit(run)
    if a_bits < 16:
        with act_quant_ctx(lambda x: fake_quant_act(x, a_bits)):
            ces = [float(jrun(toks[i], labels[i])) for i in range(n_batches)]
    else:
        ces = [float(jrun(toks[i], labels[i])) for i in range(n_batches)]
    return float(jnp.exp(jnp.mean(jnp.asarray(ces))))


def synthetic_acts(n=256, N=4096, n_outliers=8, scale=12.0, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.laplace(k1, (N, n)) * 0.5
    oc = jax.random.choice(k2, n, (n_outliers,), replace=False)
    x = x.at[:, oc].multiply(scale)
    return x / jnp.std(x)
