"""Roofline report: reads the dry-run artifacts and emits the per-cell table
(EXPERIMENTS.md §Roofline source of truth)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    """Analytic MODEL_FLOPS per device: 6*N*D (train) / 2*N_active*D (decode)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    n_active = cfg.n_active_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / n_devices
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / n_devices
    tokens = cell.global_batch          # one new token per sequence
    return 2.0 * n_active * tokens / n_devices


def run(smoke: bool = False) -> list:
    # ``smoke`` is accepted for harness uniformity (every module emits a
    # BENCH json in CI); this report is artifact-driven, not compute-driven,
    # so there is nothing to scale down.
    rows = []
    if not DRYRUN.exists():
        return [("roofline,missing", 0, "artifacts_absent")]
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        tag = f"{rec['arch']},{rec['shape']},{'pod2' if rec['multi_pod'] else 'pod1'}"
        r = rec["roofline"]
        mf = model_flops(rec["arch"], rec["shape"], rec["n_devices"])
        hlo_f = rec["hlo_stats"]["dot_flops"]
        rows.append((f"roofline,{tag},t_compute", r["t_compute"], "s"))
        rows.append((f"roofline,{tag},t_memory", r["t_memory"], "s"))
        rows.append((f"roofline,{tag},t_collective", r["t_collective"], "s"))
        rows.append((f"roofline,{tag},bottleneck", 0.0, r["bottleneck"]))
        rows.append((f"roofline,{tag},useful_flop_ratio",
                     mf / max(hlo_f, 1.0), "model/hlo"))
        rows.append((f"roofline,{tag},mem_gib",
                     rec["memory"]["peak_estimate_bytes"] / 2**30, "GiB"))
    return rows
