"""Paper Table 4 + Fig. 7b: QR-Orth vs Cayley — per-step cost + convergence.

Three measurements:
  * wall-clock per iteration (same Whip objective, same data),
  * XLA-counted FLOPs of one update step (cost_analysis on the jitted step),
  * steps to reach the Cayley-100-step loss (the paper's 41x claim shape).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import synthetic_acts
from repro.core import random_hadamard, whip
from repro.core.qr_orth import (calibrate_scan, cayley_sgd_step, qr_rotation,
                                sgd_update)


def _time_loop(fn, steps=20):
    fn()                                   # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        fn()
    return (time.perf_counter() - t0) / steps


def run(smoke: bool = False) -> list:
    rows = []
    # n large enough that the orthogonality machinery (O(n^3)) is visible
    # against the Whip grad (O(N n^2)) — the paper's regime (n = d_model)
    n = 128 if smoke else 1024
    loop_steps = 5 if smoke else 20
    conv_steps = 20 if smoke else 60
    x = synthetic_acts(n=n, N=256 if smoke else 1024)
    key = jax.random.PRNGKey(0)
    z0 = random_hadamard(n, key)

    # --- per-step wall clock -------------------------------------------------
    grad_q = jax.jit(jax.value_and_grad(lambda z: whip(x @ qr_rotation(z))))
    grad_c = jax.jit(jax.value_and_grad(lambda r: whip(x @ r)))
    step_c = jax.jit(cayley_sgd_step)

    z = z0
    m = jnp.zeros_like(z)

    def qr_step():
        nonlocal z, m
        l, g = grad_q(z)
        z, m = sgd_update(z, m, g, 0.05)
        jax.block_until_ready(z)

    r = z0
    mc = jnp.zeros_like(r)

    def cayley_step():
        nonlocal r, mc
        l, g = grad_c(r)
        r, mc = step_c(r, mc, g, 0.05)
        jax.block_until_ready(r)

    t_qr = _time_loop(qr_step, loop_steps)
    t_cy = _time_loop(cayley_step, loop_steps)
    rows.append(("table4,qr_step", t_qr * 1e6, "us"))
    rows.append(("table4,cayley_step", t_cy * 1e6, "us"))
    rows.append(("table4,speedup_per_step", t_cy / t_qr, "x"))

    # isolate the orthogonality machinery itself (QR decomp vs Cayley update)
    zq = z0
    fq_only = jax.jit(qr_rotation)
    fc_only = jax.jit(lambda r, m, g: cayley_sgd_step(r, m, g, 0.05))
    g0 = jnp.ones_like(z0) * 1e-3
    t_qr_o = _time_loop(lambda: jax.block_until_ready(fq_only(zq)),
                        loop_steps)
    t_cy_o = _time_loop(lambda: jax.block_until_ready(
        fc_only(zq, jnp.zeros_like(zq), g0)[0]), loop_steps)
    rows.append(("table4,qr_orth_only", t_qr_o * 1e6, "us"))
    rows.append(("table4,cayley_orth_only", t_cy_o * 1e6, "us"))
    rows.append(("table4,orth_speedup", t_cy_o / t_qr_o, "x"))
    rows.append(("table4,analytic_qr_flops", (4 / 3) * n ** 3, "flops"))
    rows.append(("table4,analytic_cayley_extra_flops", 6 * n ** 3, "flops"))

    # --- XLA FLOPs of the orthogonality machinery alone ----------------------
    def _flops(compiled):
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):       # older jax: list per device
            ca = ca[0] if ca else {}
        return float(ca.get("flops", -1))

    fq = jax.jit(qr_rotation).lower(jnp.zeros((n, n))).compile()
    fc = jax.jit(lambda r, m, g: cayley_sgd_step(r, m, g, 0.05)).lower(
        jnp.zeros((n, n)), jnp.zeros((n, n)), jnp.zeros((n, n))).compile()
    flops_q = _flops(fq)
    flops_c = _flops(fc)
    rows.append(("table4,qr_orth_flops", flops_q, "flops"))
    rows.append(("table4,cayley_flops", flops_c, "flops"))

    # --- convergence: steps for QR to match Cayley@60 (smoke: @20) -----------
    # loss histories come straight off the scanned engine (no callbacks)
    cy_losses = calibrate_scan(x, z0, whip, method="cayley", steps=conv_steps,
                               lr=0.1).loss_history.tolist()
    qr_losses = calibrate_scan(x, z0, whip, method="qr", steps=conv_steps,
                               lr=0.1).loss_history.tolist()
    target = cy_losses[-1]
    steps_needed = next((i + 1 for i, l in enumerate(qr_losses)
                         if l <= target), conv_steps)
    rows.append(("table4,cayley60_loss", target, "whip"))
    rows.append(("table4,qr_steps_to_match", steps_needed, "steps"))
    rows.append(("table4,convergence_speedup", conv_steps / steps_needed,
                 "x"))
    return rows
