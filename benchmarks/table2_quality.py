"""Paper Table 2: quant quality across bit settings x methods.

PPL of the trained tiny LM under {16-16-16, 4-8-16, 4-4-16, 4-4-4} for
{RTN, QuaRot(Hadamard), DartQuant}.  Absolute Llama PPLs are not reproducible
without weights; the deliverable is the paper's ORDERING at each setting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CFG, eval_ppl, trained_model
from repro.core import calibrate_model, fuse_rotations, random_pack
from repro.core.rotations import online_hadamard
from repro.data.pipeline import calibration_batch
from repro.quant import make_kv_quant, quantize_params


def run(smoke: bool = False) -> list:
    params = trained_model(smoke)
    key = jax.random.PRNGKey(0)
    calib = jnp.asarray(calibration_batch(CFG, 4 if smoke else 8,
                                          32 if smoke else 64))
    pack = calibrate_model(CFG, params, calib, key=key,
                           steps=16 if smoke else 80, lr_r1=0.05, lr_r2=0.05)
    dcfg, dparams = fuse_rotations(CFG, params, pack)
    hcfg, hparams = fuse_rotations(CFG, params, random_pack(CFG, key))
    n_batches = 2 if smoke else 4
    rows = []
    rows.append(("table2,fp,16-16-16",
                 eval_ppl(CFG, params, n_batches=n_batches)))
    settings = [((4, 8, 16), "4-8-16"), ((4, 4, 4), "4-4-4")] if smoke else \
        [((4, 8, 16), "4-8-16"), ((4, 4, 16), "4-4-16"), ((4, 4, 4), "4-4-4")]
    for (w, a, kv), tag in settings:
        kvq = make_kv_quant(kv)
        rot_h = {"r4": online_hadamard, "kv_quant": kvq}
        rows.append((f"table2,rtn,{tag}",
                     eval_ppl(CFG, quantize_params(CFG, params), a_bits=a,
                              rot={"kv_quant": kvq}, n_batches=n_batches)))
        rows.append((f"table2,quarot,{tag}",
                     eval_ppl(hcfg, quantize_params(hcfg, hparams), a_bits=a,
                              rot=rot_h, n_batches=n_batches)))
        rows.append((f"table2,dartquant,{tag}",
                     eval_ppl(dcfg, quantize_params(dcfg, dparams), a_bits=a,
                              rot=rot_h, n_batches=n_batches)))
    return [(name, ppl, "ppl") for name, ppl in rows]
