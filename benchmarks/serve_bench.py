"""Serve-runtime benchmark: the paged runtime across decoder families.

Measures on reduced configs:
  * decode throughput (tok/s) and chunked-prefill latency of the paged engine
    on a dense GQA decoder (llama2),
  * MLA latent-cache serving (deepseek-v3): decode tok/s plus latent-cache
    bytes — paged-actual (quantized c_kv + rope-key pages) vs the fp16 dense
    latent cache at the same capacity,
  * hybrid serving (zamba2): decode tok/s through the SSM state pool + shared
    attention pages under the same token-level scheduler,
  * KV memory: actual paged-pool bytes vs the dense-cache estimate at the
    same capacity,
  * weight memory: packed-QTensor projection bytes vs the fp16 QDQ footprint
    they replace, artifact (hash-verified, mmap) load time, and decode
    throughput of the packed-weight engine cold-booted from that artifact,
  * shared-prompt traffic (the production shape: one system prompt, many
    divergent suffixes) through the prefix cache, against a no-sharing
    baseline with the index disabled.

Scheduler counters reported by the shared-prompt section (each also appears
in every paged engine's ``generate`` stats):

  prefix_hit_rate   prompt tokens served from cached pages / prompt tokens
                    submitted (prefix_hit_tokens / prompt_tokens)
  cow_copies        shared pages copied-on-write at admission (the last,
                    partially filled prefix page a sequence must append into)
  prefill_tokens    tokens actually prefilled — cache hits excluded, so
                    shared traffic prefills fewer tokens than the baseline
  preemptions       sequences preempted (pages recycled, request requeued)
                    when on-demand page growth found the pool exhausted
  prefix_evictions  cached pages reclaimed LRU-style to satisfy allocation

The legacy lockstep engine is no longer benchmarked: for decoder-only
families ``ServeEngine`` is a thin wrapper over the paged engine (the
lockstep loop survives only for enc-dec).  The single-traffic sections pin
``prefix_cache=False`` so their warm re-runs measure real prefill work, not
a 100% cache hit on the identical prompts.

Warm numbers re-run ``generate`` with the jit cache hot — the serving regime:
the paged engine's programs are keyed by engine geometry (slots, pages, page
size, chunk), so repeat deployments recompile nothing.  Warm timings follow
the warmup+repeat discipline (``repro.obs.bench``): the compile run is the
warmup, then the serve repeats and the rows carry median + IQR so the
regression gate can tell noise from drift.  The final section drives the
open-loop Poisson load generator (``repro.serve.loadgen``) through real
scheduler admission and reports goodput against TTFT/p99-ITL SLOs, with a
token-for-token parity assertion against batch ``generate``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.obs.bench import measure, record_from_samples
from repro.quant import kv_bytes
from repro.quant.kv_cache import latent_bytes
from repro.serve import LoadSpec, PagedServeEngine, Request, SLO
from repro.serve.loadgen import build_workload, run_workload


def _requests(cfg, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, prompt_len),
                    max_new=max_new) for _ in range(n)]


def _serve(eng, cfg, n, prompt_len, max_new, require_done=True):
    reqs, stats = eng.generate(_requests(cfg, n, prompt_len, max_new))
    assert all(r.done for r in reqs) or not require_done
    return stats


def run(smoke: bool = False) -> list:
    n_req, slots, plen, max_new = (4, 2, 8, 8) if smoke else (16, 4, 32, 24)
    page = 8 if smoke else 16
    cfg = get_config("llama2-7b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    max_seq = plen + max_new
    tag = "smoke" if smoke else f"r{n_req}xs{slots}"
    rows = []

    repeats = 2 if smoke else 3

    paged = PagedServeEngine(cfg, params, batch_slots=slots, max_seq=max_seq,
                             page_size=page, a_bits=8, kv_bits=4,
                             prefix_cache=False)
    t0 = time.perf_counter()
    stats = _serve(paged, cfg, n_req, plen, max_new)
    rows.append((f"serve,paged_total_cold,{tag}",
                 time.perf_counter() - t0, "s"))
    warm = [_serve(paged, cfg, n_req, plen, max_new)
            for _ in range(repeats)]                        # jit cache hot
    stats = warm[-1]
    rows.append(record_from_samples(
        f"serve,paged_decode,{tag}",
        [s["decode_tok_per_s"] for s in warm], "tok_per_s", warmup=1))
    rows.append(record_from_samples(
        f"serve,paged_prefill,{tag}",
        [s["prefill_tok_per_s"] for s in warm], "tok_per_s", warmup=1))
    # latency distributions from the engine's registry histograms (warm +
    # cold runs both contribute; the p99 carries the compile)
    for q in (50, 95, 99):
        rows.append((f"serve,ttft_p{q},{tag}", stats[f"ttft_p{q}"], "s"))
        rows.append((f"serve,itl_p{q},{tag}", stats[f"itl_p{q}"], "s"))
    rows.append((f"serve,kv_bytes_paged,{tag}", stats["kv_cache_bytes"], "B"))
    rows.append((f"serve,kv_bytes_dense_est,{tag}",
                 stats["kv_cache_bytes_dense"], "B"))
    # dense fp16 cache at the same capacity: what paging + int4 replaces
    rows.append((f"serve,kv_bytes_dense_fp16,{tag}",
                 kv_bytes(slots, max_seq, cfg.n_layers, cfg.n_kv_heads,
                          cfg.resolved_head_dim, 16), "B"))

    # ---- MLA latent pages (deepseek-v3): decode tok/s + latent bytes ----- #
    mla_cfg = get_config("deepseek-v3-671b").reduced()
    mla_params = M.init_params(mla_cfg, jax.random.PRNGKey(1))
    mla = PagedServeEngine(mla_cfg, mla_params, batch_slots=slots,
                           max_seq=max_seq, page_size=page, kv_bits=4,
                           prefix_cache=False)
    _serve(mla, mla_cfg, n_req, plen, max_new)              # compile
    mla_warm = [_serve(mla, mla_cfg, n_req, plen, max_new)
                for _ in range(repeats)]
    stats = mla_warm[-1]
    rows.append(record_from_samples(
        f"serve,mla_paged_decode,{tag}",
        [s["decode_tok_per_s"] for s in mla_warm], "tok_per_s", warmup=1))
    # deepseek's reduced config is a mixed stack: latent pages live in the
    # attn_dense + attn_moe sub-states
    rows.append((f"serve,mla_latent_bytes_paged,{tag}",
                 sum(v for k, v in stats["cache_bytes_by_kind"].items()
                     if k.startswith("attn")), "B"))
    rows.append((f"serve,mla_latent_bytes_fp16,{tag}",
                 latent_bytes(slots * max_seq, mla_cfg.n_layers,
                              mla_cfg.kv_lora_rank,
                              mla_cfg.qk_rope_head_dim, 16), "B"))

    # ---- hybrid (zamba2): SSM state pool + shared-attn pages ------------- #
    hy_cfg = get_config("zamba2-7b").reduced()
    hy_params = M.init_params(hy_cfg, jax.random.PRNGKey(2))
    hy = PagedServeEngine(hy_cfg, hy_params, batch_slots=slots,
                          max_seq=max_seq, page_size=page, kv_bits=4)
    _serve(hy, hy_cfg, n_req, plen, max_new)                # compile
    hy_warm = [_serve(hy, hy_cfg, n_req, plen, max_new)
               for _ in range(repeats)]
    stats = hy_warm[-1]
    rows.append(record_from_samples(
        f"serve,hybrid_paged_decode,{tag}",
        [s["decode_tok_per_s"] for s in hy_warm], "tok_per_s", warmup=1))
    rows.append((f"serve,hybrid_cache_bytes_paged,{tag}",
                 stats["kv_cache_bytes"], "B"))

    # ---- shared-prompt traffic: prefix cache + CoW vs no-sharing --------- #
    # shared prefix deliberately ends mid-page: sharers must CoW the last,
    # partially filled prefix page before appending their suffix into it
    sp_len, suf_len = 3 * page + page // 2, max(2, page // 2)
    sp_max_seq = sp_len + suf_len + max_new

    def _shared_reqs():
        rng = np.random.default_rng(7)
        sys_prompt = rng.integers(0, cfg.vocab_size, sp_len)
        return [Request(prompt=np.concatenate(
                            [sys_prompt,
                             rng.integers(0, cfg.vocab_size, suf_len)]),
                        max_new=max_new) for _ in range(n_req)]

    base_eng = PagedServeEngine(cfg, params, batch_slots=slots,
                                max_seq=sp_max_seq, page_size=page, a_bits=8,
                                kv_bits=4, prefix_cache=False)
    base_reqs, base_stats = base_eng.generate(_shared_reqs())
    shared_eng = PagedServeEngine(cfg, params, batch_slots=slots,
                                  max_seq=sp_max_seq, page_size=page,
                                  a_bits=8, kv_bits=4, prefix_cache=True)
    shared_reqs, shared_stats = shared_eng.generate(_shared_reqs())
    # sharing is an optimization, never a behaviour change
    assert [r.out for r in shared_reqs] == [r.out for r in base_reqs]
    assert shared_stats["prefix_hit_rate"] > 0
    assert shared_stats["cow_copies"] >= 1
    assert shared_stats["prefill_tokens"] < base_stats["prefill_tokens"]
    rows.append((f"serve,prefix_hit_rate,{tag}",
                 shared_stats["prefix_hit_rate"], "ratio"))
    rows.append((f"serve,prefix_cow_copies,{tag}",
                 shared_stats["cow_copies"], "pages"))
    rows.append((f"serve,prefill_tokens_shared,{tag}",
                 shared_stats["prefill_tokens"], "tok"))
    rows.append((f"serve,prefill_tokens_baseline,{tag}",
                 base_stats["prefill_tokens"], "tok"))
    rows.append((f"serve,shared_decode,{tag}",
                 shared_stats["decode_tok_per_s"], "tok_per_s"))
    rows.append((f"serve,baseline_decode,{tag}",
                 base_stats["decode_tok_per_s"], "tok_per_s"))

    # over-committed pool: reserve-at-admission could at best run one of
    # these sequences at a time; on-demand growth runs them concurrently and
    # preempts-with-requeue when pages run dry.  Sized to one full prompt +
    # one CoW page + one growth page (+ the null page): the second slot's
    # first growth is guaranteed to find the pool dry and preempt.
    oc_pages = -(-(sp_len + suf_len) // page) + 3
    oc_eng = PagedServeEngine(cfg, params, batch_slots=2, max_seq=sp_max_seq,
                              page_size=page, num_pages=oc_pages, a_bits=8,
                              kv_bits=4, prefix_cache=True)
    oc_reqs, oc_stats = oc_eng.generate(_shared_reqs())
    assert all(r.done for r in oc_reqs)
    assert [r.out for r in oc_reqs] == [r.out for r in base_reqs]
    rows.append((f"serve,overcommit_preemptions,{tag}",
                 oc_stats["preemptions"], "seqs"))
    rows.append((f"serve,overcommit_evictions,{tag}",
                 oc_stats["prefix_evictions"], "pages"))

    # ---- sharing-density headline: effective tokens per byte of pages ---- #
    # int4 quantized pages + prefix sharing (this runtime) vs the unshared
    # fp16 page cache it replaces (the vLLM-default shape).  "Effective"
    # counts every token each sequence can attend over; "stored" counts the
    # unique token slots actually written — the ratio is the sharing factor,
    # and bytes/token carries the quantization factor.
    eff_tokens = sum(len(r.prompt) + len(r.out) for r in shared_reqs)
    stored_tokens = (shared_stats["prefill_tokens"]
                     + sum(len(r.out) for r in shared_reqs))
    bpt_int4 = shared_eng.pool.nbytes / (shared_eng.pool.num_pages * page)
    bpt_fp16 = kv_bytes(1, 1, cfg.n_layers, cfg.n_kv_heads,
                        cfg.resolved_head_dim, 16)
    dens_int4 = eff_tokens / (stored_tokens * bpt_int4)
    dens_fp16 = 1.0 / bpt_fp16                  # unshared: effective == stored
    rows.append((f"serve,page_density_int4_shared,{tag}", dens_int4,
                 "tok_per_B"))
    rows.append((f"serve,page_density_fp16_unshared,{tag}", dens_fp16,
                 "tok_per_B"))
    rows.append((f"serve,page_density_gain,{tag}", dens_int4 / dens_fp16,
                 "x"))
    rows.append((f"serve,page_bytes_per_token_int4,{tag}", bpt_int4, "B"))

    # quantize-once pipeline: weight memory + artifact cold-boot cost.
    # Rotation choice doesn't matter for bytes — use the Hadamard pack so the
    # bench never pays calibration time.
    import tempfile

    from repro.artifacts import (QuantArtifact, load_artifact, rotation_spec,
                                 save_artifact)
    from repro.core import fuse_rotations, random_pack
    from repro.quant import pack_params, projection_weight_bytes

    pack = random_pack(cfg, jax.random.PRNGKey(1))
    fcfg, fparams = fuse_rotations(cfg, params, pack)
    # snapshot the same serving bits the engines above ran with, so the
    # packed cold-boot row is apples-to-apples
    fcfg = fcfg.replace(quant=fcfg.quant.replace(a_bits=8, kv_bits=4))
    packed = pack_params(fcfg, fparams)
    proj, proj_fp16 = projection_weight_bytes(packed)
    rows.append((f"serve,w_bytes_packed,{tag}", proj, "B"))
    rows.append((f"serve,w_bytes_qdq_fp16,{tag}", proj_fp16, "B"))
    with tempfile.TemporaryDirectory() as td:
        save_artifact(td, QuantArtifact(cfg=fcfg, params=packed,
                                        rotations=rotation_spec(pack)))
        rows.append(measure(f"serve,artifact_load,{tag}",
                            lambda: load_artifact(td), unit="s",
                            repeats=repeats, warmup=1))
        art = load_artifact(td)                  # mmap + hash verification
        cold = PagedServeEngine.from_artifact(art, batch_slots=slots,
                                              max_seq=max_seq, page_size=page,
                                              prefix_cache=False)
        _serve(cold, cfg, n_req, plen, max_new)            # compile
        stats = _serve(cold, cfg, n_req, plen, max_new)    # warm
        rows.append((f"serve,paged_packed_decode,{tag}",
                     stats["decode_tok_per_s"], "tok_per_s"))

    # ---- open-loop load generation: goodput against TTFT/p99-ITL SLOs --- #
    # Requests arrive through real scheduler admission at a Poisson offered
    # rate, with mixed prompt/output lengths and a shared-prefix traffic
    # fraction.  SLOs are sized for a CPU smoke box: the gate watches the
    # goodput *ratio* (strict failures: unfinished requests), while
    # achieved_rps tracks throughput drift with IQR tolerance.
    lg_spec = LoadSpec(n_requests=n_req, rate_rps=50.0 if smoke else 20.0,
                       prompt_len=(max(2, plen // 2), plen),
                       max_new=(2, max_new),
                       shared_prefix_len=page + page // 2, shared_frac=0.5,
                       seed=11)
    slo = SLO(ttft_s=120.0, itl_p99_s=60.0)
    lg_max_seq = lg_spec.shared_prefix_len + plen + max_new
    lg_eng = PagedServeEngine(cfg, params, batch_slots=slots,
                              max_seq=lg_max_seq, page_size=page, a_bits=8,
                              kv_bits=4, prefix_cache=True)
    lg_reqs, lg_stats = run_workload(lg_eng, lg_spec, slo=slo)
    assert all(r.done for r in lg_reqs)
    # open-loop admission is an arrival-order change, never a behaviour
    # change: the same prompts batch-served must decode identical tokens
    ref_eng = PagedServeEngine(cfg, params, batch_slots=slots,
                               max_seq=lg_max_seq, page_size=page, a_bits=8,
                               kv_bits=4, prefix_cache=True)
    ref_reqs, _ = ref_eng.generate(
        [r for _, r in build_workload(lg_spec, cfg.vocab_size)])
    assert [r.out for r in lg_reqs] == [r.out for r in ref_reqs]
    rows.append((f"serve,loadgen_goodput,{tag}", lg_stats["goodput"],
                 "ratio"))
    rows.append((f"serve,loadgen_finished,{tag}", lg_stats["n_finished"],
                 "seqs"))
    rows.append((f"serve,loadgen_achieved,{tag}", lg_stats["achieved_rps"],
                 "req_per_s"))
    rows.append((f"serve,loadgen_ttft_mean,{tag}", lg_stats["ttft_mean_s"],
                 "s"))
    rows.append((f"serve,loadgen_itl_p99_worst,{tag}",
                 lg_stats["itl_p99_worst_s"], "s"))

    # ---- tensor-parallel serve (8 virtual devices, subprocess) ----------- #
    # The bench process pins a single device, so the TP rows come from a
    # child with XLA_FLAGS-forced 8 CPU devices (same launcher discipline as
    # tests/_mesh_compat).  Per-device decode is tolerant (IQR, emulated
    # devices time-share one socket); cache-bytes/device and the analytic
    # psum-bytes/token are strict byte accounting.  The reduced config ships
    # 4 heads — the TP child bumps to 8 uniform heads so the mesh divides.
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    tp_code = f"""
import json
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models import model as M
from repro.serve import PagedServeEngine, Request
cfg = get_config("llama2-7b").reduced().replace(n_heads=8, n_kv_heads=8,
                                                head_dim=8)
params = M.init_params(cfg, jax.random.PRNGKey(0))
eng = PagedServeEngine(cfg, params, mesh=make_serve_mesh(8),
                       batch_slots={slots}, max_seq={max_seq},
                       page_size={page}, kv_bits=4, prefix_cache=False)
def serve():
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, {plen}),
                    max_new={max_new}) for _ in range({n_req})]
    _, stats = eng.generate(reqs)
    return stats
serve()                                     # compile
warm = [serve() for _ in range({repeats})]
out = dict(decode=[s["decode_tok_per_s"] for s in warm],
           tp=warm[-1]["tp_devices"],
           cache_per_dev=warm[-1]["kv_cache_bytes_per_device"],
           psum_per_tok=warm[-1]["psum_bytes_per_token"])
print("TPJSON " + json.dumps(out))
"""
    env = dict(_os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS=_os.environ.get("JAX_PLATFORMS", "cpu"))
    r = _sp.run([_sys.executable, "-c", tp_code], capture_output=True,
                text=True, env=env, timeout=560)
    tp_line = [ln for ln in r.stdout.splitlines()
               if ln.startswith("TPJSON ")]
    assert tp_line, r.stdout + r.stderr
    tp = _json.loads(tp_line[0][len("TPJSON "):])
    assert tp["tp"] == 8
    rows.append(record_from_samples(
        f"serve,tp8_decode_per_device,{tag}",
        [d / tp["tp"] for d in tp["decode"]], "tok_per_s", warmup=0))
    rows.append((f"serve,tp8_cache_bytes_per_device,{tag}",
                 tp["cache_per_dev"], "B"))
    rows.append((f"serve,tp8_psum_bytes_per_token,{tag}",
                 tp["psum_per_tok"], "B"))
    return rows
