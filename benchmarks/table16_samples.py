"""Paper Table 16 + Table 5: robustness to sample size and calibration set."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CFG, eval_ppl, trained_model
from repro.core import calibrate_model, fuse_rotations
from repro.core.rotations import online_hadamard
from repro.data.pipeline import calibration_batch
from repro.quant import quantize_params


def run(smoke: bool = False) -> list:
    params = trained_model(smoke)
    key = jax.random.PRNGKey(0)
    rows = []
    rot = {"r4": online_hadamard}
    steps = 15 if smoke else 60
    seq = 32 if smoke else 64
    n_batches = 2 if smoke else 4
    # sample-size sweep (Tab. 16)
    for n_samples in (2, 8) if smoke else (2, 4, 8, 16):
        calib = jnp.asarray(calibration_batch(CFG, n_samples, seq))
        pack = calibrate_model(CFG, params, calib, key=key, steps=steps,
                               lr_r1=0.05, use_r2=False)
        dcfg, dp = fuse_rotations(CFG, params, pack)
        rows.append((f"table16,samples={n_samples}",
                     eval_ppl(dcfg, quantize_params(dcfg, dp), a_bits=4,
                              rot=rot, n_batches=n_batches), "ppl"))
    # dataset sweep (Tab. 5): calibrate on *different corpora*, evaluate on
    # the training corpus — the paper's cross-dataset robustness check
    for seed in (0, 7) if smoke else (0, 7, 42):
        calib = jnp.asarray(calibration_batch(CFG, 4 if smoke else 8, seq,
                                              corpus_seed=seed))
        pack = calibrate_model(CFG, params, calib, key=key, steps=steps,
                               lr_r1=0.05, use_r2=False)
        dcfg, dp = fuse_rotations(CFG, params, pack)
        rows.append((f"table5,corpus_seed={seed}",
                     eval_ppl(dcfg, quantize_params(dcfg, dp), a_bits=4,
                              rot=rot, n_batches=n_batches), "ppl"))
    return rows
