"""Paper Table 16 + Table 5: robustness to sample size and calibration set."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CFG, eval_ppl, trained_model
from repro.core import calibrate_model, fuse_rotations
from repro.core.rotations import online_hadamard
from repro.data.pipeline import calibration_batch
from repro.quant import quantize_params


def run() -> list:
    params = trained_model()
    key = jax.random.PRNGKey(0)
    rows = []
    rot = {"r4": online_hadamard}
    # sample-size sweep (Tab. 16)
    for n_samples in (2, 4, 8, 16):
        calib = jnp.asarray(calibration_batch(CFG, n_samples, 64))
        pack = calibrate_model(CFG, params, calib, key=key, steps=60,
                               lr_r1=0.05, use_r2=False)
        dcfg, dp = fuse_rotations(CFG, params, pack)
        rows.append((f"table16,samples={n_samples}",
                     eval_ppl(dcfg, quantize_params(dcfg, dp), a_bits=4,
                              rot=rot), "ppl"))
    # dataset sweep (Tab. 5): calibrate on *different corpora*, evaluate on
    # the training corpus — the paper's cross-dataset robustness check
    for seed in (0, 7, 42):
        calib = jnp.asarray(calibration_batch(CFG, 8, 64, corpus_seed=seed))
        pack = calibrate_model(CFG, params, calib, key=key, steps=60,
                               lr_r1=0.05, use_r2=False)
        dcfg, dp = fuse_rotations(CFG, params, pack)
        rows.append((f"table5,corpus_seed={seed}",
                     eval_ppl(dcfg, quantize_params(dcfg, dp), a_bits=4,
                              rot=rot), "ppl"))
    return rows
