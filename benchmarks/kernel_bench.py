"""Kernel microbench: AOT compile-vs-warm split, analytic MFU, watermarks.

For the two serving hot-path Pallas kernels (``quant_matmul`` — packed-int4
dequant matmul over QTensor weights — and ``paged_attention`` — block-table
gather + fused dequant + online softmax over quantized KV pages):

  * **compile vs warm**: the single-shot timings the other benches report
    mix XLA compilation into the first call.  Here the AOT path
    (``jax.jit(f).lower(args).compile()``) prices compilation explicitly,
    then the compiled executable is timed under warmup+repeat discipline —
    two separate rows, so a compile-time regression and an execution-time
    regression gate independently.
  * **analytic utilization**: XLA-counted FLOPs and bytes-accessed from
    ``compiled.cost_analysis()`` divided by (median warm time x device
    peak) give MFU and bandwidth-utilization estimates against the
    ``repro.obs.bench.device_peaks()`` table.  On a CPU smoke box these are
    tiny absolute numbers — the gate watches them as ratios with IQR
    tolerance; on TPU they become the roofline placement of the real
    kernels.  Skipped (not guessed) when the device kind is unknown or XLA
    reports no cost model.
  * **peak-memory watermarks**: ``device.memory_stats()`` where the backend
    exposes it, else the live-buffer ``nbytes`` lower bound — reported in
    MB, an informational (never strictly gated) unit, because the live set
    depends on allocator state.

Interpret-mode caveat: off-TPU the Pallas bodies run through the
interpreter, so absolute times are emulation costs — still regression-
comparable run-over-run on the same backend (the fingerprint gates
cross-backend compares).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attn.ops import paged_attention
from repro.kernels.quant_matmul.ops import quant_matmul
from repro.obs.bench import device_peaks, measure, peak_memory_bytes
from repro.quant.kv_cache import quantize_kv
from repro.quant.qlinear import pack_weight


def _cost(compiled) -> tuple:
    """(flops, bytes_accessed) from XLA's cost model; -1 when unreported."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):           # older jax: list per device
        ca = ca[0] if ca else {}
    return float(ca.get("flops", -1)), float(ca.get("bytes accessed", -1))


def _aot_rows(name, fn, args, tag, repeats) -> list:
    """Compile-vs-warm split + utilization rows for one kernel call."""
    rows = []
    t0 = time.perf_counter()
    compiled = jax.jit(fn).lower(*args).compile()
    rows.append((f"kernel,{name}_compile,{tag}", time.perf_counter() - t0,
                 "s"))
    warm = measure(f"kernel,{name}_warm,{tag}",
                   lambda: jax.block_until_ready(compiled(*args)),
                   unit="s", repeats=repeats, warmup=1)
    rows.append(warm)
    flops, nbytes = _cost(compiled)
    peaks = device_peaks()
    if flops > 0:
        rows.append((f"kernel,{name}_flops,{tag}", flops, "flops"))
        if peaks is not None:
            rows.append((f"kernel,{name}_mfu,{tag}",
                         flops / (warm.value * peaks[0]), "ratio"))
    if nbytes > 0 and peaks is not None:
        rows.append((f"kernel,{name}_bw_util,{tag}",
                     nbytes / (warm.value * peaks[1]), "ratio"))
    return rows


def run(smoke: bool = False) -> list:
    tag = "smoke" if smoke else "full"
    repeats = 3 if smoke else 5
    rows = []
    key = jax.random.PRNGKey(0)

    # ---- quant_matmul: packed-int4 (and int8) dequant matmul ------------- #
    m, K, N = (8, 64, 64) if smoke else (32, 256, 512)
    x = jax.random.normal(key, (m, K))
    for bits in (4, 8):
        qt = pack_weight(jax.random.normal(jax.random.fold_in(key, bits),
                                           (N, K)), bits=bits)
        rows += _aot_rows(f"quant_matmul_w{bits}",
                          lambda xx, q=qt: quant_matmul(xx, q), (x,),
                          f"m{m}xk{K}xn{N},{tag}", repeats)

    # ---- paged_attention: int4 KV pages, GQA decode ---------------------- #
    P, T, H, hd, G = (9, 4, 2, 16, 2) if smoke else (33, 16, 4, 64, 4)
    B, Pmax = (4, 5) if smoke else (8, 17)
    k = jax.random.normal(jax.random.fold_in(key, 1), (P, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (P, T, H, hd))
    qk, qv = quantize_kv(k, 4), quantize_kv(v, 4)
    pool = {"kq": qk.q, "ks": qk.scale[..., 0], "kz": qk.zero[..., 0],
            "vq": qv.q, "vs": qv.scale[..., 0], "vz": qv.zero[..., 0]}
    rng = np.random.default_rng(3)
    bt = jnp.asarray(rng.integers(1, P, (B, Pmax)), jnp.int32)
    lengths = jnp.asarray(
        rng.integers(1, T * Pmax, B), jnp.int32)
    q = jax.random.normal(jax.random.fold_in(key, 4), (B, H * G, hd))
    rows += _aot_rows("paged_attn",
                      lambda qq, bb, ll: paged_attention(qq, pool, bb, ll,
                                                         bits=4),
                      (q, bt, lengths), f"b{B}xh{H * G}xd{hd},{tag}", repeats)

    # ---- paged_attention at the tensor-parallel shard shape -------------- #
    # What ONE device of an 8-way serve mesh runs: the same kernel with the
    # head axes divided (pages shard over heads, group ratio preserved) —
    # prices the per-shard decode step the TP engine issues per layer.
    tp = 8
    Hs = max(1, H * G // tp // G)               # kv heads per shard
    ks, vs = k[:, :, :Hs], v[:, :, :Hs]
    qks, qvs = quantize_kv(ks, 4), quantize_kv(vs, 4)
    pool_s = {"kq": qks.q, "ks": qks.scale[..., 0], "kz": qks.zero[..., 0],
              "vq": qvs.q, "vs": qvs.scale[..., 0], "vz": qvs.zero[..., 0]}
    q_s = q[:, :Hs * G]
    rows += _aot_rows("paged_attn_tp_shard",
                      lambda qq, bb, ll: paged_attention(qq, pool_s, bb, ll,
                                                         bits=4),
                      (q_s, bt, lengths),
                      f"tp{tp},b{B}xh{Hs * G}xd{hd},{tag}", repeats)

    # ---- device peak-memory watermark ------------------------------------ #
    peak, source = peak_memory_bytes()
    rows.append((f"kernel,peak_memory,{source},{tag}", peak / 2**20, "MB"))
    return rows
