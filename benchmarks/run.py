"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,unit`` CSV to stdout (the human view) and — with
``--json-dir`` — writes one ``BENCH_<module>.json`` per module: a
``repro.obs.bench.BenchReport`` carrying every record (warmup/repeat
discipline, median + IQR for repeated timings) plus the environment
fingerprint (jax/jaxlib, backend, device kind/count, cpu count, git sha,
smoke flag).  Those artifacts are the machine-readable perf trajectory:
CI uploads them per run and gates regressions with

  python -m repro.obs.bench compare benchmarks/baselines BENCH_DIR

Usage: ``PYTHONPATH=src python -m benchmarks.run [filter] [--smoke]
[--json-dir DIR]``; ``--smoke`` runs tiny-dimension variants (CI) — every
module supports it.

Modules may yield plain ``(name, value, unit)`` tuples (recorded as
single-shot, ``repeats=1``) or ``BenchRecord`` objects (the warmup+repeat
timing rows).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

from repro.obs.bench import (BenchRecord, BenchReport, env_fingerprint,
                             write_bench_json)

MODULES = [
    "benchmarks.table2_quality",      # Tab. 2: quant quality per bit setting
    "benchmarks.table3_calib_cost",   # Tab. 3: calibration cost scaling
    "benchmarks.table4_optimizer",    # Tab. 4 / Fig. 7b: QR-Orth vs Cayley
    "benchmarks.fig7_convergence",    # Fig. 7a / Tab. 22: objectives
    "benchmarks.fig3_outliers",       # Figs. 3/6: outliers + quant error
    "benchmarks.table16_samples",     # Tabs. 16/5: sample/dataset robustness
    "benchmarks.gptq_table",          # GPTQ vs RTN reconstruction
    "benchmarks.serve_bench",         # serve runtime: paged engine + loadgen
    "benchmarks.kernel_bench",        # Pallas kernels: AOT compile/warm, MFU
    "benchmarks.roofline_report",     # §Roofline: dry-run derived terms
]


def as_record(row) -> BenchRecord:
    """Normalize a module row: 3-tuples become single-shot records."""
    if isinstance(row, BenchRecord):
        return row
    name, value, unit = row
    return BenchRecord(name=name, value=float(value), unit=str(unit))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog="positional [filter] selects modules by substring")
    ap.add_argument("filter", nargs="?", default=None,
                    help="run only modules whose name contains this")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-dimension variants (CI)")
    ap.add_argument("--json-dir", default=None, metavar="DIR",
                    help="write BENCH_<module>.json per module here")
    args = ap.parse_args(argv)

    fingerprint = env_fingerprint(smoke=args.smoke) if args.json_dir else None
    print("name,value,unit")
    ok = True
    for modname in MODULES:
        if args.filter and args.filter not in modname:
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(modname, fromlist=["run"])
            kwargs = {}
            if args.smoke and "smoke" in inspect.signature(
                    mod.run).parameters:
                kwargs["smoke"] = True
            records = [as_record(r) for r in mod.run(**kwargs)]
            for rec in records:
                if isinstance(rec.value, float):
                    print(f"{rec.name},{rec.value:.6g},{rec.unit}",
                          flush=True)
                else:
                    print(f"{rec.name},{rec.value},{rec.unit}", flush=True)
            dt = time.perf_counter() - t0
            print(f"# {modname} done in {dt:.1f}s", flush=True)
            if args.json_dir:
                report = BenchReport(module=modname, fingerprint=fingerprint,
                                     records=records)
                path = write_bench_json(report, args.json_dir)
                print(f"# {modname} -> {path}", flush=True)
        except Exception as e:      # noqa: BLE001 — keep the harness running
            ok = False
            print(f"# {modname} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
