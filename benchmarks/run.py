"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,unit`` CSV.  PYTHONPATH=src python -m benchmarks.run
[filter] [--smoke]; ``--smoke`` runs tiny-dimension variants (CI) for the
modules that support it.
"""
from __future__ import annotations

import inspect
import sys
import time
import traceback

MODULES = [
    "benchmarks.table2_quality",      # Tab. 2: quant quality per bit setting
    "benchmarks.table3_calib_cost",   # Tab. 3: calibration cost scaling
    "benchmarks.table4_optimizer",    # Tab. 4 / Fig. 7b: QR-Orth vs Cayley
    "benchmarks.fig7_convergence",    # Fig. 7a / Tab. 22: objectives
    "benchmarks.fig3_outliers",       # Figs. 3/6: outliers + quant error
    "benchmarks.table16_samples",     # Tabs. 16/5: sample/dataset robustness
    "benchmarks.gptq_table",          # GPTQ vs RTN reconstruction
    "benchmarks.serve_bench",         # serve runtime: paged vs legacy engine
    "benchmarks.roofline_report",     # §Roofline: dry-run derived terms
]


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--smoke"]
    smoke = "--smoke" in sys.argv[1:]
    only = args[0] if args else None
    print("name,value,unit")
    ok = True
    for modname in MODULES:
        if only and only not in modname:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            kwargs = {}
            if smoke and "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            for name, value, unit in mod.run(**kwargs):
                if isinstance(value, float):
                    print(f"{name},{value:.6g},{unit}", flush=True)
                else:
                    print(f"{name},{value},{unit}", flush=True)
            print(f"# {modname} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:      # noqa: BLE001 — keep the harness running
            ok = False
            print(f"# {modname} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
