"""Paper Table 3: rotation-calibration cost (time, memory) vs model size.

Measures wall-clock of a full DartQuant calibration (capture + R1 + R2) at
three widths standing in for 7B/13B/70B hidden sizes (scaled to CPU), plus the
analytic FLOP count per QR-Orth step vs the end-to-end fine-tuning
alternative (which must backprop the whole model per step).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import synthetic_acts
from repro.core import calibrate_rotation


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    for n, tag in [(256, "7b-proxy"), (384, "13b-proxy"), (512, "70b-proxy")]:
        x = synthetic_acts(n=n, N=2048)
        t0 = time.time()
        calibrate_rotation(x, n, key, objective="whip", steps=30, lr=0.1)
        dt = (time.time() - t0) / 30
        rows.append((f"table3,calib_step,{tag}", dt * 1e6, "us_per_step"))
        # per-step FLOPs: whip fwd+bwd (4*N*n^2) + QR ((4/3)n^3) — vs
        # end-to-end fine-tuning which is 6 * n_params * tokens per step.
        qr_flops = 4 * x.shape[0] * n * n + (4 / 3) * n ** 3
        rows.append((f"table3,calib_flops,{tag}", qr_flops, "flops_per_step"))
    return rows
