"""Paper Table 3: rotation-calibration cost (time, memory) vs model size.

Three measurements:
  * wall-clock of a calibration step at widths standing in for 7B/13B/70B
    hidden sizes (scaled to CPU), on the scanned engine,
  * engine-vs-legacy wall-clock on the multi-site R2 workload
    [L=8, N=2048, n=256] (and the realistic head-dim variant n=64): the
    legacy path is the seed implementation — a serial Python loop over sites,
    each call building fresh jit closures (recompile per site) and re-entering
    jit every step, pulling the loss to host per step as its callback
    consumers did.  The scanned+vmapped engine compiles once and runs all
    sites in a single XLA call.  Reported cold (first call, compile included
    for both) and warm (jit cache hit — the production regime: one engine
    executable serves every model with the same site shape),
  * batched-vs-serial rotation agreement, verified in float64 where
    float-noise amplification over the trajectory does not mask algorithmic
    equality (in float32 both paths are the same algorithm, but chaotic loss
    landscapes amplify 1e-7 lowering differences over tens of steps),
  * sharded-vs-single-device: the token-sharded engine (mesh over every
    local device on the 'data' axis; latents replicated, loss/grad psum'd
    per step) on the same R2 workload — cold/warm wall-clock plus rotation
    max-diff against the single-device engine.  On a 1-device box this
    measures pure shard_map overhead; with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` it is a PARITY
    row, not a perf row (8 virtual devices oversubscribe the host cores and
    every shard redundantly runs the replicated QR) — the perf reading needs
    real accelerators, where the matmul term (the one that scales with
    calibration-set size N) is what shards.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import synthetic_acts
from repro.core import calibrate_rotation, random_hadamard, whip
from repro.core.qr_orth import (calibrate_qr_legacy,
                                calibrate_rotations_batched)
from repro.obs.bench import record_from_samples

STEPS = 30
LR = 0.01
WARM_REPEATS = 3   # warm timings: median + IQR over this many runs


def _workload(L, N, n, dtype=jnp.float32):
    xs = jnp.stack([synthetic_acts(n=n, N=N, seed=i) for i in range(L)])
    key = jax.random.PRNGKey(0)
    z0s = jnp.stack([random_hadamard(n, k).astype(dtype)
                     for k in jax.random.split(key, L)])
    return xs.astype(dtype), z0s


def _legacy_serial(xs, z0s):
    """The seed implementation's behavior: per-site fresh-jit host loop with
    per-step loss pulls (the callback protocol every consumer used)."""
    sink = []
    rs = [calibrate_qr_legacy(xs[i], z0s[i], whip, steps=STEPS, lr=LR,
                              callback=lambda k, l, z: sink.append(l))
          for i in range(xs.shape[0])]
    jax.block_until_ready(rs)
    return rs


def _engine(xs, z0s):
    res = calibrate_rotations_batched(xs, z0s, whip, steps=STEPS, lr=LR)
    jax.block_until_ready(res.rotation)
    return res


def _timed(fn, *args):
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def _compare(L, N, n, tag) -> list:
    rows = []
    xs, z0s = _workload(L, N, n)
    # legacy is single-shot by design: its cost IS the per-site recompiles,
    # warm repeats would measure a regime the seed code never reaches
    t_legacy = _timed(_legacy_serial, xs, z0s)
    t_cold = _timed(_engine, xs, z0s)              # compile included
    warm = [_timed(_engine, xs, z0s) for _ in range(WARM_REPEATS)]
    t_warm = sorted(warm)[len(warm) // 2]

    rows.append((f"table3,legacy_loop,{tag}", t_legacy, "s"))
    rows.append((f"table3,engine_cold,{tag}", t_cold, "s"))
    rows.append(record_from_samples(f"table3,engine_warm,{tag}", warm, "s",
                                    warmup=1))
    rows.append((f"table3,speedup_cold,{tag}", t_legacy / t_cold, "x"))
    rows.append((f"table3,speedup_warm,{tag}", t_legacy / t_warm, "x"))
    return rows


def _engine_sharded(xs, z0s, mesh):
    res = calibrate_rotations_batched(xs, z0s, whip, steps=STEPS, lr=LR,
                                      mesh=mesh)
    jax.block_until_ready(res.rotation)
    return res


def _compare_sharded(L, N, n, tag) -> list:
    """Token-sharded engine vs single-device on the same workload."""
    from repro.launch.mesh import make_calib_mesh
    mesh = make_calib_mesh()
    ndev = len(jax.devices())
    xs, z0s = _workload(L, N, n)
    single = _engine(xs, z0s)

    t_cold = _timed(_engine_sharded, xs, z0s, mesh)
    warm = [_timed(_engine_sharded, xs, z0s, mesh)
            for _ in range(WARM_REPEATS)]
    res = _engine_sharded(xs, z0s, mesh)

    d = float(jnp.max(jnp.abs(res.rotation - single.rotation)))
    return [
        (f"table3,sharded_devices,{tag}", ndev, "devices"),
        (f"table3,engine_sharded_cold,{tag}", t_cold, "s"),
        record_from_samples(f"table3,engine_sharded_warm,{tag}", warm, "s",
                            warmup=1),
        (f"table3,sharded_vs_single_maxdiff,{tag}", d, "abs"),
    ]


def _equivalence(L=4, N=512, n=64) -> list:
    """Batched == serial (same engine), checked in f64 (see module doc)."""
    from jax.experimental import enable_x64
    with enable_x64():
        xs, z0s = _workload(L, N, n, dtype=jnp.float64)
        batched = calibrate_rotations_batched(xs, z0s, whip, steps=STEPS,
                                              lr=LR).rotation
        from repro.core.qr_orth import calibrate_scan
        serial = [calibrate_scan(xs[i], z0s[i], whip, steps=STEPS,
                                 lr=LR).rotation for i in range(L)]
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(serial, batched))
    return [("table3,batched_vs_serial_maxdiff", d, "abs")]


def run(smoke: bool = False) -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    widths = [(128, "7b-proxy")] if smoke else [
        (256, "7b-proxy"), (384, "13b-proxy"), (512, "70b-proxy")]
    for n, tag in widths:
        x = synthetic_acts(n=n, N=2048)

        def _calib():
            jax.block_until_ready(
                calibrate_rotation(x, n, key, objective="whip", steps=STEPS,
                                   lr=0.1))

        _calib()                                   # warmup: compile
        samples = [_timed(_calib) / STEPS * 1e6 for _ in range(WARM_REPEATS)]
        rows.append(record_from_samples(f"table3,calib_step,{tag}", samples,
                                        "us_per_step", warmup=1))
        # per-step FLOPs: whip fwd+bwd (4*N*n^2) + QR ((4/3)n^3) — vs
        # end-to-end fine-tuning which is 6 * n_params * tokens per step.
        qr_flops = 4 * x.shape[0] * n * n + (4 / 3) * n ** 3
        rows.append((f"table3,calib_flops,{tag}", qr_flops, "flops_per_step"))

    if smoke:
        rows += _compare(2, 256, 64, "smoke")
        rows += _compare_sharded(2, 256, 64, "smoke")
        return rows

    # multi-site R2 workloads: acceptance shape + realistic head-dim shape
    rows += _compare(8, 2048, 256, "L8xN2048xn256")
    rows += _compare(8, 2048, 64, "L8xN2048xn64")
    rows += _compare_sharded(8, 2048, 256, "L8xN2048xn256")
    rows += _equivalence()
    return rows
