"""GPTQ-vs-RTN reconstruction (supports the paper's §5 'GPTQ for weights'):
per-matrix reconstruction error on captured activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import CFG, captured_acts, trained_model
from repro.quant import gptq_quantize, hessian, recon_error, rtn_quantize


def run(smoke: bool = False) -> list:
    params = trained_model(smoke)
    acts = captured_acts(smoke)
    x = acts["r1"]
    rows = []
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    for name in ("wq", "wo"):
        w = lp["attn"][name] if name in lp["attn"] else None
        if w is None or w.shape[-1] != x.shape[-1]:
            continue
        h = hessian(x)
        wq, _ = gptq_quantize(w, h, bits=4)
        e_g = float(recon_error(w, wq, x))
        e_r = float(recon_error(w, rtn_quantize(w, 4), x))
        rows.append((f"gptq,{name},gptq_err", e_g, "mse"))
        rows.append((f"gptq,{name},rtn_err", e_r, "mse"))
        rows.append((f"gptq,{name},improvement", e_r / max(e_g, 1e-12), "x"))
    return rows
