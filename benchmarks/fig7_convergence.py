"""Paper Fig. 7a + Table 22: quant-error trajectories per objective.

On REAL captured activations of the trained tiny LM (not synthetic): optimize
R with each objective and measure activation quant error along the way.  The
per-step quant-error trace is recorded INSIDE the scanned engine
(``metrics=``), so the whole trajectory costs one compiled call per objective
instead of a host callback round-trip every step.

``run(smoke=True)`` (CI) swaps the trained model for tiny synthetic
activations and shortens the trajectory.
"""
from __future__ import annotations

import jax

from repro.core import quant_error, random_hadamard
from repro.core.qr_orth import calibrate_scan
from repro.core.whip import OBJECTIVES


def run(smoke: bool = False) -> list:
    if smoke:
        from benchmarks.common import synthetic_acts
        x = synthetic_acts(n=32, N=256)
        steps = 10
    else:
        from benchmarks.common import captured_acts
        x = captured_acts()["r1"]
        steps = 80
    n = x.shape[-1]
    key = jax.random.PRNGKey(0)
    z0 = random_hadamard(n, key)
    rows = [("fig7,start_quant_err", float(quant_error(x @ z0)), "mse")]
    for obj in ("whip", "variance", "kurtosis", "quant"):
        res = calibrate_scan(x, z0, OBJECTIVES[obj], steps=steps, lr=0.1,
                             metrics=(("quant_err", quant_error),))
        errs = res.aux["quant_err"]        # [steps], pre-update trace
        final = float(quant_error(x @ res.rotation))
        rows.append((f"fig7,{obj},final_quant_err", final, "mse"))
        rows.append((f"fig7,{obj},delta_pct",
                     100 * (final - float(errs[0])) / float(errs[0]), "%"))
    return rows
