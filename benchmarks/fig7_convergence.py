"""Paper Fig. 7a + Table 22: quant-error trajectories per objective.

On REAL captured activations of the trained tiny LM (not synthetic): optimize
R with each objective and measure activation quant error along the way.
"""
from __future__ import annotations

import jax

from benchmarks.common import captured_acts
from repro.core import quant_error, random_hadamard
from repro.core.qr_orth import calibrate_qr, qr_rotation
from repro.core.whip import OBJECTIVES


def run() -> list:
    acts = captured_acts()
    x = acts["r1"]
    n = x.shape[-1]
    key = jax.random.PRNGKey(0)
    z0 = random_hadamard(n, key)
    rows = [("fig7,start_quant_err", float(quant_error(x @ z0)), "mse")]
    for obj in ("whip", "variance", "kurtosis", "quant"):
        errs = []

        def cb(k, l, z):
            if k % 20 == 0 or k == 79:
                errs.append(float(quant_error(x @ qr_rotation(z))))

        calibrate_qr(x, z0, OBJECTIVES[obj], steps=80, lr=0.1, callback=cb)
        rows.append((f"fig7,{obj},final_quant_err", errs[-1], "mse"))
        rows.append((f"fig7,{obj},delta_pct",
                     100 * (errs[-1] - errs[0]) / errs[0], "%"))
    return rows
